"""Request-lifecycle tests: submit validation, deadlines/TTL, cancellation
in every state (pending, waiting, slot-resident, swap-parked, recompute-
parked, mid-speculation), graceful drain, bounded-queue shedding, and the
stall-to-per-request-failure path that replaced the engine-wide
``RuntimeError``.

The standing invariants, asserted throughout: survivors' greedy outputs are
token-identical to an undisturbed run (cancellation never perturbs
co-scheduled slots), every request ends in exactly one terminal status, and
the allocator/auditor find zero leaked or aliased blocks afterwards.
"""
import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs.base import ModelConfig
from repro.core.precision import KVTunerSchedule, PrecisionPair
from repro.models.registry import build_model
from repro.serving.engine import (ContinuousEngine, EngineStats, Request,
                                  RequestStatus)
from repro.serving.faults import FaultInjector

jax.config.update("jax_platform_name", "cpu")

R = 8
CHUNK = 16


@pytest.fixture(scope="module")
def tiny_api():
    cfg = ModelConfig(name="lifecycle-tiny", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=61, q_chunk=16, kv_group_size=R)
    return build_model(cfg)


@pytest.fixture(scope="module")
def tiny_params(tiny_api):
    return tiny_api.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sched():
    return KVTunerSchedule.uniform(2, PrecisionPair(8, 4))


def _engine(api, params, sched, **kw):
    kw.setdefault("prefill_chunk", CHUNK)
    kw.setdefault("prefix_cache", True)
    kw.setdefault("max_seq", 64)
    kw.setdefault("max_batch", 2)
    return ContinuousEngine(api, params, sched, **kw)


def _reqs(n=6, plen=20, max_new=8, seed=3, **kw):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, 61, plen),
                    max_new_tokens=max_new, arrival_step=2 * i, **kw)
            for i in range(n)]


def _run(engine, reqs):
    for r in reqs:
        engine.submit(r)
    done = sorted(engine.run(), key=lambda r: r.uid)
    engine.alloc.assert_consistent()
    engine.audit()
    return done


@pytest.fixture(scope="module")
def reference(tiny_api, tiny_params, sched):
    """Undisturbed outputs every lifecycle interleaving must reproduce for
    its survivors."""
    done = _run(_engine(tiny_api, tiny_params, sched), _reqs())
    assert all(r.status == RequestStatus.DONE for r in done)
    return {r.uid: list(r.output) for r in done}


def _check_survivors(done, reference):
    for r in done:
        assert r.terminal, f"request {r.uid} not terminal: {r.status}"
        if r.status == RequestStatus.DONE:
            assert list(r.output) == reference[r.uid], \
                f"survivor {r.uid} diverged"


# ================================================== submit() validation
class TestSubmitValidation:
    def test_duplicate_uid(self, tiny_api, tiny_params, sched):
        eng = _engine(tiny_api, tiny_params, sched)
        eng.submit(Request(uid=1, prompt=np.arange(8), max_new_tokens=2))
        with pytest.raises(ValueError, match="duplicate request id"):
            eng.submit(Request(uid=1, prompt=np.arange(8), max_new_tokens=2))

    def test_empty_prompt(self, tiny_api, tiny_params, sched):
        eng = _engine(tiny_api, tiny_params, sched)
        with pytest.raises(ValueError, match="empty prompt"):
            eng.submit(Request(uid=0, prompt=np.zeros(0, np.int32),
                               max_new_tokens=2))

    @pytest.mark.parametrize("mnt", [0, -3])
    def test_nonpositive_max_new(self, tiny_api, tiny_params, sched, mnt):
        eng = _engine(tiny_api, tiny_params, sched)
        with pytest.raises(ValueError, match="must be positive"):
            eng.submit(Request(uid=0, prompt=np.arange(8),
                               max_new_tokens=mnt))

    def test_deadline_in_past(self, tiny_api, tiny_params, sched):
        eng = _engine(tiny_api, tiny_params, sched)
        with pytest.raises(ValueError, match="already in the past"):
            eng.submit(Request(uid=0, prompt=np.arange(8), max_new_tokens=2,
                               deadline_step=0))

    def test_deadline_before_arrival(self, tiny_api, tiny_params, sched):
        eng = _engine(tiny_api, tiny_params, sched)
        with pytest.raises(ValueError, match="can never complete"):
            eng.submit(Request(uid=0, prompt=np.arange(8), max_new_tokens=2,
                               arrival_step=10, deadline_step=5))

    def test_oversized_still_rejected(self, tiny_api, tiny_params, sched):
        eng = _engine(tiny_api, tiny_params, sched)
        with pytest.raises(ValueError, match="max_seq"):
            eng.submit(Request(uid=0, prompt=np.zeros(80, np.int32),
                               max_new_tokens=40))


# ===================================================== deadlines / TTL
def test_deadline_times_out_and_survivors_match(tiny_api, tiny_params,
                                                sched, reference):
    """A mid-flight deadline ends exactly that request with TIMED_OUT and
    frees its state; everything else finishes bit-identically."""
    reqs = _reqs()
    reqs[2] = Request(uid=2, prompt=reqs[2].prompt, max_new_tokens=8,
                      arrival_step=reqs[2].arrival_step, deadline_step=6)
    done = _run(_engine(tiny_api, tiny_params, sched), reqs)
    victim = next(r for r in done if r.uid == 2)
    assert victim.status == RequestStatus.TIMED_OUT
    assert "deadline_step 6" in victim.error
    assert sum(r.status == RequestStatus.TIMED_OUT for r in done) == 1
    _check_survivors(done, reference)


def test_deadline_expires_while_waiting(tiny_api, tiny_params, sched):
    """A request whose deadline passes before it ever gets a slot is timed
    out from the waiting queue, not admitted dead."""
    eng = _engine(tiny_api, tiny_params, sched, max_batch=1)
    reqs = _reqs(n=3, max_new=12)
    reqs[2] = Request(uid=2, prompt=reqs[2].prompt, max_new_tokens=12,
                      arrival_step=1, deadline_step=3)
    done = _run(eng, reqs)
    victim = next(r for r in done if r.uid == 2)
    assert victim.status == RequestStatus.TIMED_OUT
    assert victim.output == []          # never produced a token
    assert eng.stats.timed_out == 1


# ======================================================== cancellation
def test_cancel_pending_and_unknown(tiny_api, tiny_params, sched):
    eng = _engine(tiny_api, tiny_params, sched)
    eng.submit(Request(uid=0, prompt=np.arange(8), max_new_tokens=2,
                       arrival_step=50))
    assert eng.cancel(0) is True
    assert eng.cancel(0) is False       # already terminal
    assert eng.cancel(99) is False      # unknown
    done = eng.run()
    assert [r.uid for r in done] == [0]
    assert done[0].status == RequestStatus.CANCELLED
    eng.audit()


def test_cancel_mid_decode(tiny_api, tiny_params, sched, reference):
    """Cancelling a slot-resident request mid-decode frees its blocks and
    leaves co-scheduled slots bitwise undisturbed."""
    inj = FaultInjector(cancel_at=[(4, 1)])
    done = _run(_engine(tiny_api, tiny_params, sched, faults=inj), _reqs())
    assert inj.cancels_fired == 1
    victim = next(r for r in done if r.uid == 1)
    assert victim.status == RequestStatus.CANCELLED
    assert 0 < len(victim.output) < 8   # was genuinely mid-decode
    _check_survivors(done, reference)


def test_cancel_swap_parked(tiny_api, tiny_params, sched):
    """Cancel a request while it sits preempted on the host tier: its host
    handles and device pins must be released (satellite: cancellation x
    preemption interleaving)."""
    api, params = tiny_api, tiny_params

    def cancel_parked(eng):
        for uid, parked in list(eng._parked.items()):
            if parked.entries is not None:
                assert eng.cancel(uid)
                return

    inj = FaultInjector(call_at=[(s, cancel_parked) for s in range(2, 40)])
    rng = np.random.default_rng(5)
    tpl = rng.integers(0, 61, 24)
    reqs = [Request(uid=i, prompt=np.concatenate(
                        [tpl, rng.integers(0, 61, 8)]),
                    max_new_tokens=10, arrival_step=3 * i, priority=i)
            for i in range(5)]
    pages = 64 // R + 1
    eng = _engine(api, params, sched, num_blocks=1 + 2 * pages,
                  host_blocks=24, scheduler="priority", faults=inj)
    done = _run(eng, reqs)
    assert eng.stats.preemptions > 0
    assert eng.stats.cancelled >= 1
    assert all(r.terminal for r in done)
    assert eng.host is None or len(eng.host) >= 0  # audit already checked


def test_cancel_mid_speculation(tiny_api, tiny_params, sched, reference):
    """Cancel while the engine runs speculative decode: the rollback
    machinery and the freed slot must not disturb other slots."""
    base = _run(_engine(tiny_api, tiny_params, sched, speculate_k=3),
                _reqs())
    assert {r.uid: list(r.output) for r in base} == reference
    inj = FaultInjector(cancel_at=[(3, 1), (5, 4)])
    done = _run(_engine(tiny_api, tiny_params, sched, speculate_k=3,
                        faults=inj), _reqs())
    assert sum(r.status == RequestStatus.CANCELLED for r in done) == 2
    _check_survivors(done, reference)


def test_cancel_sole_holder_of_spilled_chain(tiny_api, tiny_params, sched):
    """Satellite: cancel the only non-tree holder of a spilled prefix
    chain. The handles it pinned are released, the chain becomes droppable,
    and a full host-LRU sweep cascade-drops it leak-free."""
    rng = np.random.default_rng(9)
    tpl = rng.integers(0, 61, 32)
    pages = 64 // R + 1
    eng = _engine(tiny_api, tiny_params, sched, num_blocks=1 + 2 * pages,
                  host_blocks=32, scheduler="priority")
    reqs = [Request(uid=i, prompt=np.concatenate(
                        [tpl, rng.integers(0, 61, 8)]),
                    max_new_tokens=8, arrival_step=4 * i, priority=i)
            for i in range(4)]

    cancelled = []

    def cancel_any_parked(e):
        for uid, parked in list(e._parked.items()):
            if parked.entries is not None and \
                    any(k == "host" for k, _ in parked.entries):
                assert e.cancel(uid)
                cancelled.append(uid)
                return

    eng.faults = FaultInjector(
        call_at=[(s, cancel_any_parked) for s in range(1, 60)])
    done = _run(eng, reqs)
    assert all(r.terminal for r in done)
    if cancelled:        # a host-parked victim existed and was cancelled
        assert eng.stats.cancelled >= 1
    # tree-only host chains must now be fully droppable without leaks
    if eng.prefix is not None and eng.host is not None:
        eng.prefix.clear()
        eng.alloc.assert_consistent()
        assert len(eng.host) == 0
    eng.audit()


# ============================================== drain + bounded queue
def test_drain_sheds_waiting_finishes_running(tiny_api, tiny_params, sched,
                                              reference):
    inj = FaultInjector(call_at=[(3, lambda e: e.drain())])
    eng = _engine(tiny_api, tiny_params, sched, faults=inj)
    done = _run(eng, _reqs())
    assert eng.draining
    shed = [r for r in done if r.status == RequestStatus.SHED]
    fin = [r for r in done if r.status == RequestStatus.DONE]
    assert shed and fin and len(shed) + len(fin) == 6
    assert all("drain" in r.error for r in shed)
    _check_survivors(done, reference)
    # post-drain submissions are shed on arrival, not queued
    late = Request(uid=100, prompt=np.arange(16), max_new_tokens=4)
    eng.submit(late)
    assert late.status == RequestStatus.SHED
    done2 = eng.run()                   # returns instantly: nothing to serve
    assert late in done2 and len(done2) == 7


def test_drain_finishes_parked_work(tiny_api, tiny_params, sched):
    """Preemption-parked requests are work in flight: drain completes them
    instead of shedding."""
    rng = np.random.default_rng(11)
    pages = 64 // R + 1

    def drain_once_parked(e):
        if e._parked and not e.draining:
            e.drain()

    inj = FaultInjector(call_at=[(s, drain_once_parked)
                                 for s in range(1, 60)])
    eng = _engine(tiny_api, tiny_params, sched, num_blocks=1 + 2 * pages,
                  host_blocks=24, scheduler="priority", faults=inj)
    reqs = [Request(uid=i, prompt=rng.integers(0, 61, 24), max_new_tokens=8,
                    arrival_step=2 * i, priority=i) for i in range(5)]
    done = _run(eng, reqs)
    statuses = {r.uid: r.status for r in done}
    assert all(r.terminal for r in done)
    if eng.stats.preemptions:
        # every request that was ever parked still finished
        assert RequestStatus.DONE in statuses.values()


def test_max_waiting_sheds_lowest_priority(tiny_api, tiny_params, sched):
    rng = np.random.default_rng(13)
    reqs = [Request(uid=i, prompt=rng.integers(0, 61, 16), max_new_tokens=6,
                    arrival_step=0, priority=i) for i in range(6)]
    eng = _engine(tiny_api, tiny_params, sched, scheduler="priority",
                  max_waiting=2)
    done = _run(eng, reqs)
    shed = sorted(r.uid for r in done if r.status == RequestStatus.SHED)
    assert eng.stats.shed == len(shed) > 0
    assert all("over capacity" in r.error for r in done
               if r.status == RequestStatus.SHED)
    # priority scheduler sheds from the LOW-priority end
    kept = [r.uid for r in done if r.status == RequestStatus.DONE]
    assert max(shed) < min(5, max(kept))


def test_stall_fails_head_instead_of_crashing(tiny_api, tiny_params, sched):
    """The old ``RuntimeError('admission stalled...')`` is now a
    per-request FAILED ending: with every alloc call faulted, requests fail
    one by one and the engine returns instead of raising."""
    inj = FaultInjector(p_alloc_fail=1.0)
    eng = _engine(tiny_api, tiny_params, sched, faults=inj, stall_ticks=5)
    done = _run(eng, _reqs(n=3))
    assert all(r.status == RequestStatus.FAILED for r in done)
    assert all("admission stalled" in r.error for r in done)
    assert eng.stats.failed == 3 and inj.alloc_faults > 0


# ======================================================= stats surface
def test_empty_percentiles_return_zero():
    """Satellite: reports from drained/all-shed runs (no samples) must not
    raise."""
    s = EngineStats()
    assert s.decode_p50_ms == 0.0 and s.decode_p95_ms == 0.0
    assert s.prefill_p50_ms == 0.0 and s.prefill_p95_ms == 0.0
    assert s.admit_p50_ms == 0.0 and s.admit_p95_ms == 0.0
    assert s.accepted_len_p50 == 0.0 and s.accepted_len_p95 == 0.0


def test_terminal_counts_breakdown(tiny_api, tiny_params, sched):
    inj = FaultInjector(cancel_at=[(4, 0)])
    reqs = _reqs(n=4)
    reqs[3] = Request(uid=3, prompt=reqs[3].prompt, max_new_tokens=8,
                      arrival_step=reqs[3].arrival_step, deadline_step=8)
    eng = _engine(tiny_api, tiny_params, sched, faults=inj)
    done = _run(eng, reqs)
    tc = eng.stats.terminal_counts
    assert tc["cancelled"] == 1 and tc["timed_out"] == 1
    assert sum(tc[k] for k in ("done", "cancelled", "timed_out", "shed",
                               "failed")) == len(done) == 4


def test_status_progression(tiny_api, tiny_params, sched):
    eng = _engine(tiny_api, tiny_params, sched)
    req = Request(uid=0, prompt=np.arange(16), max_new_tokens=4)
    assert req.status == RequestStatus.QUEUED
    eng.submit(req)
    (done,) = eng.run()
    assert done.status == RequestStatus.DONE and done.done
    assert done.error is None and done.terminal
