"""Paper-claim checks on the TRAINED benchmark model (attention structure is
what creates the sensitivity asymmetries — random init provably can't, see
tests/test_kvtuner.py). Uses the cached artifact from benchmarks/common.py;
skips if it hasn't been trained yet (run `python -m benchmarks.run` first)."""
import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import sensitivity
from repro.core.clustering import cluster_layers
from repro.core.precision import MODE_KIVI, MODE_PER_TOKEN
from repro.core.pruning import prune_intra_layer

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def trained():
    from benchmarks.common import ART_DIR, get_bench_model
    if not os.path.isdir(ART_DIR) or not os.listdir(ART_DIR):
        pytest.skip("bench model not trained yet (run python -m benchmarks.run)")
    ctx = get_bench_model()
    caps = sensitivity.capture_activations(ctx.api, ctx.params,
                                           ctx.calib_batches())
    return ctx, caps


def test_trained_model_solves_task(trained):
    from repro.data import synthetic
    ctx, _ = trained
    eb = ctx.eval_batches(1, 32)[0]
    logits, _ = ctx.api.forward(ctx.params, eb)
    em = synthetic.exact_match_accuracy(
        logits, {k: np.asarray(v) for k, v in eb.items()})
    assert em > 0.9


def test_errors_monotone_and_key_sensitivity(trained):
    """Monotonicity + Lemma-1 consistency.

    Our trained 2M model's attention is highly *concentrated* (the chain task
    induces streaming/positional heads) — exactly the regime the paper's
    Lemma 1 proves robust to key quantization. So unlike the 8B models of
    Table 3 (retrieval-heavy → key-dominant errors), this model is
    value-sensitive; we assert the predictions that are scale-invariant:
    monotonicity in bits, key degradation at matched value precision, and
    the concentration-robustness link itself (checked in the benchmark's
    sparsity/e_o correlation). See EXPERIMENTS.md §Reproduction scale note.
    """
    ctx, caps = trained
    for mode in (MODE_PER_TOKEN, MODE_KIVI):
        errs = sensitivity.layer_errors(caps, ctx.api.cfg, mode)
        names = {p.name: i for i, p in enumerate(errs.pairs)}
        eo = errs.e_o.mean(axis=0)
        assert eo[names["KV8"]] < eo[names["KV4"]] < eo[names["KV2"]]
        # dropping K bits at fixed V strictly hurts (both columns)
        assert eo[names["K2V4"]] > eo[names["K8V4"]]
        assert eo[names["K2V8"]] > eo[names["K8V8" if "K8V8" in names
                                          else "KV8"]]
    # Lemma 1: the model's attention is concentrated → keys must be MORE
    # robust than values here (the inverse of the paper's 8B retrieval-heavy
    # regime, and the direct prediction of its own theory)
    errs = sensitivity.layer_errors(caps, ctx.api.cfg, MODE_PER_TOKEN)
    names = {p.name: i for i, p in enumerate(errs.pairs)}
    eo = errs.e_o.mean(axis=0)
    sparsity = sensitivity.attention_pattern_stats(caps, ctx.api.cfg.q_per_kv)
    if sparsity.mean() > 0.5:  # concentrated-attention regime
        assert eo[names["K2V8"]] < eo[names["K8V2"]]


def test_layer_profile_prompt_independent(trained):
    """§4.5: sensitivity profile is a model property, not a prompt property."""
    ctx, caps = trained
    errs_a = sensitivity.layer_errors(caps, ctx.api.cfg, MODE_PER_TOKEN)
    caps_b = sensitivity.capture_activations(
        ctx.api, ctx.params, ctx.calib_batches(seed=987654))
    errs_b = sensitivity.layer_errors(caps_b, ctx.api.cfg, MODE_PER_TOKEN)
    i = [p.name for p in errs_a.pairs].index("KV4")
    corr = np.corrcoef(errs_a.e_o[:, i], errs_b.e_o[:, i])[0, 1]
    assert corr > 0.8, f"layer profile not prompt-independent (corr={corr:.3f})"


def test_pipeline_reduces_space_on_trained_model(trained):
    ctx, caps = trained
    errs = sensitivity.layer_errors(caps, ctx.api.cfg, MODE_PER_TOKEN)
    pruned = prune_intra_layer(errs)
    groups = cluster_layers(pruned, eps=0.25)
    L = pruned.num_layers
    assert pruned.space_size() < 9.0 ** L
    assert groups.search_space_size() <= pruned.space_size()
    assert groups.num_groups <= L
