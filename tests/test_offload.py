"""Tiered KV block store tests: host-RAM offload, preemption-aware
scheduling, and spill-instead-of-drop prefix caching.

Covers the tier subsystem end-to-end: bitwise host<->device block round
trips (mixed per-layer precision, unquantized layers included), the
refcounted ``HostBlockStore``, allocator utilization/consistency stats,
scheduler policy resolution and ordering, and the engine-level guarantees —
preemption/resume and recompute-fallback token-identity across scheduler
policies x ``decode_horizon`` x ``batched_admission`` x ``use_pallas``,
host-tier prefix hits on spilled chains, and workload reproducibility from
explicit seeds.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.cache.offload import HostBlockStore, extract_blocks
from repro.cache.paged import BlockAllocator, PagedKVPool
from repro.cache.prefix import PrefixCache
from repro.configs.base import ModelConfig
from repro.core.precision import MODE_KIVI, KVTunerSchedule, PrecisionPair
from repro.models.registry import build_model
from repro.serving.engine import ContinuousEngine, Request
from repro.serving.scheduler import (POLICIES, SchedulerPolicy,
                                     make_scheduler)

jax.config.update("jax_platform_name", "cpu")

R = 8        # tiny quant group -> groups/flushes within a few tokens
CHUNK = 16   # prefill chunk (2 groups)


@pytest.fixture(scope="module")
def tiny_api():
    cfg = ModelConfig(name="offload-tiny", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=61, q_chunk=16, kv_group_size=R)
    return build_model(cfg)


@pytest.fixture(scope="module")
def tiny_params(tiny_api):
    return tiny_api.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sched():
    return KVTunerSchedule.uniform(2, PrecisionPair(8, 4))


def _pools(seed=0):
    """Two-layer pool list with a None (non-attention) gap and mixed
    precision, incl. an unquantized layer (dummy scale/zero path)."""
    key = jax.random.PRNGKey(seed)
    hkv, d, n = 2, 16, 6
    pools = [
        PagedKVPool.init(n, 1, hkv, d, PrecisionPair(8, 4), MODE_KIVI, R,
                         dtype=jnp.float32),
        None,
        PagedKVPool.init(n, 1, hkv, d, PrecisionPair(16, 16), MODE_KIVI, R,
                         dtype=jnp.float32),
    ]
    for i, p in enumerate(pools):
        if p is None:
            continue
        k = jax.random.normal(jax.random.fold_in(key, 2 * i),
                              (1, hkv, 2 * R, d), jnp.float32)
        v = jax.random.normal(jax.random.fold_in(key, 2 * i + 1),
                              (1, hkv, 2 * R, d), jnp.float32)
        pools[i] = p.write_prefill_groups(k, v, jnp.asarray([2, 4]))
    return pools


def _gather(pools, pt):
    return [None if p is None else
            tuple(np.asarray(x) for x in p.gather_dequant(pt))
            for p in pools]


# =============================================== host store: bitwise moves
def test_host_roundtrip_bitwise():
    """swap-out -> swap-in (to different block ids) -> gather_dequant is
    bitwise identical, for quantized and unquantized layers alike."""
    pools = _pools()
    pt = jnp.asarray([[2, 4]], jnp.int32)
    before = _gather(pools, pt)

    store = HostBlockStore(capacity=4)
    handles = store.put_blocks(pools, [2, 4])
    assert len(store) == 2 and store.free_slots == 2
    # clobber the source blocks, then restore into DIFFERENT slots
    zeroed = list(pools)
    for i, p in enumerate(zeroed):
        if p is None:
            continue
        import dataclasses
        zeroed[i] = dataclasses.replace(
            p, k_codes=jnp.zeros_like(p.k_codes),
            v_codes=jnp.zeros_like(p.v_codes))
    restored = store.take_to_device(zeroed, handles, [1, 3])
    store.release(handles)
    assert len(store) == 0
    after = _gather(restored, jnp.asarray([[1, 3]], jnp.int32))
    for b, a in zip(before, after):
        if b is None:
            continue
        np.testing.assert_array_equal(b[0], a[0])
        np.testing.assert_array_equal(b[1], a[1])


def test_host_store_capacity_and_refcounts():
    pools = _pools()
    store = HostBlockStore(capacity=1)
    assert store.put_blocks(pools, [2, 4]) is None   # over capacity: no-op
    assert len(store) == 0
    (h,) = store.put_blocks(pools, [2])
    assert store.free_slots == 0
    store.ref([h])
    store.release([h])
    assert len(store) == 1                # second owner still holds it
    store.release([h])
    assert len(store) == 0
    with pytest.raises(ValueError, match="handle"):
        store.release([h])                # double free raises
    with pytest.raises(ValueError):
        HostBlockStore(capacity=-1)


def test_extract_blocks_payload_shapes():
    pools = _pools()
    payloads = extract_blocks(pools, [2])
    (quant, raw) = payloads[0][0], payloads[0][1]
    assert quant[0].shape[0] == 2          # k_codes [Hkv, R, D*kb/8]
    assert quant[1] is not None            # quantized: scales move
    assert raw[1] is None and raw[2] is None  # bits>=16: dummies stay put


# ==================================== allocator stats + consistency check
def test_allocator_stats_and_consistency():
    a = BlockAllocator(9)
    assert a.utilization == 0.0 and a.high_watermark == 0
    x = a.alloc(4)
    assert a.allocated_blocks == 4 and a.utilization == 0.5
    assert a.high_watermark == 4
    a.release(x[:2])
    assert a.high_watermark == 4           # watermark is the peak
    a.assert_consistent()
    # corrupt deliberately: a freed id with a dangling refcount
    a._refs[x[0]] = 1
    with pytest.raises(AssertionError, match="free but has refcount"):
        a.assert_consistent()
    a._refs[x[0]] = 0
    a._refs[x[2]] = 0                      # leaked: allocated, refcount 0
    with pytest.raises(AssertionError, match="leaked"):
        a.assert_consistent()


# ====================================================== scheduler policies
def test_make_scheduler_resolution():
    assert make_scheduler("ssf").name == "ssf"
    assert isinstance(make_scheduler(POLICIES["fcfs"]), SchedulerPolicy)
    inst = POLICIES["priority"]()
    assert make_scheduler(inst) is inst
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_scheduler("lifo")
    with pytest.raises(TypeError):
        make_scheduler(42)


def test_policy_orderings():
    class Eng:  # engine stub: no prefix cache, nothing running
        prefix = None
        _parked = {}
        _slots = []

        @staticmethod
        def suffix_tokens(req):
            return len(req.prompt)

    a = Request(uid=0, prompt=np.zeros(8), arrival_step=5, priority=1,
                max_new_tokens=4)
    b = Request(uid=1, prompt=np.zeros(24), arrival_step=0, priority=3,
                max_new_tokens=4)
    eng = Eng()
    fcfs, prio, ssf = (make_scheduler(n) for n in ("fcfs", "priority", "ssf"))
    assert fcfs.admission_key(b, eng) < fcfs.admission_key(a, eng)
    assert prio.admission_key(b, eng) < prio.admission_key(a, eng)
    assert ssf.admission_key(a, eng) < ssf.admission_key(b, eng)  # shorter
    # preemption predicates are strict: equal rank never preempts
    assert not fcfs.wants_preempt(a, a, eng)
    assert not prio.wants_preempt(a, a, eng)
    assert not ssf.wants_preempt(a, a, eng)
    assert fcfs.wants_preempt(b, a, eng)       # earlier arrival wins
    assert prio.wants_preempt(b, a, eng)       # higher priority wins
    assert ssf.wants_preempt(a, b, eng)        # less remaining work wins


# ============================================ engine: preemption + resume
def _engine(api, params, sched, **kw):
    kw.setdefault("prefill_chunk", CHUNK)
    kw.setdefault("prefix_cache", True)
    kw.setdefault("max_seq", 48)
    return ContinuousEngine(api, params, sched, max_batch=2, **kw)


def _workload(seed=1, n_templates=2, n=8):
    """Late arrivals with climbing priority and shrinking budgets: priority
    and ssf schedulers both find preemption victims under pool pressure."""
    rng = np.random.default_rng(seed)
    tpls = [rng.integers(0, 61, 32) for _ in range(n_templates)]
    prompts = [np.concatenate([tpls[i % n_templates],
                               rng.integers(0, 61, 5)]) for i in range(n)]
    arrivals = [0, 0, 3, 5, 8, 11, 14, 17][:n]
    prios = [0, 0, 2, 3, 4, 5, 6, 7][:n]
    maxnew = [12, 12, 6, 6, 5, 5, 4, 4][:n]
    return [Request(uid=i, prompt=p, max_new_tokens=maxnew[i],
                    arrival_step=arrivals[i], priority=prios[i])
            for i, p in enumerate(prompts)]


def _run(engine, reqs):
    for r in reqs:
        engine.submit(r)
    done = sorted(engine.run(), key=lambda r: r.uid)
    engine.alloc.assert_consistent()
    return [r.output for r in done]


@pytest.fixture(scope="module")
def reference(tiny_api, tiny_params, sched):
    """Unconstrained-pool outputs every overload config must reproduce."""
    return _run(_engine(tiny_api, tiny_params, sched), _workload())


@pytest.mark.parametrize("kw", [
    dict(scheduler="priority"),
    dict(scheduler="priority", decode_horizon=3),
    dict(scheduler="priority", batched_admission=True),
    dict(scheduler="priority", use_pallas=True),
], ids=["priority", "horizon3", "batched", "pallas"])
def test_preempt_resume_token_identity(tiny_api, tiny_params, sched,
                                       reference, kw):
    """The acceptance property: an undersized pool + host tier + preemption
    finishes every request with greedy outputs bitwise-identical to the
    unconstrained run — swap-out/swap-in is a bitwise round trip and shared
    blocks stay pinned."""
    eng = _engine(tiny_api, tiny_params, sched, num_blocks=14,
                  host_blocks=10, **kw)
    assert _run(eng, _workload()) == reference
    s = eng.stats
    assert s.preemptions > 0 and s.resumes > 0
    assert s.swap_out_blocks > 0 and s.swap_in_blocks >= s.swap_out_blocks
    assert s.recompute_resumes == 0
    assert s.pool_high_watermark == 1.0     # the pool really was the wall
    assert not eng._parked                  # everyone resumed and finished
    # remaining host entries can only be tree-owned spilled prefixes
    assert len(eng.host) <= eng.stats.prefix_spilled_blocks


@pytest.mark.parametrize("pallas", [False, True], ids=["xla", "pallas"])
def test_ssf_preempts_long_victim(tiny_api, tiny_params, sched, pallas):
    """Shortest-suffix-first: a short late arrival displaces the
    long-remaining victim, and outputs still match the unconstrained run
    (kernel on or off)."""
    rng = np.random.default_rng(5)
    long_p = [rng.integers(0, 61, 37) for _ in range(2)]
    short_p = [rng.integers(0, 61, 12) for _ in range(2)]
    reqs = lambda: (  # noqa: E731 - rebuilt per engine (outputs accumulate)
        [Request(uid=i, prompt=p, max_new_tokens=24, arrival_step=0)
         for i, p in enumerate(long_p)]
        + [Request(uid=2 + i, prompt=p, max_new_tokens=3, arrival_step=4)
           for i, p in enumerate(short_p)])
    ref = _run(_engine(tiny_api, tiny_params, sched, scheduler="ssf",
                       max_seq=64, use_pallas=pallas), reqs())
    eng = _engine(tiny_api, tiny_params, sched, scheduler="ssf",
                  max_seq=64, num_blocks=18, host_blocks=12,
                  use_pallas=pallas)
    assert _run(eng, reqs()) == ref
    assert eng.stats.preemptions > 0 and eng.stats.resumes > 0


@pytest.mark.parametrize("pallas", [False, True], ids=["xla", "pallas"])
def test_fcfs_is_non_preemptive_under_overload(tiny_api, tiny_params, sched,
                                               pallas):
    """FCFS finds no victim by construction (running requests never arrived
    later than a waiter), so overload degrades to stall-and-wait — but the
    host tier still spills/revives prefixes and outputs stay identical."""
    ref = _run(_engine(tiny_api, tiny_params, sched, use_pallas=pallas),
               _workload())
    eng = _engine(tiny_api, tiny_params, sched, scheduler="fcfs",
                  num_blocks=14, host_blocks=10, use_pallas=pallas)
    assert _run(eng, _workload()) == ref
    assert eng.stats.preemptions == 0


def test_spilled_prefix_hits(tiny_api, tiny_params, sched):
    """Evicted radix chains spill to the host tier and a later match on the
    spilled chain swaps it back in, counting as BOTH a prefix hit and a
    host-tier hit — instead of yesterday's drop + full re-prefill."""
    rng = np.random.default_rng(7)
    tpls = [rng.integers(0, 61, 32) for _ in range(3)]
    prompts = [np.concatenate([tpls[i % 3], rng.integers(0, 61, 5)])
               for i in range(12)]
    mk = lambda: [Request(uid=i, prompt=p, max_new_tokens=5,  # noqa: E731
                          arrival_step=(0 if i < 6 else 2))
                  for i, p in enumerate(prompts)]
    ref = _run(_engine(tiny_api, tiny_params, sched), mk())
    eng = _engine(tiny_api, tiny_params, sched, num_blocks=14,
                  host_blocks=16)
    assert _run(eng, mk()) == ref
    s = eng.stats
    assert s.prefix_spilled_blocks > 0
    assert s.host_prefix_hits > 0
    assert s.host_prefix_hit_tokens > 0
    assert s.swap_in_blocks > 0
    # host hits are a subset of hits; spilled-chain tokens were NOT
    # prefilled again (the whole point): every prompt token was either
    # prefilled or served from a (device- or host-) cached chain
    assert s.host_prefix_hits <= s.prefix_hits
    assert s.prefill_tokens + s.prefix_hit_tokens == \
        sum(len(p) for p in prompts)


def test_recompute_fallback_when_host_full(tiny_api, tiny_params, sched):
    """Host tier too small to park a victim's blocks: preemption drops them
    and resume replays prompt + recorded tokens — still token-identical."""
    ref = _run(_engine(tiny_api, tiny_params, sched), _workload())
    eng = _engine(tiny_api, tiny_params, sched, scheduler="priority",
                  num_blocks=14, host_blocks=2)
    assert _run(eng, _workload()) == ref
    s = eng.stats
    assert s.preemptions > 0
    assert s.recompute_resumes > 0
    assert s.replay_steps > 0
    # resume re-reservation must not double-count admission hit/miss stats
    assert s.prefix_hits + s.prefix_misses == s.admitted


def test_recompute_only_preemption_no_host_tier(tiny_api, tiny_params,
                                                sched):
    """preempt=True with host_blocks=0: every preemption takes the
    recompute path (the engine never allocates a host store)."""
    ref = _run(_engine(tiny_api, tiny_params, sched), _workload())
    eng = _engine(tiny_api, tiny_params, sched, scheduler="priority",
                  num_blocks=14, host_blocks=0, preempt=True)
    assert _run(eng, _workload()) == ref
    assert eng.host is None
    assert eng.stats.preemptions > 0
    assert eng.stats.recompute_resumes == eng.stats.preemptions
    assert eng.stats.swap_out_blocks == 0


# ============================================== prefix cache spill details
def test_prefix_spill_and_promote():
    """Node-level spill semantics: evict with a host store keeps the chain
    matchable; insert with a fresh device block promotes it back and frees
    the host copy."""
    a = BlockAllocator(16)
    store = HostBlockStore(capacity=8)
    cache = PrefixCache(a, group_size=4, host_store=store)
    pools = None  # spill payloads only matter on the engine path

    toks = np.arange(12)
    blocks = a.alloc(3)
    cache.insert(toks, blocks)
    a.release(blocks)            # tree is sole owner
    # without pools, evict drops (no payload to move) — use drop_host path
    # via the engine-style call: pools=None means plain drop
    assert cache.evict(1, pools=pools) == 1
    assert cache.dropped_blocks == 1
    assert len(cache) == 2

    # re-adopt a block for the dropped group, then spill WITH payloads
    tail = a.alloc(1)
    cache.insert(toks, blocks[:2] + tail)
    a.release(tail)
    real_pools = _pools()
    assert cache.evict(2, pools=real_pools) == 2
    assert cache.spilled_blocks == 2 and len(store) == 2
    assert len(cache) == 3       # nodes survive as host-resident
    nodes = cache.match_nodes(toks)
    assert len(nodes) == 3
    assert nodes[0].on_device and not nodes[1].on_device
    assert cache.match(toks) == [nodes[0].block]   # device prefix only

    # promotion: a request prefilled fresh device blocks for those groups
    fresh = a.alloc(2)
    cache.insert(toks, [nodes[0].block] + fresh)
    assert len(store) == 0       # host copies freed
    assert all(n.on_device for n in cache.match_nodes(toks))
    cache.clear()
    a.assert_consistent()


def test_drop_cascades_host_suffix_and_prefers_spill():
    """A dropped device node takes its detached host-resident suffix with it
    (no handle leaks); and under store pressure eviction prefers dropping
    the coldest host entry so a hotter victim can still spill."""
    toks = np.arange(12)

    a = BlockAllocator(16)
    store = HostBlockStore(capacity=2)
    cache = PrefixCache(a, group_size=4, host_store=store)
    blocks = a.alloc(3)
    cache.insert(toks, blocks)
    a.release(blocks)
    cache.evict(2, pools=_pools())          # chain is now [dev, host, host]
    cache._drop(cache.match_nodes(toks)[0])  # backstop path: cascade
    assert len(cache) == 0 and len(store) == 0
    assert cache.host_dropped_blocks == 2 and cache.dropped_blocks == 1
    a.assert_consistent()

    a2, store2 = BlockAllocator(16), HostBlockStore(capacity=2)
    c2 = PrefixCache(a2, group_size=4, host_store=store2)
    b2 = a2.alloc(3)
    c2.insert(toks, b2)
    a2.release(b2)
    c2.evict(2, pools=_pools())
    assert c2.evict(1, pools=_pools()) == 1  # store full: drop cold + spill
    assert len(c2) == 2 and len(store2) == 2  # chain survives [host, host]
    assert c2.host_dropped_blocks == 1 and c2.spilled_blocks == 3
    assert not any(n.on_device for n in c2.match_nodes(toks)[:2])
    a2.assert_consistent()


def test_drop_host_lru():
    a = BlockAllocator(16)
    store = HostBlockStore(capacity=8)
    cache = PrefixCache(a, group_size=4, host_store=store)
    toks = np.arange(8)
    blocks = a.alloc(2)
    cache.insert(toks, blocks)
    a.release(blocks)
    assert cache.evict(2, pools=_pools()) == 2
    assert len(store) == 2
    assert cache.drop_host_lru(1) == 1
    assert len(store) == 1 and cache.host_dropped_blocks == 1
    assert cache.drop_host_lru(5) == 1     # only one left
    assert len(store) == 0 and len(cache) == 0


# ======================================================== reproducibility
def test_workloads_reproducible_from_seed():
    from benchmarks.common import poisson_arrivals, shared_template_prompts
    from benchmarks.table12_offload import build_workload

    r1 = np.random.default_rng(11)
    r2 = np.random.default_rng(11)
    p1 = shared_template_prompts(61, 2, 3, 16, 4, r1)
    p2 = shared_template_prompts(61, 2, 3, 16, 4, r2)
    assert all(np.array_equal(a, b) for a, b in zip(p1, p2))
    assert poisson_arrivals(9, 1.5, r1) == poisson_arrivals(9, 1.5, r2)
    w1, w2 = build_workload(61, 2, 2, 16, 4, seed=3), \
        build_workload(61, 2, 2, 16, 4, seed=3)
    assert all(np.array_equal(a, b) for a, b in zip(w1[0], w2[0]))
    assert w1[1:] == w2[1:]
    assert build_workload(61, 2, 2, 16, 4, seed=4)[1] != w1[1] or \
        not all(np.array_equal(a, b) for a, b in
                zip(build_workload(61, 2, 2, 16, 4, seed=4)[0], w1[0]))
