"""Distribution-layer tests: sharding rules/specs (divisibility-safety,
dedup), and an SPMD parity check in a subprocess with 8 host devices
(sharded jit == single-device execution)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import AbstractMesh

from repro.configs import ARCH_CONFIGS
from repro.distributed.sharding import make_rules
from repro.distributed.specs import SpecBuilder, _param_logical

jax.config.update("jax_platform_name", "cpu")


def abstract_mesh(shape=(16, 16), axes=("data", "model")):
    return AbstractMesh(tuple(zip(axes, shape)))


# ----------------------------------------------------------------- rules
def test_rules_divisibility_fallback():
    rules = make_rules(abstract_mesh())
    assert rules.axes("heads", 32) == "model"
    assert rules.axes("heads", 56) is None          # 56 % 16 != 0 → replicate
    assert rules.axes("experts", 128) == "model"
    assert rules.axes("experts", 8) is None


def test_rules_greedy_prefix():
    rules = make_rules(abstract_mesh((2, 16, 16), ("pod", "data", "model")))
    # batch=("pod","data"): 16 % 32 != 0 but 16 % 2 == 0 → pod only
    assert rules.axes("batch", 16) == "pod"
    assert rules.axes("batch", 256) == ("pod", "data")
    assert rules.axes("batch", 1) is None


def test_spec_dedup_one_axis_one_dim():
    rules = make_rules(abstract_mesh(), {"seq": ("model",)})
    spec = rules.spec("batch", "seq", "vocab", shape=(256, 4096, 32000))
    flat = [a for part in spec for a in
            ((part,) if not isinstance(part, tuple) else part) if a]
    assert len(flat) == len(set(flat))  # no duplicate mesh axes


def test_rules_missing_mesh_axis_drops_out():
    """A table written for the multi-pod mesh still resolves on a
    (data, model) mesh: names absent from the mesh silently drop."""
    rules = make_rules(abstract_mesh())        # no "pod" axis
    assert rules.axes("batch", 256) == "data"  # ("pod","data") → data only
    only_pod = make_rules(abstract_mesh(), {"weird": ("pod",)})
    assert only_pod.axes("weird", 256) is None
    assert only_pod.spec("weird", shape=(256,)) == \
        jax.sharding.PartitionSpec(None)


def test_rules_repeated_axis_collapses():
    """("model", "model") in one entry must not double-count the axis —
    it collapses to a single occurrence."""
    rules = make_rules(abstract_mesh(), {"dup": ("model", "model")})
    assert rules.axes("dup", 32) == "model"
    # P(("model","model")) would claim 256 shards; the dedup keeps 16
    assert rules.spec("dup", shape=(32,)) == \
        jax.sharding.PartitionSpec("model")


def test_rules_degenerate_dim_replicates():
    rules = make_rules(abstract_mesh())
    assert rules.axes("heads", 0) is None
    assert rules.axes("heads", -4) is None


def test_spec_same_logical_twice_earlier_dim_wins():
    """One mesh axis shards at most one dim: the second `heads` dim (e.g.
    q heads and kv heads of one fused tensor) falls back to replication."""
    rules = make_rules(abstract_mesh())
    spec = rules.spec("heads", "kv_heads", shape=(32, 32))
    assert spec == jax.sharding.PartitionSpec("model", None)


def test_spec_pruned_dim_frees_axes_for_later_dims():
    """A dim whose post-dedup divisibility fails must fall back to
    replication WITHOUT claiming the axes it could not use — a later dim
    with a compatible shape still gets them."""
    rules = make_rules(abstract_mesh(), {"a": ("data", "model"),
                                         "b": ("model",)})
    # dim0: data(16) fits 16 but data*model(256) doesn't divide 16 → data
    # only; dim1 takes model — the greedy prefix never blocks it here
    spec = rules.spec("a", "b", shape=(16, 32))
    assert spec == jax.sharding.PartitionSpec("data", "model")
    # dim0 claims nothing at all when even its first axis fails; dim1 must
    # still see every axis free
    spec = rules.spec("a", "b", shape=(7, 32))
    assert spec == jax.sharding.PartitionSpec(None, "model")


# ----------------------------------------------------------- test mesh
def test_make_test_mesh_shape_and_axes():
    """conftest forces 8 host devices, so the test mesh builds in-process;
    all devices land on the LAST axis (the one the paged pool shards)."""
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(8)
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["data"] == 1 and mesh.shape["model"] == 8
    single = make_test_mesh(1, axes=("model",))
    assert single.shape["model"] == 1


def test_make_test_mesh_validates():
    from repro.launch.mesh import make_test_mesh

    with pytest.raises(ValueError):
        make_test_mesh(8, axes=())
    with pytest.raises(RuntimeError, match="devices are visible"):
        make_test_mesh(len(jax.devices()) + 1)


# ----------------------------------------------------------------- specs
@pytest.mark.parametrize("arch", sorted(ARCH_CONFIGS))
def test_param_specs_all_archs_valid(arch):
    """Every param leaf gets a spec whose axes divide its dims."""
    cfg = ARCH_CONFIGS[arch]()
    from repro.models.registry import build_model
    api = build_model(cfg)
    abstract = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    mesh = abstract_mesh()
    builder = SpecBuilder(make_rules(mesh))
    specs = builder.params(abstract)

    def check(path, leaf, spec):
        used = set()
        for dim, part in enumerate(spec):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            total = 1
            for a in axes:
                assert a not in used, f"{arch}: duplicate axis {a} in {spec}"
                used.add(a)
                total *= dict(zip(mesh.axis_names, mesh.shape.values())) \
                    if False else mesh.shape[a]
            assert leaf.shape[dim] % total == 0, \
                f"{arch} {path}: dim {dim} ({leaf.shape}) not divisible by {spec}"

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), abstract, specs)


def test_big_tensors_get_fsdp():
    cfg = ARCH_CONFIGS["deepseek-67b"]()
    from repro.models.registry import build_model
    api = build_model(cfg)
    abstract = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    builder = SpecBuilder(make_rules(abstract_mesh()))
    specs = builder.params(abstract)
    # the stacked MLP weights [95, 8192, 22016] must be 2-D sharded
    spec = specs["layers"]["mlp"]["w_up"]
    flat = [a for part in spec if part
            for a in ((part,) if not isinstance(part, tuple) else part)]
    assert "model" in flat and "data" in flat, spec


def test_moe_logical_assignment():
    assert _param_logical(["layers", "moe", "w_gate"], (35, 128, 7168, 4864)) \
        == ["_", "experts", "_", "expert_ff"]
    assert _param_logical(["layers", "attn", "wq"], (22, 2048, 2048)) \
        == ["_", "fsdp?", "model"]


# ---------------------------------------------------- SPMD parity (8 dev)
_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json
    from repro.configs.base import ModelConfig
    from repro.models.registry import build_model
    from repro.launch.steps import build_cell
    from repro.configs.base import ShapeCell
    from repro.distributed.sharding import make_rules, use_rules
    from repro.distributed.specs import SpecBuilder
    from repro.training.optimizer import AdamW
    from repro.training.trainer import TrainState, make_train_step

    cfg = ModelConfig(name="parity", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                      q_chunk=16)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 64)}
    opt = AdamW(lr=1e-3)
    state = TrainState(params=params, opt=opt.init(params), ef=None)
    step = make_train_step(api, opt)

    # single-device reference
    ref_state, ref_metrics = jax.jit(step)(state, batch)
    ref_loss = float(ref_metrics["loss"])

    # sharded execution on a 2x4 mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = make_rules(mesh, {"seq": ("model",)})
    b = SpecBuilder(rules, fsdp_threshold=10**12)
    st_sh = b.named(b.train_state(jax.eval_shape(lambda: state)))
    bt_sh = b.named(b.batch(jax.eval_shape(lambda: batch)))
    def sharded_step(s, bt):
        with use_rules(rules):
            return step(s, bt)
    with mesh:
        f = jax.jit(sharded_step, in_shardings=(st_sh, bt_sh),
                    out_shardings=(st_sh, None))
        sh_state, sh_metrics = f(state, batch)
    sh_loss = float(sh_metrics["loss"])
    # compare updated params
    diffs = jax.tree.map(lambda a, c: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - c.astype(jnp.float32)))),
        ref_state.params, sh_state.params)
    print(json.dumps({"ref_loss": ref_loss, "sh_loss": sh_loss,
                      "max_param_diff": max(jax.tree.leaves(diffs))}))
""")


def test_spmd_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _PARITY_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(result["ref_loss"] - result["sh_loss"]) < 1e-2
    assert result["max_param_diff"] < 5e-2  # bf16 + collective reduction order
