"""Chaos suite: seeded fault injection against the full serving stack, and
the engine-wide invariant auditor.

The acceptance property (ISSUE 8): under a randomized-but-seeded fault
schedule — allocator exhaustion, host-tier put/get failures, mid-flight
cancellations, one NaN-poisoned slot, one corrupted packed block — every
request ends in a terminal status (nothing hangs, the engine never raises),
every surviving request's greedy output is token-identical to an unfaulted
run, and the auditor reports zero leaked or aliased blocks at drain.

The auditor itself is tested adversarially: planted leaks, aliases, and
dangling handles must each raise ``AuditError`` naming the violation.
"""
import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.cache.offload import HostBlockStore, HostStoreError
from repro.cache.paged import BlockAllocator
from repro.configs.base import ModelConfig
from repro.core.precision import KVTunerSchedule, PrecisionPair
from repro.models.registry import build_model
from repro.serving.audit import AuditError, audit_engine
from repro.serving.engine import ContinuousEngine, Request, RequestStatus
from repro.serving.faults import FaultInjector

jax.config.update("jax_platform_name", "cpu")

R = 8
CHUNK = 16


@pytest.fixture(scope="module")
def tiny_api():
    cfg = ModelConfig(name="chaos-tiny", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=61, q_chunk=16, kv_group_size=R)
    return build_model(cfg)


@pytest.fixture(scope="module")
def tiny_params(tiny_api):
    return tiny_api.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sched():
    return KVTunerSchedule.uniform(2, PrecisionPair(8, 4))


def _workload(n=10, seed=21):
    """Shared-template prompts + staggered arrivals: enough tier traffic
    (prefix sharing, spills, preemption) for every fault class to bite."""
    rng = np.random.default_rng(seed)
    tpls = [rng.integers(0, 61, 24) for _ in range(2)]
    return [Request(uid=i,
                    prompt=np.concatenate([tpls[i % 2],
                                           rng.integers(0, 61, 8)]),
                    max_new_tokens=10, arrival_step=2 * i, priority=i % 4)
            for i in range(n)]


def _engine(api, params, sched, **kw):
    kw.setdefault("prefill_chunk", CHUNK)
    kw.setdefault("prefix_cache", True)
    kw.setdefault("max_seq", 64)
    kw.setdefault("max_batch", 3)
    kw.setdefault("scheduler", "priority")
    kw.setdefault("host_blocks", 24)
    return ContinuousEngine(api, params, sched, **kw)


def _run(engine, reqs):
    for r in reqs:
        engine.submit(r)
    done = sorted(engine.run(), key=lambda r: r.uid)
    engine.alloc.assert_consistent()
    engine.audit()
    return done


@pytest.fixture(scope="module")
def reference(tiny_api, tiny_params, sched):
    done = _run(_engine(tiny_api, tiny_params, sched), _workload())
    assert all(r.status == RequestStatus.DONE for r in done)
    return {r.uid: list(r.output) for r in done}


def _check(done, reference, n=10):
    assert len(done) == n
    assert all(r.terminal for r in done)
    for r in done:
        if r.status == RequestStatus.DONE:
            assert list(r.output) == reference[r.uid], \
                f"survivor {r.uid} diverged"


# ======================================================= the auditor
class TestAuditor:
    def test_clean_engine_summary(self, tiny_api, tiny_params, sched,
                                  reference):
        eng = _engine(tiny_api, tiny_params, sched)
        _run(eng, _workload())
        s = audit_engine(eng)
        assert s["live_slots"] == 0 and s["swap_parked"] == 0
        assert s["device_blocks_live"] == s["prefix_device_nodes"]

    def test_detects_leaked_device_block(self, tiny_api, tiny_params,
                                         sched):
        eng = _engine(tiny_api, tiny_params, sched)
        _run(eng, _workload(n=2))
        eng.alloc.alloc(1)          # plant: allocated but unaccounted
        with pytest.raises(AuditError, match="leaked"):
            audit_engine(eng)

    def test_detects_aliased_device_block(self, tiny_api, tiny_params,
                                          sched):
        eng = _engine(tiny_api, tiny_params, sched)
        _run(eng, _workload(n=2))
        node = next(n for n in eng.prefix.iter_nodes() if n.on_device)
        eng.alloc.release([node.block])   # plant: tree ref dropped early
        with pytest.raises(AuditError, match="aliased|dangling"):
            audit_engine(eng)

    def test_detects_leaked_host_handle(self, tiny_api, tiny_params, sched):
        eng = _engine(tiny_api, tiny_params, sched)
        _run(eng, _workload(n=2))
        # plant a host entry no parked request / prefix node references
        eng.host._store[999] = [()]
        eng.host._refs[999] = 1
        with pytest.raises(AuditError, match="leaked"):
            audit_engine(eng)
        del eng.host._store[999], eng.host._refs[999]

    def test_detects_stale_page_table(self, tiny_api, tiny_params, sched):
        eng = _engine(tiny_api, tiny_params, sched)
        for r in _workload(n=2):
            eng.submit(r)
        # run a few ticks by bounding the budget via deadline-free manual
        # stepping: easiest is to corrupt after a full run with a live slot
        # faked back in
        done = eng.run()
        slot_req = done[0]
        eng._slots[0] = slot_req                  # fake a live slot...
        eng._slot_pages[0] = [3, 4]
        eng._pt[0, :2] = [3, 5]                   # ...whose mirror diverges
        with pytest.raises(AuditError):
            audit_engine(eng)
        eng._slots[0] = None
        eng._slot_pages[0] = []


# ============================================= injector unit behavior
class TestInjector:
    def test_validation(self):
        with pytest.raises(ValueError, match="p_alloc_fail"):
            FaultInjector(p_alloc_fail=1.5)

    def test_alloc_hook_budget(self):
        inj = FaultInjector(seed=1, p_alloc_fail=1.0, max_alloc_faults=2)
        alloc = BlockAllocator(8)
        alloc.fault_hook = inj._alloc_hook
        assert alloc.alloc(1) is None
        assert alloc.alloc(1) is None
        assert alloc.alloc(1) is not None      # budget exhausted: clean
        assert inj.alloc_faults == 2
        alloc.assert_consistent()

    def test_host_hooks(self):
        inj = FaultInjector(seed=1, p_host_put_fail=1.0, p_host_get_fail=1.0)
        store = HostBlockStore(capacity=4)
        store.fault_hook = inj._host_hook
        assert store.put_blocks([], []) == []  # empty put never faults
        with pytest.raises(HostStoreError):
            store.take_to_device([], [0], [1])
        assert inj.host_get_faults == 1

    def test_deterministic_across_runs(self, tiny_api, tiny_params, sched,
                                       reference):
        def one(seed):
            inj = FaultInjector(seed=seed, p_alloc_fail=0.1,
                                p_host_put_fail=0.3, cancel_at=[(5, 2)])
            eng = _engine(tiny_api, tiny_params, sched, faults=inj,
                          stall_ticks=40)
            done = _run(eng, _workload())
            return ([(r.uid, r.status, tuple(r.output)) for r in done],
                    inj.summary())
        a, b = one(7), one(7)
        assert a == b


# ==================================== single-fault-class engine behavior
def test_alloc_faults_token_identity(tiny_api, tiny_params, sched,
                                     reference):
    """Transient allocator exhaustion delays work but never corrupts it."""
    inj = FaultInjector(seed=3, p_alloc_fail=0.25, max_alloc_faults=12)
    done = _run(_engine(tiny_api, tiny_params, sched, faults=inj,
                        stall_ticks=60), _workload())
    assert inj.alloc_faults > 0
    _check(done, reference)
    assert sum(r.status == RequestStatus.DONE for r in done) == 10


def test_host_put_faults_recompute_fallback(tiny_api, tiny_params, sched,
                                            reference):
    """Failed swap-outs force the recompute/drop fallbacks; outputs hold."""
    pages = 64 // R + 1
    inj = FaultInjector(seed=4, p_host_put_fail=1.0)
    eng = _engine(tiny_api, tiny_params, sched, faults=inj,
                  num_blocks=1 + 3 * pages, stall_ticks=60)
    done = _run(eng, _workload())
    assert inj.host_put_faults > 0
    _check(done, reference)


def test_host_get_faults_chain_drop(tiny_api, tiny_params, sched,
                                    reference):
    """Failed swap-ins drop the unreachable chain / demote the parked
    request; survivors still match bitwise."""
    pages = 64 // R + 1
    inj = FaultInjector(seed=5, p_host_get_fail=0.5)
    eng = _engine(tiny_api, tiny_params, sched, faults=inj,
                  num_blocks=1 + 3 * pages, stall_ticks=60)
    done = _run(eng, _workload())
    _check(done, reference)


def test_corrupt_block_quarantines_one(tiny_api, tiny_params, sched,
                                       reference):
    """A NaN-corrupted packed block fails exactly its owner; co-scheduled
    slots never see it (select-masked attention + per-slot page tables)."""
    inj = FaultInjector(seed=6, corrupt_at=[7])
    eng = _engine(tiny_api, tiny_params, sched, faults=inj, guard_nan=True)
    done = _run(eng, _workload())
    assert inj.corruptions_fired == 1
    assert eng.stats.quarantined == 1
    (victim,) = [r for r in done if r.status == RequestStatus.FAILED]
    assert victim.uid in inj.corrupted_uids
    assert "non-finite" in victim.error
    _check(done, reference)
    assert sum(r.status == RequestStatus.DONE for r in done) == 9


def test_poisoned_logits_quarantine_only_that_slot(tiny_api, tiny_params,
                                                   sched, reference):
    inj = FaultInjector(seed=8, poison_at=[(6, 1), (9, 4)])
    eng = _engine(tiny_api, tiny_params, sched, faults=inj, guard_nan=True)
    done = _run(eng, _workload())
    assert inj.poisons_fired == 2
    assert eng.stats.quarantined == 2
    failed = {r.uid for r in done if r.status == RequestStatus.FAILED}
    assert failed == {1, 4}
    _check(done, reference)


def test_guard_nan_identity_when_unfaulted(tiny_api, tiny_params, sched,
                                           reference):
    """The guard's host-side argmax must be bitwise-neutral: an unfaulted
    guarded run reproduces the reference exactly."""
    done = _run(_engine(tiny_api, tiny_params, sched, guard_nan=True),
                _workload())
    assert {r.uid: list(r.output) for r in done} == reference


def test_guard_nan_config_validation(tiny_api, tiny_params, sched):
    for kw in (dict(decode_horizon=3), dict(speculate_k=2),
               dict(greedy=False)):
        with pytest.raises(ValueError, match="guard_nan"):
            _engine(tiny_api, tiny_params, sched, guard_nan=True, **kw)


# ============================================== the acceptance chaos run
def test_full_chaos_acceptance(tiny_api, tiny_params, sched, reference):
    """ISSUE 8 acceptance: randomized seeded fault schedule combining every
    class — allocator exhaustion, host put/get failures, mid-flight
    cancellations, one poisoned slot, one corrupted block — with the
    auditor running at EVERY host sync. Every request terminates, survivors
    are token-identical, the auditor finds zero leaks/aliases at drain."""
    inj = FaultInjector(seed=1234, p_alloc_fail=0.15, p_host_put_fail=0.3,
                        p_host_get_fail=0.3, cancel_at=[(4, 3), (11, 7)],
                        poison_at=[(6, 5)], corrupt_at=[9])
    pages = 64 // R + 1
    eng = _engine(tiny_api, tiny_params, sched, faults=inj, guard_nan=True,
                  audit=True, num_blocks=1 + 3 * pages, stall_ticks=40,
                  max_waiting=8)
    done = _run(eng, _workload())
    _check(done, reference)
    # no hang is implied by run() returning; now: full coverage + isolation
    s = inj.summary()
    assert s["alloc_faults"] > 0
    assert s["host_put_faults"] + s["host_get_faults"] > 0
    assert s["cancels_fired"] == 2
    assert s["poisons_fired"] == 1 and s["corruptions_fired"] == 1
    assert eng.stats.quarantined == 2       # poison + corruption, nobody else
    tc = eng.stats.terminal_counts
    assert tc["cancelled"] == 2 and tc["quarantined"] == 2
    assert sum(tc[k] for k in ("done", "cancelled", "timed_out", "shed",
                               "failed")) == 10
    assert eng.audit()["live_slots"] == 0
