"""Fused paged prefill kernel + batched admission: parity and identity suite.

Covers the flash-style ``qprefill_paged`` kernel (packed pool-block context
streaming + causal fp intra-chunk tile, one normalized launch) against the
dense gather oracle across ragged context lengths, empty context, trailing
partial groups, dead lanes, and q-tiling; the masked batched wave write
against the serial write path (bitwise); and the engine-level guarantees —
greedy outputs token-identical across kernel on/off × batched/serial
admission, with batched admission costing fewer device dispatches.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.codec import kv_modes
from repro.cache.paged import PagedKVPool
from repro.configs.base import ModelConfig
from repro.core.precision import (MODE_KIVI, MODE_PER_TOKEN, KVTunerSchedule,
                                  PrecisionPair)
from repro.kernels.qprefill import pick_block_q, qprefill_paged
from repro.models.registry import build_model
from repro.serving.engine import ContinuousEngine, Request

jax.config.update("jax_platform_name", "cpu")

R = 8


def _rand(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


def _mk_pool(pair, mode, b, hkv, d, r, n_blocks, seed=0):
    pp = PrecisionPair(*pair)
    pool = PagedKVPool.init(n_blocks, b, hkv, d, pp, mode, r,
                            dtype=jnp.float32)
    c = pool.codec
    kc, ks, kz = c.k.encode(_rand((n_blocks, hkv, r, d), seed))
    vc, vs, vz = c.v.encode(_rand((n_blocks, hkv, r, d), seed + 1))
    return dataclasses.replace(
        pool, k_codes=kc, k_scale=ks, k_zero=kz, v_codes=vc, v_scale=vs,
        v_zero=vz)


def _reference(q, pool, pt, n_ctx, k_ch, v_ch, n_chunk, g):
    """Masked softmax over [gathered ctx ; causal fp chunk] — the oracle.
    q [S, Hkv, C·G, D] with rows flattened chunk-position-major."""
    s, hkv, cg, d = q.shape
    c = k_ch.shape[2]
    kk, vv = pool.gather_dequant(pt, jnp.float32)
    kk = jnp.concatenate([kk, k_ch], axis=2)
    vv = jnp.concatenate([vv, v_ch], axis=2)
    s_ctx = pt.shape[1] * pool.group_size
    kidx = jnp.arange(s_ctx + c)
    qpos = jnp.arange(cg) // g
    valid = jnp.where(
        kidx[None, None, :] < s_ctx,
        kidx[None, None, :] < n_ctx[:, None, None],
        ((kidx[None, None, :] - s_ctx) <= qpos[None, :, None])
        & ((kidx[None, None, :] - s_ctx) < n_chunk[:, None, None]))
    valid = valid[:, None]
    scores = jnp.einsum("bhgd,bhsd->bhgs", q, kk) / jnp.sqrt(d)
    scores = jnp.where(valid, scores, -jnp.inf)
    probs = jnp.where(valid, jax.nn.softmax(scores, -1), 0.0)
    return jnp.einsum("bhgs,bhsd->bhgd", probs, vv)


def _run_kernel(q, pool, pt, n_ctx, k_ch, v_ch, n_chunk, **kw):
    k_mode, v_mode = kv_modes(pool.mode)
    return qprefill_paged(
        q, pool.k_codes, pool.k_scale, pool.k_zero, pool.v_codes,
        pool.v_scale, pool.v_zero, k_ch, v_ch, pt, n_ctx, n_chunk,
        k_bits=pool.k_bits, v_bits=pool.v_bits, k_mode=k_mode,
        v_mode=v_mode, group_size=pool.group_size, interpret=True, **kw)


# ============================================================ kernel parity
@pytest.mark.parametrize("pair,mode", [((8, 8), MODE_PER_TOKEN),
                                       ((4, 2), MODE_KIVI),
                                       ((16, 16), MODE_PER_TOKEN)])
def test_prefill_ragged_ctx_matches_reference(pair, mode):
    """Mixed live context lengths — full, partial, empty — with ragged
    chunk occupancy (incl. a trailing partial group and a dead lane), one
    launch, vs the dense gather oracle."""
    s, hkv, g, d, r, p, c = 4, 2, 4, 64, 32, 4, 64
    pool = _mk_pool(pair, mode, s, hkv, d, r, 1 + s * p, seed=7)
    pt = jnp.arange(1, 1 + s * p, dtype=jnp.int32).reshape(s, p)
    n_ctx = jnp.asarray([4 * r, 2 * r, 0, r], jnp.int32)
    n_chunk = jnp.asarray([c, c - 3, 0, 5], jnp.int32)
    q = _rand((s, hkv, c * g, d), seed=11)
    k_ch = _rand((s, hkv, c, d), seed=12)
    v_ch = _rand((s, hkv, c, d), seed=13)

    o = np.asarray(_run_kernel(q, pool, pt, n_ctx, k_ch, v_ch, n_chunk))
    ref = np.asarray(_reference(q, pool, pt, n_ctx, k_ch, v_ch, n_chunk, g))
    np.testing.assert_allclose(o[[0, 1, 3]], ref[[0, 1, 3]],
                               rtol=3e-5, atol=3e-5)
    # dead lane: nothing attended, exact zeros out
    np.testing.assert_array_equal(o[2], 0.0)


def test_prefill_empty_context_all_slots():
    """First chunk of every request: zero live context blocks — the grid
    collapses to the intra-chunk step only."""
    s, hkv, g, d, r, p, c = 2, 2, 2, 64, 32, 3, 32
    pool = _mk_pool((4, 4), MODE_PER_TOKEN, s, hkv, d, r, 1 + s * p, seed=3)
    pt = jnp.arange(1, 1 + s * p, dtype=jnp.int32).reshape(s, p)
    n_ctx = jnp.zeros((s,), jnp.int32)
    n_chunk = jnp.asarray([c, c - 7], jnp.int32)
    q = _rand((s, hkv, c * g, d), seed=5)
    k_ch = _rand((s, hkv, c, d), seed=6)
    v_ch = _rand((s, hkv, c, d), seed=7)
    o = _run_kernel(q, pool, pt, n_ctx, k_ch, v_ch, n_chunk)
    ref = _reference(q, pool, pt, n_ctx, k_ch, v_ch, n_chunk, g)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_prefill_q_tiling_matches_untiled():
    """Forcing multiple q tiles (block_q < C·G) must not change anything —
    each tile carries its own online-softmax state."""
    s, hkv, g, d, r, p, c = 2, 2, 4, 64, 32, 3, 64
    pool = _mk_pool((8, 4), MODE_KIVI, s, hkv, d, r, 1 + s * p, seed=9)
    pt = jnp.arange(1, 1 + s * p, dtype=jnp.int32).reshape(s, p)
    n_ctx = jnp.asarray([3 * r, r], jnp.int32)
    n_chunk = jnp.asarray([c, 17], jnp.int32)
    q = _rand((s, hkv, c * g, d), seed=13)
    k_ch = _rand((s, hkv, c, d), seed=14)
    v_ch = _rand((s, hkv, c, d), seed=15)
    wide = _run_kernel(q, pool, pt, n_ctx, k_ch, v_ch, n_chunk)
    tiled = _run_kernel(q, pool, pt, n_ctx, k_ch, v_ch, n_chunk, block_q=64)
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(wide),
                               rtol=3e-5, atol=3e-5)
    ref = _reference(q, pool, pt, n_ctx, k_ch, v_ch, n_chunk, g)
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_prefill_ignores_garbage_past_live_ctx():
    """Page-table entries past a slot's live context must not affect its
    output (out-of-range grid steps alias the last live block and are
    compute-skipped) — the work-proportionality safety property."""
    s, hkv, g, d, r, p, c = 2, 2, 2, 64, 32, 4, 32
    pool = _mk_pool((4, 4), MODE_PER_TOKEN, s, hkv, d, r, 1 + s * p, seed=31)
    n_ctx = jnp.asarray([2 * r, r], jnp.int32)
    n_chunk = jnp.asarray([c, c - 5], jnp.int32)
    q = _rand((s, hkv, c * g, d), seed=33)
    k_ch = _rand((s, hkv, c, d), seed=34)
    v_ch = _rand((s, hkv, c, d), seed=35)
    pt_a = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    pt_b = jnp.asarray([[1, 2, 8, 7], [5, 1, 2, 3]], jnp.int32)  # junk tail
    o_a = _run_kernel(q, pool, pt_a, n_ctx, k_ch, v_ch, n_chunk)
    o_b = _run_kernel(q, pool, pt_b, n_ctx, k_ch, v_ch, n_chunk)
    np.testing.assert_array_equal(np.asarray(o_a), np.asarray(o_b))


def test_pick_block_q():
    assert pick_block_q(256, 256, 4) == 256
    assert pick_block_q(256, 100, 4) == 64
    assert pick_block_q(24, 256, 4) == 24
    assert pick_block_q(8, 2, 4) == 4
    with pytest.raises(ValueError):
        pick_block_q(10, 8, 4)


# ============================================================== wave writes
def test_write_wave_matches_serial_writes_bitwise():
    """The masked batched wave write must produce bitwise the blocks and
    residuals that the serial write_prefill_groups + write_residual path
    does, and leave dead lanes untouched."""
    hkv, d, r, p = 2, 16, 8, 4
    pool = _mk_pool((4, 2), MODE_KIVI, 3, hkv, d, r, 1 + 3 * p, seed=41)
    pt = jnp.arange(1, 1 + 3 * p, dtype=jnp.int32).reshape(3, p)
    c = 2 * r
    k = _rand((3, hkv, c, d), seed=42)
    v = _rand((3, hkv, c, d), seed=43)
    ctx = jnp.asarray([r, 0, 0], jnp.int32)
    clen = jnp.asarray([c, r + 3, 0], jnp.int32)  # full / partial / dead

    batched = pool.write_wave(k, v, pt, ctx, clen)

    serial = pool
    # slot 0: ctx 1 group, chunk 2 full groups → blocks pt[0, 1:3]
    serial = serial.write_prefill_groups(k[0:1], v[0:1], pt[0, 1:3])
    # slot 1: 1 full group → pt[1, 0:1], 3-token tail → residual
    serial = serial.write_prefill_groups(k[1:2, :, :r], v[1:2, :, :r],
                                         pt[1, 0:1])
    serial = serial.write_residual(jnp.int32(1), k[1:2, :, r:r + 3],
                                   v[1:2, :, r:r + 3])

    for name in ("k_codes", "k_scale", "k_zero", "v_codes", "v_scale",
                 "v_zero"):
        b_arr = np.asarray(getattr(batched, name))
        s_arr = np.asarray(getattr(serial, name))
        if b_arr.ndim > 1:  # skip scratch block 0 (write-order dependent)
            b_arr, s_arr = b_arr[1:], s_arr[1:]
        np.testing.assert_array_equal(b_arr, s_arr, err_msg=name)
    np.testing.assert_array_equal(np.asarray(batched.k_res[1, :, :3]),
                                  np.asarray(serial.k_res[1, :, :3]))
    # dead lane 2 and untouched tails keep their original residuals
    np.testing.assert_array_equal(np.asarray(batched.k_res[2]),
                                  np.asarray(pool.k_res[2]))
    np.testing.assert_array_equal(np.asarray(batched.v_res[0]),
                                  np.asarray(pool.v_res[0]))


def test_write_wave_rejects_unaligned_chunk():
    pool = _mk_pool((8, 8), MODE_PER_TOKEN, 2, 2, 16, 8, 9, seed=1)
    pt = jnp.zeros((2, 4), jnp.int32)
    bad = _rand((2, 2, 12, 16))  # 12 % 8 != 0
    with pytest.raises(ValueError, match="multiple"):
        pool.write_wave(bad, bad, pt, jnp.zeros(2, jnp.int32),
                        jnp.zeros(2, jnp.int32))


def test_prefill_stream_bytes_tracks_live_context():
    pool = PagedKVPool.init(65, 4, 2, 32, PrecisionPair(4, 4),
                            MODE_PER_TOKEN, 8)
    b25 = pool.prefill_stream_bytes([2 * 8] * 4, chunk=16)
    b50 = pool.prefill_stream_bytes([4 * 8] * 4, chunk=16)
    b100 = pool.prefill_stream_bytes([8 * 8] * 4, chunk=16)
    assert b25 < b50 < b100
    # a zero-context slot still counts one aliased block + its chunk tile
    assert pool.prefill_stream_bytes([0] * 4, chunk=16) \
        == pool.prefill_stream_bytes([8] * 4, chunk=16)
    # every q tile re-streams the context and chunk tiles
    assert pool.prefill_stream_bytes([4 * 8] * 4, chunk=16, q_tiles=2) \
        == 2 * b50


# ==================================================== decode ref clamping
def test_decode_reference_clamps_gather_to_live_pages():
    """Eager (concrete-length) calls of the XLA paged decode path gather
    only the batch's live pages; output must match the jitted full-width
    gather."""
    from repro.models import attention

    cfg = ModelConfig(name="clamp-tiny", family="dense", num_layers=1,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=61, q_chunk=16, kv_group_size=R)
    from repro.models.transformer import layer_params_at

    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    p = layer_params_at(params, cfg, 0)

    pool = _mk_pool((8, 4), MODE_KIVI, 2, 2, cfg.head_dim, R, 17, seed=51)
    pt = jnp.arange(1, 17, dtype=jnp.int32).reshape(2, 8)
    lengths = jnp.asarray([2 * R, R + 3], jnp.int32)  # max 2 live pages of 8
    alive = jnp.asarray([True, True])
    x = _rand((2, 1, cfg.d_model), seed=52)

    out_eager, _ = attention.paged_decode_attention(
        p["attn"], cfg, x, pool, pt, lengths, alive, cfg.rope_theta)
    jitted = jax.jit(lambda x_, pool_, pt_, ln, al: attention.
                     paged_decode_attention(p["attn"], cfg, x_, pool_, pt_,
                                            ln, al, cfg.rope_theta))
    out_full, _ = jitted(x, pool, pt, lengths, alive)
    np.testing.assert_allclose(np.asarray(out_eager), np.asarray(out_full),
                               rtol=1e-5, atol=1e-5)


# ========================================================= engine identity
@pytest.fixture(scope="module")
def tiny_api():
    cfg = ModelConfig(name="qprefill-tiny", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=61, q_chunk=16, kv_group_size=R)
    return build_model(cfg)


@pytest.fixture(scope="module")
def tiny_params(tiny_api):
    return tiny_api.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sched():
    return KVTunerSchedule.uniform(2, PrecisionPair(8, 4))


def _engine_outputs(api, params, sched, prompts, max_new=5, arrivals=None,
                    **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 48)
    kw.setdefault("prefill_paged", True)
    eng = ContinuousEngine(api, params, sched, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=np.asarray(p), max_new_tokens=max_new,
                           arrival_step=0 if arrivals is None else arrivals[i]))
    done = sorted(eng.run(), key=lambda r: r.uid)
    return [r.output for r in done], eng


def test_batched_vs_serial_admission_identity(tiny_api, tiny_params, sched):
    """4-request burst with ragged prompt lengths: greedy outputs must be
    token-identical across kernel on/off × batched/serial admission, and
    batched admission must cost fewer prefill dispatches."""
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, 61, n) for n in (12, 7, 19, 9)]
    base, ref_eng = _engine_outputs(tiny_api, tiny_params, sched, prompts)
    assert ref_eng.stats.prefill_dispatches == 4  # serial: one per request
    for kw in ({"use_pallas": True},
               {"batched_admission": True},
               {"batched_admission": True, "use_pallas": True}):
        out, eng = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                                   **kw)
        assert out == base, kw
        assert eng.alloc.free_blocks == eng.num_blocks - 1
        if kw.get("batched_admission"):
            # longest suffix 19 tokens, chunk R=8 → 3 waves for the burst
            assert eng.stats.prefill_dispatches == 3


def test_batched_admission_single_wave_burst(tiny_api, tiny_params, sched):
    """Prompts that fit one chunk: the whole burst admits in ONE dispatch
    (>= 4x fewer than serial)."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 61, 12) for _ in range(4)]
    _, serial = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                                prefill_chunk=2 * R)
    _, batched = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                                 prefill_chunk=2 * R,
                                 batched_admission=True)
    assert serial.stats.prefill_dispatches == 4
    assert batched.stats.prefill_dispatches == 1


def test_batched_admission_with_arrivals(tiny_api, tiny_params, sched):
    """Requests arriving at different steps form bursts per sync point;
    outputs stay identical to the serial engine."""
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, 61, n) for n in (8, 8, 16, 10)]
    arrivals = [0, 0, 3, 3]
    ref, _ = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                             arrivals=arrivals)
    out, eng = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                               arrivals=arrivals, batched_admission=True,
                               use_pallas=True)
    assert out == ref
    assert eng.alloc.free_blocks == eng.num_blocks - 1


def test_batched_admission_with_slot_contention(tiny_api, tiny_params,
                                                sched):
    """More requests than slots: later admissions join bursts mid-decode
    (live decode lanes ride through the wave masked); outputs identical."""
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, 61, n) for n in (9, 14, 11, 7, 12)]
    ref, _ = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                             max_batch=2)
    out, eng = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                               max_batch=2, batched_admission=True,
                               use_pallas=True)
    assert out == ref
    assert eng.alloc.free_blocks == eng.num_blocks - 1


def test_prefix_cache_identity_with_kernel(tiny_api, tiny_params, sched):
    """Prefix-cached serving stays token-identical with the fused prefill
    kernel on or off (and still hits the cache)."""
    rng = np.random.default_rng(10)
    tpl = rng.integers(0, 61, 16)
    prompts = [np.concatenate([tpl, rng.integers(0, 61, 4 + i)])
               for i in range(4)]
    # max_batch=2: admissions span several ticks, so later bursts can hit
    # prefixes inserted by earlier ones (same-burst members never share —
    # the tree is updated at burst end)
    base, _ = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                              prefill_chunk=2 * R, max_batch=2)
    for kw in ({"prefix_cache": True},
               {"prefix_cache": True, "use_pallas": True},
               {"prefix_cache": True, "use_pallas": True,
                "batched_admission": True}):
        out, eng = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                                   prefill_chunk=2 * R, max_batch=2, **kw)
        assert out == base, kw
        assert eng.stats.prefix_hits > 0


def test_horizon_composes_with_batched_admission(tiny_api, tiny_params,
                                                 sched):
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, 61, n) for n in (10, 13, 9)]
    ref, _ = _engine_outputs(tiny_api, tiny_params, sched, prompts)
    out, _ = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                             batched_admission=True, decode_horizon=3,
                             use_pallas=True)
    assert out == ref


def test_batched_admission_instant_finish_frees_slot(tiny_api, tiny_params,
                                                     sched):
    """max_new_tokens=1: every burst member finishes at admission. The
    freed slot must be re-collected for waiting requests instead of
    stalling (the serial path's rolling loop behavior)."""
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, 61, 9) for _ in range(3)]
    ref, _ = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                             max_new=1, max_batch=1)
    out, eng = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                               max_new=1, max_batch=1,
                               batched_admission=True)
    assert out == ref and all(len(o) == 1 for o in out)
    assert eng.alloc.free_blocks == eng.num_blocks - 1


def test_batched_admission_implies_prefill_paged(tiny_api, tiny_params,
                                                 sched):
    eng = ContinuousEngine(tiny_api, tiny_params, sched,
                           batched_admission=True)
    assert eng.prefill_paged


def test_prefill_stats_populated(tiny_api, tiny_params, sched):
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, 61, 10) for _ in range(3)]
    _, eng = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                             batched_admission=True)
    st = eng.stats
    assert st.prefill_dispatches > 0
    assert len(st.prefill_wall_times) == st.prefill_dispatches
    assert len(st.admit_latency_times) == st.admitted == 3
    assert st.prefill_p95_ms >= st.prefill_p50_ms > 0.0
    assert st.admit_p95_ms >= st.admit_p50_ms > 0.0
