"""Paged KV pool + continuous-batching engine tests.

Covers the paged-pool refactor end-to-end: codec extraction, block
adopt/append/gather parity with the dense ``LayerKVCache``, the
scalar-prefetch Pallas kernel, and the continuous engine's scheduling
behavior (mixed prompt lengths, mid-stream admission after an early EOS,
per-request equivalence with the single-request path, single decode compile).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.codec import KVCodec
from repro.cache.kvcache import LayerKVCache
from repro.cache.paged import SCRATCH_BLOCK, BlockAllocator, PagedKVPool
from repro.configs.base import ModelConfig
from repro.core import quant
from repro.core.precision import (MODE_KIVI, MODE_PER_TOKEN, KVTunerSchedule,
                                  PrecisionPair)
from repro.models.registry import build_model
from repro.serving.engine import ContinuousEngine, Request, ServeEngine

jax.config.update("jax_platform_name", "cpu")

R = 8  # small quant group → frequent flushes in few decode steps


def _rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@pytest.fixture(scope="module")
def tiny_api():
    cfg = ModelConfig(name="paged-tiny", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=61, q_chunk=16, kv_group_size=R)
    return build_model(cfg)


@pytest.fixture(scope="module")
def tiny_params(tiny_api):
    return tiny_api.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sched():
    return KVTunerSchedule.uniform(2, PrecisionPair(8, 4))


def _requests(prompts, max_new=6, eos_id=None, arrivals=None):
    return [Request(uid=i, prompt=np.asarray(p), max_new_tokens=max_new,
                    eos_id=eos_id,
                    arrival_step=0 if arrivals is None else arrivals[i])
            for i, p in enumerate(prompts)]


def _run(engine, reqs):
    for r in reqs:
        engine.submit(r)
    return sorted(engine.run(), key=lambda r: r.uid)


# ===================================================================== codec
def test_codec_roundtrip_matches_fake_quant():
    codec = KVCodec.make(PrecisionPair(4, 2), MODE_KIVI, R, 32)
    x = _rand((3, 2, 4 * R, 32), seed=1)
    for seg, bits, mode in ((codec.k, 4, codec.k.mode),
                            (codec.v, 2, codec.v.mode)):
        c, s, z = seg.encode(x)
        deq = seg.decode(c, s, z, jnp.float32)
        fq = quant.fake_quant(x, bits, mode, R)
        np.testing.assert_allclose(np.asarray(deq), np.asarray(fq),
                                   rtol=1e-5, atol=1e-5)


# ================================================================= allocator
def test_block_allocator():
    a = BlockAllocator(8)  # blocks 1..7 usable
    assert a.free_blocks == 7
    x = a.alloc(3)
    y = a.alloc(4)
    assert len(x) == 3 and len(y) == 4 and not (set(x) & set(y))
    assert SCRATCH_BLOCK not in x + y
    assert a.alloc(1) is None  # exhausted, not an exception
    a.release(x)
    assert a.free_blocks == 3
    assert a.alloc(3) is not None
    with pytest.raises(ValueError):
        a.release([0])  # the scratch block is never allocatable


# ============================================================ pool vs dense
@pytest.mark.parametrize("pair,mode", [((8, 8), MODE_PER_TOKEN),
                                       ((4, 2), MODE_KIVI),
                                       ((16, 16), MODE_PER_TOKEN)])
def test_adopt_and_append_match_dense(pair, mode):
    """Two slots at different lengths: prefill-adopt + batched appends must
    reproduce each slot's dense per-request cache bit-for-bit."""
    hkv, d = 2, 16
    pp = PrecisionPair(*pair)
    pool = PagedKVPool.init(9, 2, hkv, d, pp, mode, R, dtype=jnp.float32)
    pages = [[1, 2, 3], [4, 5, 6]]
    pt = np.zeros((2, 4), np.int32)
    for s_, pg in enumerate(pages):
        pt[s_, :len(pg)] = pg
    pt = jnp.asarray(pt)

    lens = [13, 7]
    dense = []
    for s_, ln in enumerate(lens):
        k = _rand((1, hkv, ln, d), seed=10 + s_)
        v = _rand((1, hkv, ln, d), seed=20 + s_)
        c = LayerKVCache.init(1, hkv, d, 32, pp, mode, R,
                              dtype=jnp.float32).fill(k, v)
        dense.append(c)
        n_groups = ln // R
        pool = pool.adopt_prefill(c, jnp.int32(s_),
                                  jnp.asarray(pages[s_][:n_groups], jnp.int32))

    lengths = jnp.asarray(lens, jnp.int32)
    alive = jnp.ones((2,), bool)
    for step in range(10):
        k_new = _rand((2, hkv, 1, d), seed=100 + step)
        v_new = _rand((2, hkv, 1, d), seed=200 + step)
        pool = pool.append(k_new, v_new, lengths, alive, pt)
        dense = [c.append(k_new[s_:s_ + 1], v_new[s_:s_ + 1])
                 for s_, c in enumerate(dense)]
        lengths = lengths + 1

    kg, vg = pool.gather_dequant(pt, jnp.float32)   # [2, hkv, 4R, d]
    for s_, c in enumerate(dense):
        k_all, v_all, valid = c.dequant(jnp.float32)
        ln = int(lengths[s_])
        n_main = ln // R * R
        n_res = ln - n_main
        np.testing.assert_allclose(np.asarray(kg[s_, :, :n_main]),
                                   np.asarray(k_all[0, :, :n_main]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(vg[s_, :, :n_main]),
                                   np.asarray(v_all[0, :, :n_main]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(pool.k_res[s_, :, :n_res]),
            np.asarray(c.k_res[0, :, :n_res]), rtol=1e-6, atol=1e-6)


def test_dead_slot_flush_lands_in_scratch_block():
    """A dead slot's (masked) flush must not touch any real block."""
    hkv, d = 2, 16
    pool = PagedKVPool.init(4, 2, hkv, d, PrecisionPair(8, 8), MODE_PER_TOKEN,
                            R, dtype=jnp.float32)
    pt = jnp.asarray([[1, 2], [3, 0]], jnp.int32)
    before = np.asarray(pool.k_codes[1:])
    # slot 0 dead at a would-be flush boundary; slot 1 alive mid-group
    lengths = jnp.asarray([R - 1, 2], jnp.int32)
    alive = jnp.asarray([False, True])
    pool = pool.append(_rand((2, hkv, 1, d)), _rand((2, hkv, 1, d), 1),
                       lengths, alive, pt)
    after = np.asarray(pool.k_codes[1:])
    np.testing.assert_array_equal(before, after)


# ============================================================== paged kernel
@pytest.mark.parametrize("pair,mode", [((8, 8), MODE_PER_TOKEN),
                                       ((4, 2), MODE_KIVI),
                                       ((16, 8), MODE_KIVI)])
def test_qdecode_paged_matches_gather(pair, mode):
    from repro.cache.codec import kv_modes
    from repro.kernels.qdecode import qdecode_paged

    b, hkv, g, d, r, n_blocks, p = 2, 2, 4, 64, 32, 7, 3
    pp = PrecisionPair(*pair)
    pool = PagedKVPool.init(n_blocks, b, hkv, d, pp, mode, r,
                            dtype=jnp.float32)
    c = pool.codec
    k = _rand((n_blocks, hkv, r, d), seed=0)
    v = _rand((n_blocks, hkv, r, d), seed=1)
    kc, ks, kz = c.k.encode(k)
    vc, vs, vz = c.v.encode(v)
    pool = dataclasses.replace(pool, k_codes=kc, k_scale=ks, k_zero=kz,
                               v_codes=vc, v_scale=vs, v_zero=vz)
    pt = jnp.asarray([[1, 4, 2], [5, 3, 6]], jnp.int32)
    n_valid = jnp.asarray([3 * r, 2 * r], jnp.int32)
    n_res = jnp.zeros((b,), jnp.int32)  # empty residual: main segment only
    q = _rand((b, hkv, g, d), seed=2)
    k_mode, v_mode = kv_modes(mode)
    o = qdecode_paged(q, kc, ks, kz, vc, vs, vz, pool.k_res, pool.v_res,
                      pt, n_valid, n_res, k_bits=pp.k_bits, v_bits=pp.v_bits,
                      k_mode=k_mode, v_mode=v_mode, group_size=r,
                      interpret=True)
    kk, vv = pool.gather_dequant(pt, jnp.float32)
    scores = jnp.einsum("bhgd,bhsd->bhgs", q, kk) / jnp.sqrt(d)
    mask = (jnp.arange(p * r)[None, :] < n_valid[:, None])[:, None, None, :]
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, -1)
    ref = jnp.einsum("bhgs,bhsd->bhgd", probs, vv)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


# ============================================================== engine tests
def test_engine_mixed_prompt_lengths_match_wave(tiny_api, tiny_params, sched):
    """Mixed prompt lengths in ONE continuous batch: greedy outputs must be
    token-identical to the wave engine (which buckets by exact length)."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 61, n) for n in (12, 7, 19, 12, 25)]
    wave = _run(ServeEngine(tiny_api, tiny_params, sched, max_batch=3),
                _requests(prompts))
    eng = ContinuousEngine(tiny_api, tiny_params, sched, max_batch=3,
                           max_seq=40)
    cont = _run(eng, _requests(prompts))
    assert [r.output for r in cont] == [r.output for r in wave]
    assert eng.decode_compilations == 1
    assert eng.stats.admitted == 5
    # all blocks recycled once the queue drains
    assert eng.alloc.free_blocks == eng.num_blocks - 1


def test_engine_matches_single_request_path(tiny_api, tiny_params, sched):
    """Per-request output equivalence: each request decoded alone (batch=1)
    must equal its output from the shared continuous batch."""
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 61, n) for n in (9, 17, 13)]
    eng = ContinuousEngine(tiny_api, tiny_params, sched, max_batch=3,
                           max_seq=32)
    batched = _run(eng, _requests(prompts, max_new=5))
    for i, p in enumerate(prompts):
        solo_eng = ContinuousEngine(tiny_api, tiny_params, sched, max_batch=1,
                                    max_seq=32)
        solo = _run(solo_eng, [Request(uid=0, prompt=np.asarray(p),
                                       max_new_tokens=5)])
        assert solo[0].output == batched[i].output, f"request {i} diverged"


def test_mid_stream_admission_after_early_eos(tiny_api, tiny_params, sched):
    """A request hitting EOS early frees its slot mid-decode; the queued
    request is admitted into it and still decodes correctly."""
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 61, 11) for _ in range(4)]
    # dry run (no EOS) to learn outputs, then pick request 0's 2nd token as
    # the EOS id → request 0 finishes after 2 tokens, freeing its slot while
    # others are mid-decode.
    dry = _run(ContinuousEngine(tiny_api, tiny_params, sched, max_batch=2,
                                max_seq=32), _requests(prompts, max_new=8))
    eos = dry[0].output[1]

    def truncate(out):
        return out[:out.index(eos) + 1] if eos in out else out

    eng = ContinuousEngine(tiny_api, tiny_params, sched, max_batch=2,
                           max_seq=32)
    done = _run(eng, _requests(prompts, max_new=8, eos_id=eos))
    assert len(done) == 4 and all(r.done for r in done)
    assert done[0].output == truncate(dry[0].output)
    assert len(done[0].output) == 2
    for i in range(1, 4):
        assert done[i].output == truncate(dry[i].output), f"request {i}"
    # with max_batch=2 and 4 requests, at least two admissions were mid-run
    assert eng.stats.admitted == 4
    assert eng.decode_compilations == 1
    assert eng.alloc.free_blocks == eng.num_blocks - 1


def test_poisson_arrivals_respected(tiny_api, tiny_params, sched):
    """arrival_step delays visibility: a request arriving at step k must not
    shorten earlier requests' outputs, and all requests still complete."""
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 61, n) for n in (8, 8, 16)]
    eng = ContinuousEngine(tiny_api, tiny_params, sched, max_batch=2,
                           max_seq=32)
    done = _run(eng, _requests(prompts, max_new=4, arrivals=[0, 3, 6]))
    assert len(done) == 3 and all(len(r.output) == 4 for r in done)
    ref = _run(ContinuousEngine(tiny_api, tiny_params, sched, max_batch=2,
                                max_seq=32), _requests(prompts, max_new=4))
    assert [r.output for r in done] == [r.output for r in ref]


def test_engine_pool_pressure_queues_requests(tiny_api, tiny_params, sched):
    """With a pool too small for all requests at once, admission stalls until
    blocks free up — and every request still completes correctly."""
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, 61, 16) for _ in range(4)]
    # each request needs (16+4)//8 + 1 = 3 blocks; pool of 7 fits 2 at a time
    eng = ContinuousEngine(tiny_api, tiny_params, sched, max_batch=4,
                           max_seq=24, num_blocks=7)
    done = _run(eng, _requests(prompts, max_new=4))
    ref = _run(ContinuousEngine(tiny_api, tiny_params, sched, max_batch=4,
                                max_seq=24), _requests(prompts, max_new=4))
    assert [r.output for r in done] == [r.output for r in ref]
    assert eng.alloc.free_blocks == 6


def test_engine_rejects_oversized_request(tiny_api, tiny_params, sched):
    eng = ContinuousEngine(tiny_api, tiny_params, sched, max_batch=2,
                           max_seq=16)
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, prompt=np.zeros(64, np.int64),
                           max_new_tokens=4))


def test_engine_pallas_path_matches_xla(tiny_api, tiny_params):
    sched = KVTunerSchedule.uniform(2, PrecisionPair(4, 2), mode=MODE_KIVI)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 61, n) for n in (12, 7, 19)]
    outs = {}
    for up in (False, True):
        eng = ContinuousEngine(tiny_api, tiny_params, sched, max_batch=3,
                               max_seq=32, use_pallas=up)
        outs[up] = [r.output for r in _run(eng, _requests(prompts, max_new=4))]
    assert outs[False] == outs[True]
