"""Substrate tests: optimizer, data pipeline, checkpointing (incl. crash
safety + reshard-on-load), fault-tolerant trainer, gradient compression,
serving engine, HLO analyzer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.core.precision import KVTunerSchedule, PrecisionPair
from repro.data import synthetic
from repro.data.pipeline import MemmapSource, Prefetcher, SyntheticSource, \
    write_token_corpus
from repro.models.registry import build_model
from repro.serving.engine import Request, ServeEngine, generate
from repro.training import grad_compress
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.trainer import Trainer
from repro.utils import hlo

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def tiny_api():
    cfg = ModelConfig(name="sub-tiny", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=61, q_chunk=16)
    return build_model(cfg)


# ---------------------------------------------------------------- optimizer
def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)


# --------------------------------------------------------------------- data
def test_synthetic_chain_arithmetic_consistent():
    task = synthetic.TaskConfig(chain_len=5, seq_len=48)
    batch = synthetic.chain_batch(task, 8, np.random.default_rng(0))
    toks, mask = batch["tokens"], batch["loss_mask"]
    for b in range(8):
        positions = np.where(mask[b] > 0)[0]
        val = toks[b][1]
        for p in positions:
            op, d = toks[b][p - 3], toks[b][p - 2]
            val = (val + d) % 10 if op == synthetic.PLUS else (val - d) % 10
            assert toks[b][p] == val  # running value correct at each '='


def test_stateless_source_deterministic():
    task = synthetic.TaskConfig()
    src = SyntheticSource(task=task, batch_size=4, seed=3)
    a, b = src.batch_at(7), src.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch_at(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_memmap_source_and_prefetcher(tmp_path):
    path = str(tmp_path / "corpus.bin")
    write_token_corpus(path, np.arange(10_000) % 97)
    src = MemmapSource(path=path, batch_size=4, seq_len=16, rank=0, world=2)
    batch = src.batch_at(0)
    assert batch["tokens"].shape == (2, 16)  # batch/world per rank
    np.testing.assert_array_equal(batch["labels"][:, :-1],
                                  batch["tokens"][:, 1:])
    pf = Prefetcher(src, start_step=5)
    step, b5 = next(iter(pf))
    assert step == 5
    np.testing.assert_array_equal(b5["tokens"], src.batch_at(5)["tokens"])
    pf.close()


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path, tiny_api):
    params = tiny_api.init(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (10, 20, 30):
        mgr.save(step, params, extra={"step": step})
    assert mgr.all_steps() == [20, 30]  # gc keeps 2
    restored, extra = mgr.restore(30, jax.eval_shape(lambda: params))
    assert extra["step"] == 30
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_uncommitted_ignored(tmp_path, tiny_api):
    params = tiny_api.init(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(10, params)
    # simulate a crash mid-save: step_20 exists without COMMITTED
    os.makedirs(tmp_path / "step_00000020")
    assert mgr.latest_step() == 10


def test_checkpoint_async_save(tmp_path, tiny_api):
    params = tiny_api.init(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, params, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


# ------------------------------------------------------------------ trainer
def _source(vocab):
    task = synthetic.TaskConfig(vocab_size=vocab, chain_len=4, seq_len=32)
    return SyntheticSource(task=task, batch_size=8, kind="chain", seed=0)


def test_trainer_loss_decreases(tiny_api):
    trainer = Trainer(api=tiny_api, optimizer=AdamW(lr=3e-3),
                      source=_source(61), log_every=20,
                      log_fn=lambda *a: None)
    state, hist = trainer.run(60)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_trainer_resume_from_checkpoint(tmp_path, tiny_api):
    mgr = CheckpointManager(str(tmp_path))
    mk = lambda: Trainer(api=tiny_api, optimizer=AdamW(lr=1e-3),
                         source=_source(61), ckpt=mgr, ckpt_every=10,
                         log_every=100, log_fn=lambda *a: None)
    mk().run(20)
    assert mgr.latest_step() == 20
    # second run resumes at 20 and continues to 30
    state, _ = mk().run(30)
    assert mgr.latest_step() == 30
    assert int(jax.device_get(state.opt.step)) == 30


def test_grad_compression_error_feedback(tiny_api):
    params = tiny_api.init(jax.random.PRNGKey(0))
    ef = grad_compress.init_error_feedback(params)
    grads = jax.tree.map(lambda p: 1e-3 * jnp.ones_like(p, jnp.float32),
                         params)
    total_comp = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    for _ in range(4):
        comp, ef = grad_compress.apply_error_feedback(grads, ef)
        total_comp = jax.tree.map(lambda a, b: a + b, total_comp, comp)
    # error feedback: accumulated compressed ≈ accumulated true gradient
    t = jax.tree.leaves(total_comp)[0]
    np.testing.assert_allclose(np.asarray(t), 4e-3, rtol=0.3)


def test_trainer_compressed_grads_still_learn(tiny_api):
    trainer = Trainer(api=tiny_api, optimizer=AdamW(lr=3e-3),
                      source=_source(61), compress_grads=True, log_every=20,
                      log_fn=lambda *a: None)
    state, hist = trainer.run(60)
    assert hist[-1]["loss"] < hist[0]["loss"]


# ------------------------------------------------------------------ serving
def test_engine_batches_and_completes(tiny_api):
    params = tiny_api.init(jax.random.PRNGKey(0))
    sched = KVTunerSchedule.uniform(2, PrecisionPair(8, 4))
    eng = ServeEngine(tiny_api, params, sched, max_batch=3)
    rng = np.random.default_rng(0)
    for i in range(5):  # 5 requests, bucket of 3 + 2
        eng.submit(Request(uid=i, prompt=rng.integers(0, 61, 12),
                           max_new_tokens=4))
    done = eng.run()
    assert len(done) == 5
    assert all(r.done and len(r.output) == 4 for r in done)
    assert eng.stats.generated_tokens == 20
    assert eng.stats.waves == 2


def test_generate_greedy_matches_forward_argmax(tiny_api):
    """First generated token == argmax of the full-forward next-token logits
    (bf16 cache ⇒ near-exact path equivalence)."""
    params = tiny_api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, 61, size=(2, 16))
    out, _ = generate(tiny_api, params, None, prompts, max_new_tokens=1)
    logits, _ = tiny_api.forward(params, {"tokens": jnp.asarray(prompts)})
    expect = np.asarray(jnp.argmax(logits[:, -1], -1))
    np.testing.assert_array_equal(out[:, 0], expect)


# ------------------------------------------------------------ HLO analyzer
def test_hlo_analyzer_scan_correction():
    """cost_analysis undercounts scan bodies; the analyzer must not."""
    def step(x, w):
        def body(c, w_):
            return jnp.tanh(c @ w_), ()
        return jax.lax.scan(body, x, w)[0]

    xs = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    comp = jax.jit(step).lower(xs, ws).compile()
    rep = hlo.analyze(comp.as_text())
    expect = 2 * 8 * 32 * 32 * 5
    assert rep.flops == pytest.approx(expect, rel=0.01)
    assert list(rep.while_trip_counts.values()) == [5]


def test_hlo_roofline_terms():
    rep = hlo.CostReport(flops=197e12, hbm_bytes=819e9)
    rep.collective_bytes["all-reduce"] = 50e9
    rl = hlo.roofline_terms(rep, model_flops_per_device=197e12 / 2)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(1.0)
    assert rl.collective_s == pytest.approx(1.0)
    assert rl.useful_flops_ratio == pytest.approx(0.5)


def test_hlo_shape_parsing():
    assert hlo._shape_bytes("bf16[4,8]{1,0}") == 64
    assert hlo._shape_bytes("(f32[2,2], s32[])") == 20
    assert hlo._shape_dims("bf16[3,5,7]{2,1,0}") == [3, 5, 7]
