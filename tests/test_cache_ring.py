"""LayerKVCache windowed-ring semantics.

Locks in the ring arithmetic (before/after the codec extraction): a
``fill(S > window)`` followed by ``append`` flushes must keep the physical
main-segment contents in agreement with ``token_positions()`` — every live
slot holds exactly the token whose absolute position the ring math reports.

Keys/values are tagged with their absolute position so the check is direct:
``dequant()[slot] == token_positions()[slot]``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.kvcache import LayerKVCache
from repro.core.precision import MODE_PER_TOKEN, PrecisionPair

jax.config.update("jax_platform_name", "cpu")

R = 8
D = 4
WINDOW = 32  # 4 ring groups


def _tagged(s0, s1):
    """[1, 1, s1-s0, D] keys whose every element equals the token position."""
    vals = jnp.arange(s0, s1, dtype=jnp.float32)
    return jnp.broadcast_to(vals[None, None, :, None],
                            (1, 1, s1 - s0, D)).astype(jnp.float32)


def _check_ring(cache: LayerKVCache):
    """Every flushed main slot and every live residual slot must hold the
    token its token_positions() entry claims."""
    k_all, _, valid = cache.dequant(jnp.float32)
    pos = np.asarray(cache.token_positions())
    vals = np.asarray(k_all[0, 0, :, 0])
    vmask = np.asarray(valid)
    length = int(cache.length)
    total_flushed = length // R * R

    for i in range(cache.s_cap):
        if pos[i] < total_flushed:  # this slot's occupant group has flushed
            assert vals[i] == pytest.approx(pos[i]), \
                f"main slot {i}: holds {vals[i]}, ring says {pos[i]}"
            # ring property: live slots only ever hold trailing-window tokens
            assert pos[i] >= total_flushed - cache.s_cap
    n_res = length - total_flushed
    for j in range(n_res):
        i = cache.s_cap + j
        assert vmask[i]
        assert pos[i] == total_flushed + j
        assert vals[i] == pytest.approx(pos[i]), \
            f"residual slot {j}: holds {vals[i]}, expected {pos[i]}"


@pytest.mark.parametrize("pair", [(16, 16), (8, 8)])
@pytest.mark.parametrize("fill_len", [52, 56, 37])
def test_windowed_fill_then_append_agrees_with_token_positions(pair, fill_len):
    cache = LayerKVCache.init(1, 1, D, 64, PrecisionPair(*pair),
                              MODE_PER_TOKEN, R, dtype=jnp.float32,
                              window=WINDOW)
    assert cache.s_cap == WINDOW  # capacity clamps to the window
    k = _tagged(0, fill_len)
    cache = cache.fill(k, k)
    assert int(cache.length) == fill_len
    _check_ring(cache)

    # decode appends across ≥ 2 flush boundaries, checking after every token
    for t in range(fill_len, fill_len + 2 * R + 3):
        tok = jnp.full((1, 1, 1, D), float(t), jnp.float32)
        cache = cache.append(tok, tok)
        assert int(cache.length) == t + 1
        _check_ring(cache)


def test_unwindowed_fill_append_positions_are_linear():
    """Control: without a window the ring must degenerate to the identity."""
    cache = LayerKVCache.init(1, 1, D, 40, PrecisionPair(16, 16),
                              MODE_PER_TOKEN, R, dtype=jnp.float32)
    cache = cache.fill(_tagged(0, 21), _tagged(0, 21))
    pos = np.asarray(cache.token_positions())
    np.testing.assert_array_equal(pos[:cache.s_cap], np.arange(cache.s_cap))
    _check_ring(cache)
    for t in range(21, 21 + R + 2):
        tok = jnp.full((1, 1, 1, D), float(t), jnp.float32)
        cache = cache.append(tok, tok)
        _check_ring(cache)
