"""Force 8 virtual CPU devices BEFORE the jax backend initializes, so
multi-device paths (the shard_map-sharded paged pool, ``ContinuousEngine``
with a mesh, SPMD parity) are testable in-process on any machine.

pytest imports conftest before any test module, and ``repro.launch.mesh``
keeps its no-device-state-at-import contract, so setting ``XLA_FLAGS`` here
is early enough. Single-device tests are unaffected: default placement is
still device 0, and an externally exported ``XLA_FLAGS`` with the flag
already present wins over this default.
"""
from repro.launch.mesh import force_host_device_count

force_host_device_count(8)
