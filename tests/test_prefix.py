"""Prefix-cached paged serving tests.

Covers the prefix-sharing refactor end-to-end: the refcount-aware block
allocator (double-free / still-referenced-free hardening), the radix-tree
prefix index (match / insert / COW fork / LRU eviction), chunked in-pool
prefill parity, and the engine-level guarantees — token-identical greedy
outputs with the cache on or off, shared-prefix admissions skipping prefill,
eviction under pool pressure, pool-exhaustion backpressure, and idle-tick
fast-forwarding to the next simulated arrival.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.paged import BlockAllocator
from repro.cache.prefix import PrefixCache
from repro.configs.base import ModelConfig
from repro.core.precision import KVTunerSchedule, PrecisionPair
from repro.models.registry import build_model
from repro.serving.engine import ContinuousEngine, Request

jax.config.update("jax_platform_name", "cpu")

R = 8        # tiny quant group → groups/flushes within a few tokens
CHUNK = 16   # prefill chunk (2 groups) → fine-grained prefix sharing


@pytest.fixture(scope="module")
def tiny_api():
    cfg = ModelConfig(name="prefix-tiny", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=61, q_chunk=16, kv_group_size=R)
    return build_model(cfg)


@pytest.fixture(scope="module")
def tiny_params(tiny_api):
    return tiny_api.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sched():
    return KVTunerSchedule.uniform(2, PrecisionPair(8, 4))


def _engine(api, params, sched, **kw):
    kw.setdefault("prefill_chunk", CHUNK)
    return ContinuousEngine(api, params, sched, **kw)


def _requests(prompts, max_new=5, eos_id=None, arrivals=None):
    return [Request(uid=i, prompt=np.asarray(p), max_new_tokens=max_new,
                    eos_id=eos_id,
                    arrival_step=0 if arrivals is None else arrivals[i])
            for i, p in enumerate(prompts)]


def _run(engine, reqs):
    for r in reqs:
        engine.submit(r)
    return sorted(engine.run(), key=lambda r: r.uid)


def _templated_prompts(n_templates=2, per_template=3, template_len=2 * CHUNK,
                       suffix_lens=(5, 9, 7), seed=0):
    rng = np.random.default_rng(seed)
    templates = [rng.integers(0, 61, template_len)
                 for _ in range(n_templates)]
    return [np.concatenate([t, rng.integers(0, 61, suffix_lens[j])])
            for t in templates for j in range(per_template)]


# ================================================== allocator refcounting
def test_allocator_double_free_raises():
    a = BlockAllocator(8)
    x = a.alloc(3)
    a.release(x)
    assert a.free_blocks == 7
    with pytest.raises(ValueError, match="double free"):
        a.release([x[0]])
    assert a.free_blocks == 7  # free list not corrupted by the bad call


def test_allocator_release_respects_refcounts():
    a = BlockAllocator(8)
    x = a.alloc(2)
    a.ref(x)                      # second owner (e.g. the prefix tree)
    a.release(x)                  # first owner drops out
    assert a.free_blocks == 5     # still referenced → still allocated
    assert all(a.refcount(b) == 1 for b in x)
    a.release(x)                  # last reference
    assert a.free_blocks == 7
    with pytest.raises(ValueError, match="unallocated"):
        a.ref([x[0]])             # pinning a free block is a bug


def test_allocator_rejects_bad_ids():
    a = BlockAllocator(4)
    with pytest.raises(ValueError):
        a.release([0])   # scratch block is never allocatable
    with pytest.raises(ValueError):
        a.release([4])   # out of range
    assert a.alloc(4) is None and a.alloc(3) is not None


# ===================================================== radix prefix index
def test_prefix_match_insert_and_fork():
    a = BlockAllocator(16)
    cache = PrefixCache(a, group_size=4)
    toks_a = np.arange(12)            # 3 groups
    blocks_a = a.alloc(3)
    assert cache.match(toks_a) == []
    cache.insert(toks_a, blocks_a)
    assert len(cache) == 3
    assert cache.match(toks_a) == blocks_a
    # prefix-only prompt matches its leading chain
    assert cache.match(toks_a[:8]) == blocks_a[:2]
    # COW fork: same first 2 groups, divergent third → sibling node
    toks_b = np.concatenate([toks_a[:8], [99, 98, 97, 96]])
    assert cache.match(toks_b) == blocks_a[:2]
    blocks_b = a.alloc(1)
    cache.insert(toks_b, blocks_a[:2] + blocks_b)
    assert len(cache) == 4
    assert cache.match(toks_b) == blocks_a[:2] + blocks_b
    assert cache.match(toks_a) == blocks_a  # original chain intact


def test_prefix_lru_eviction_leaf_first_and_pinning():
    a = BlockAllocator(16)
    cache = PrefixCache(a, group_size=4)
    old = np.arange(8)                # 2 groups, inserted first (colder)
    new = np.arange(8) + 20
    b_old, b_new = a.alloc(2), a.alloc(2)
    cache.insert(old, b_old)
    cache.insert(new, b_new)
    a.release(b_old)                  # requests finished: tree is sole owner
    a.release(b_new)
    cache.insert(old, b_old)          # re-use refresh → 'new' is now LRU
    assert cache.evict_lru() == 1
    assert cache.match(new) == b_new[:1]   # leaf of 'new' evicted first
    assert cache.match(old) == b_old       # refreshed chain untouched
    # a pinned chain (live request holds a ref) is never evicted
    a.ref(b_old)
    cache.match(new)                  # 'new' is fresher, but 'old' is pinned
    assert cache.evict_lru() == 1          # so 'new' drains instead
    assert cache.evict_lru() == 0          # only the pinned chain remains
    assert cache.match(old) == b_old
    a.release(b_old)
    assert cache.clear() == 2
    assert a.free_blocks == 15


def test_evict_refuses_doomed_requests_and_batches():
    """evict(need) frees exactly the deficit in one pass, and refuses when
    fewer blocks are evictable — a doomed allocation must not wipe the
    cache. Pinned subtrees block their whole chain."""
    a = BlockAllocator(16)
    cache = PrefixCache(a, group_size=4)
    chain = np.arange(12)             # 3 groups
    blocks = a.alloc(3)
    cache.insert(chain, blocks)
    a.release(blocks)                 # tree is sole owner
    assert cache.evict(5) == 0        # only 3 evictable → refuse, keep cache
    assert len(cache) == 3
    assert cache.evict(2) == 2        # partial chain trim, suffix-first
    assert cache.match(chain) == blocks[:1]
    # a pinned leaf makes every ancestor non-evictable
    tail = a.alloc(1)
    cache.insert(chain[:8], blocks[:1] + tail)
    a.ref(tail)                       # live request pins the leaf
    a.release(tail)
    assert cache.evict(1) == 0
    a.release(tail)                   # unpin
    assert cache.evict(2) == 2
    assert a.free_blocks == 15


# ============================================= engine: prefix-cached serving
def test_prefix_cache_outputs_identical_and_skips_prefill(tiny_api,
                                                          tiny_params, sched):
    """The acceptance property: greedy outputs token-identical with the
    cache on or off; admissions sharing a cached prefix skip prefill for the
    shared groups (hits > 0, fewer prefill tokens)."""
    prompts = _templated_prompts()
    outs = {}
    engines = {}
    for on in (False, True):
        eng = _engine(tiny_api, tiny_params, sched, max_batch=2, max_seq=48,
                      prefill_paged=True, prefix_cache=on)
        outs[on] = [r.output for r in _run(eng, _requests(prompts))]
        engines[on] = eng
    assert outs[True] == outs[False]
    on, off = engines[True].stats, engines[False].stats
    assert on.prefix_hits > 0
    assert on.prefix_hit_tokens > 0
    assert on.prefill_tokens < off.prefill_tokens
    assert on.prefill_tokens + on.prefix_hit_tokens == off.prefill_tokens
    assert on.generated_tokens == off.generated_tokens
    assert engines[True].decode_compilations == 1
    # finished requests release their refs; only the tree keeps blocks
    cached = len(engines[True].prefix)
    assert engines[True].alloc.free_blocks == \
        engines[True].num_blocks - 1 - cached


def test_identical_prompt_full_hit(tiny_api, tiny_params, sched):
    """Resubmitting an identical prompt prefills only the tail (the match is
    capped below the full prompt) and reproduces the same output."""
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, 61, 2 * CHUNK + 3)
    eng = _engine(tiny_api, tiny_params, sched, max_batch=1, max_seq=48,
                  prefix_cache=True)
    first = _run(eng, _requests([prompt], max_new=6))[0].output
    again = Request(uid=1, prompt=prompt, max_new_tokens=6)
    eng.submit(again)
    eng.run()
    assert again.output == first
    assert eng.stats.prefix_hits == 1
    # second admission prefilled only the 3-token tail past the shared chunks
    assert eng.stats.prefill_tokens == 2 * (2 * CHUNK + 3) - 2 * CHUNK


def test_eviction_under_pool_pressure(tiny_api, tiny_params, sched):
    """A pool too small to keep every template cached: LRU prefixes are
    evicted to admit new requests, and outputs stay correct."""
    prompts = _templated_prompts(n_templates=3, per_template=2,
                                 suffix_lens=(5, 9), seed=7)
    ref_eng = _engine(tiny_api, tiny_params, sched, max_batch=2, max_seq=48,
                      prefill_paged=True)
    ref = [r.output for r in _run(ref_eng, _requests(prompts))]
    # each request needs (37..41 + 5)//8 + 1 ≤ 6 blocks; 13 usable blocks
    # hold two live requests + barely one cached template (4 blocks)
    eng = _engine(tiny_api, tiny_params, sched, max_batch=2, max_seq=48,
                  num_blocks=14, prefix_cache=True)
    done = [r.output for r in _run(eng, _requests(prompts))]
    assert done == ref
    assert eng.stats.prefix_evicted_blocks > 0
    assert eng.alloc.free_blocks == \
        eng.num_blocks - 1 - len(eng.prefix)


def test_pool_exhaustion_backpressure(tiny_api, tiny_params, sched):
    """More concurrent demand than the pool holds: admission stalls, queued
    requests complete with correct outputs once blocks free (satellite:
    backpressure coverage, with staggered arrivals and prefix cache on)."""
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, 61, 16) for _ in range(6)]
    ref = [r.output for r in
           _run(_engine(tiny_api, tiny_params, sched, max_batch=6,
                        max_seq=24, prefill_paged=True),
                _requests(prompts, max_new=4))]
    for kw in ({"prefill_paged": True}, {"prefix_cache": True}):
        # each request needs (16+4)//8 + 1 = 3 blocks; 7 blocks fit 2 live
        eng = _engine(tiny_api, tiny_params, sched, max_batch=6, max_seq=24,
                      num_blocks=8, **kw)
        done = _run(eng, _requests(prompts, max_new=4,
                                   arrivals=[0, 0, 0, 2, 2, 5]))
        assert len(done) == 6 and all(r.done for r in done)
        assert [r.output for r in done] == ref, kw
        freeable = eng.num_blocks - 1 - \
            (len(eng.prefix) if eng.prefix is not None else 0)
        assert eng.alloc.free_blocks == freeable, kw


def test_engine_rejects_bad_prefill_chunk(tiny_api, tiny_params, sched):
    for bad in (0, 12):   # zero and non-multiple-of-R both refused loudly
        with pytest.raises(ValueError, match="prefill_chunk"):
            ContinuousEngine(tiny_api, tiny_params, sched, prefill_chunk=bad)


def test_idle_fast_forward_to_next_arrival(tiny_api, tiny_params, sched):
    """With no live slot, the engine jumps _step_count straight to the next
    pending arrival instead of ticking once per loop iteration."""
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, 61, 12) for _ in range(2)]
    eng = _engine(tiny_api, tiny_params, sched, max_batch=2, max_seq=32,
                  prefill_paged=True)
    done = _run(eng, _requests(prompts, max_new=3, arrivals=[10_000, 20_000]))
    assert all(len(r.output) == 3 for r in done)
    assert eng._step_count >= 20_000
    # only real decode work ran: 2 admissions × ≤2 decode steps each
    assert eng.stats.decode_steps <= 4
    ref = _run(_engine(tiny_api, tiny_params, sched, max_batch=2, max_seq=32,
                       prefill_paged=True), _requests(prompts, max_new=3))
    assert [r.output for r in done] == [r.output for r in ref]


def test_write_prefill_groups_matches_adopt_bitwise():
    """Given the same post-rope K/V, the direct in-pool group write produces
    bitwise the blocks that dense fill + adopt_prefill would have — group
    boundaries are quantization boundaries in both layouts."""
    from repro.cache.kvcache import LayerKVCache
    from repro.cache.paged import PagedKVPool
    from repro.core.precision import MODE_KIVI

    hkv, d, ln = 2, 16, 21          # 2 full groups + 5-token tail
    pp = PrecisionPair(4, 2)
    key = jax.random.PRNGKey(42)
    k = jax.random.normal(key, (1, hkv, ln, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 1), (1, hkv, ln, d),
                          jnp.float32)
    pages = jnp.asarray([3, 1], jnp.int32)
    n_full = ln // R * R

    dense = LayerKVCache.init(1, hkv, d, 24, pp, MODE_KIVI, R,
                              dtype=jnp.float32).fill(k, v)
    adopted = PagedKVPool.init(5, 1, hkv, d, pp, MODE_KIVI, R,
                               dtype=jnp.float32) \
        .adopt_prefill(dense, jnp.int32(0), pages)
    written = PagedKVPool.init(5, 1, hkv, d, pp, MODE_KIVI, R,
                               dtype=jnp.float32) \
        .write_prefill_groups(k[:, :, :n_full], v[:, :, :n_full], pages) \
        .write_residual(jnp.int32(0), k[:, :, n_full:], v[:, :, n_full:])
    for name in ("k_codes", "k_scale", "k_zero", "v_codes", "v_scale",
                 "v_zero"):
        np.testing.assert_array_equal(
            np.asarray(getattr(adopted, name)),
            np.asarray(getattr(written, name)), err_msg=name)
    rem = ln - n_full
    np.testing.assert_array_equal(np.asarray(adopted.k_res[0, :, :rem]),
                                  np.asarray(written.k_res[0, :, :rem]))
    np.testing.assert_array_equal(np.asarray(adopted.v_res[0, :, :rem]),
                                  np.asarray(written.v_res[0, :, :rem]))
