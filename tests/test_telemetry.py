"""Telemetry tests (ISSUE 10): metric primitives and the bounded-reservoir
histogram, the ``EngineStats``-over-``MetricsRegistry`` facade, request
trace invariants across engine features (speculation × horizon × preemption
× mesh) and every terminal status, Perfetto export round-trips + schema
rejection, the online quant-quality probe (finite errors, read-only token
identity, reference-precision idempotency), fault observability, and the
``BENCH_*.json`` record helpers.

The standing invariant, asserted throughout: telemetry is *observation
only* — traced/probed greedy outputs are token-identical to untraced runs.
"""
import json
import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs.base import ModelConfig
from repro.core.precision import KVTunerSchedule, PrecisionPair
from repro.core.quant import MODE_PER_TOKEN
from repro.models.registry import build_model
from repro.serving.engine import ContinuousEngine, EngineStats, Request
from repro.serving.faults import FaultInjector
from repro.serving.telemetry import (Counter, Gauge, Histogram,
                                     MetricsRegistry, QuantProbe)
from repro.serving.trace import (ENGINE_SPANS, TraceError, Tracer,
                                 to_perfetto, validate_perfetto,
                                 validate_trace)

jax.config.update("jax_platform_name", "cpu")

R = 8
CHUNK = 16


@pytest.fixture(scope="module")
def tiny_api():
    cfg = ModelConfig(name="telemetry-tiny", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=61, q_chunk=16, kv_group_size=R)
    return build_model(cfg)


@pytest.fixture(scope="module")
def tiny_params(tiny_api):
    return tiny_api.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sched():
    return KVTunerSchedule.uniform(2, PrecisionPair(8, 4),
                                   mode=MODE_PER_TOKEN)


def _engine(api, params, sched, **kw):
    kw.setdefault("prefill_chunk", CHUNK)
    kw.setdefault("prefix_cache", True)
    kw.setdefault("max_seq", 64)
    kw.setdefault("max_batch", 2)
    return ContinuousEngine(api, params, sched, **kw)


def _reqs(n=6, plen=20, max_new=8, seed=3, **kw):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, 61, plen),
                    max_new_tokens=max_new, arrival_step=2 * i, **kw)
            for i in range(n)]


def _run(engine, reqs):
    for r in reqs:
        engine.submit(r)
    done = sorted(engine.run(), key=lambda r: r.uid)
    engine.alloc.assert_consistent()
    engine.audit()
    return done


def _outputs(done):
    return [list(r.output) for r in done]


# ==================================================== metric primitives
class TestMetricPrimitives:
    def test_counter_and_gauge(self):
        c = Counter("c")
        c.inc()
        c.inc(4)
        assert c.value == 5 and c.kind == "counter"
        g = Gauge("g")
        g.set(2.5)
        assert g.value == 2.5 and g.kind == "gauge"

    def test_histogram_exact_under_cap(self):
        h = Histogram("h")
        vals = list(np.random.default_rng(0).uniform(0, 9, 100))
        h.extend(vals)
        assert list(h) == [float(v) for v in vals]
        assert h.count == 100 and len(h) == 100
        assert h.total == pytest.approx(sum(vals))
        assert h.vmin == min(vals) and h.vmax == max(vals)
        assert h.percentile(50) == pytest.approx(np.percentile(vals, 50))
        assert h.mean == pytest.approx(np.mean(vals))

    def test_histogram_reservoir_bounded_deterministic(self):
        a, b = Histogram("same", cap=16), Histogram("same", cap=16)
        vals = range(1000)
        a.extend(vals)
        b.extend(vals)
        # exact aggregates survive the cap; the reservoir is bounded and
        # reproducible (per-name seeded) so two runs agree bit-for-bit
        assert len(a) == 16 and a.count == 1000
        assert a.total == sum(vals) and a.vmin == 0 and a.vmax == 999
        assert list(a) == list(b)

    def test_histogram_list_compat(self):
        h = Histogram("lc")
        assert not h and h.percentile(95) == 0.0
        h.append(1.0)
        assert h and len(h) == 1

    def test_histogram_cap_validation(self):
        with pytest.raises(ValueError, match="cap"):
            Histogram("bad", cap=0)

    def test_registry_get_or_create_and_kind_mismatch(self):
        reg = MetricsRegistry()
        assert reg.counter("x") is reg.counter("x")
        assert "x" in reg and reg.names() == ["x"]
        with pytest.raises(TypeError, match="already registered"):
            reg.gauge("x")
        with pytest.raises(TypeError, match="already registered"):
            reg.histogram("x")

    def test_snapshot_json_roundtrip(self):
        reg = MetricsRegistry()
        reg.counter("a.count").inc(3)
        reg.gauge("a.gauge").set(1.5)
        reg.histogram("a.hist").extend([1.0, 2.0, 3.0])
        snap = json.loads(reg.to_json())
        assert snap["a.count"] == {"kind": "counter", "value": 3}
        assert snap["a.gauge"] == {"kind": "gauge", "value": 1.5}
        h = snap["a.hist"]
        assert h["count"] == 3 and h["p50"] == 2.0
        assert "samples" not in h            # never exports raw reservoirs

    def test_prometheus_exposition(self):
        reg = MetricsRegistry()
        reg.counter("engine.completed").inc(2)
        reg.histogram("engine.decode_dispatch_wall_s").extend([0.1, 0.3])
        text = reg.to_prometheus()
        assert "# TYPE engine_completed counter\nengine_completed 2" in text
        assert "engine_decode_dispatch_wall_s_count 2" in text
        assert 'quantile="0.5"' in text and text.endswith("\n")


# ================================================== EngineStats facade
class TestEngineStatsFacade:
    def test_counters_route_to_registry(self):
        s = EngineStats()
        s.completed += 3
        s.prefix_hits += 1
        assert s.registry.counter("engine.completed").value == 3
        assert s.completed == 3
        snap = s.registry.snapshot()
        assert snap["engine.prefix_hits"]["value"] == 1

    def test_record_step_wall_is_per_dispatch(self):
        """Satellite (a): a 4-step horizon dispatch is ONE 0.4s sample with
        its step count recorded — not four smeared 0.1s samples."""
        s = EngineStats()
        s.record_step_wall(0.4, steps=4)
        assert list(s.step_wall_times) == [0.4]
        assert s.decode_dispatches == 1
        steps = s.registry.histogram("engine.decode_dispatch_steps")
        assert list(steps) == [4.0]
        assert s.decode_p50_ms == pytest.approx(400.0)

    def test_histogram_fields_reject_assignment(self):
        s = EngineStats()
        with pytest.raises(AttributeError, match="histogram"):
            s.step_wall_times = []

    def test_unknown_attribute_raises(self):
        with pytest.raises(AttributeError):
            EngineStats().not_a_metric

    def test_decode_tokens_per_s_uses_exact_totals(self):
        s = EngineStats()
        s.decode_tokens += 30
        s.record_step_wall(0.5)
        s.record_step_wall(1.0)
        assert s.decode_tokens_per_s == pytest.approx(20.0)


# ============================================ trace invariants (engine)
@pytest.mark.parametrize("feature_kw", [
    {},                                      # plain continuous batching
    {"decode_horizon": 2},                   # multi-step decode dispatch
    {"speculate_k": 2},                      # draft-verify speculation
    {"batched_admission": True},             # chunk-wave prefill
], ids=["plain", "horizon2", "spec2", "batched-admission"])
def test_trace_complete_and_token_identical(tiny_api, tiny_params, sched,
                                            feature_kw):
    ref = _run(_engine(tiny_api, tiny_params, sched, **feature_kw), _reqs())
    eng = _engine(tiny_api, tiny_params, sched, trace=True, **feature_kw)
    done = _run(eng, _reqs())
    assert _outputs(done) == _outputs(ref)
    summary = validate_trace(eng.tracer)
    assert summary["terminal"] == len(done) == 6
    assert summary["statuses"] == ["done"]
    span_names = {s.name for s in eng.tracer.engine_spans}
    assert span_names and span_names <= set(ENGINE_SPANS)
    if feature_kw.get("speculate_k"):
        assert "spec_dispatch" in span_names
        commits = [e for rt in eng.tracer.requests.values()
                   for e in rt.events if e[1] == "spec_commit"]
        assert commits and any(ev[2]["accepted"] > 0 for ev in commits)
        assert all(0 <= ev[2]["accepted"] <= ev[2]["drafted"]
                   for ev in commits)


def _deadline_run(api, params, sched):
    reqs = _reqs()
    reqs[2] = Request(uid=2, prompt=reqs[2].prompt, max_new_tokens=8,
                      arrival_step=reqs[2].arrival_step, deadline_step=6)
    return _engine(api, params, sched, trace=True), reqs, 2, "timed_out"


def _cancel_run(api, params, sched):
    inj = FaultInjector(cancel_at=[(4, 1)])
    return (_engine(api, params, sched, trace=True, faults=inj),
            _reqs(), 1, "cancelled")


def _shed_run(api, params, sched):
    inj = FaultInjector(call_at=[(3, lambda e: e.drain())])
    return (_engine(api, params, sched, trace=True, faults=inj),
            _reqs(), None, "shed")


def _failed_run(api, params, sched):
    inj = FaultInjector(p_alloc_fail=1.0)
    return (_engine(api, params, sched, trace=True, faults=inj,
                    stall_ticks=5), _reqs(n=3), 0, "failed")


@pytest.mark.parametrize("builder", [_deadline_run, _cancel_run, _shed_run,
                                     _failed_run],
                         ids=["timed_out", "cancelled", "shed", "failed"])
def test_trace_terminal_status_matrix(tiny_api, tiny_params, sched, builder):
    """Every terminal ending — not just DONE — closes a valid span tree
    whose recorded status matches the request's."""
    eng, reqs, victim, status = builder(tiny_api, tiny_params, sched)
    done = _run(eng, reqs)
    summary = validate_trace(eng.tracer)
    assert summary["terminal"] == len(done)
    assert status in summary["statuses"]
    for r in done:
        assert eng.tracer.requests[r.uid].status == r.status
    if victim is not None:
        assert eng.tracer.requests[victim].status == status


def test_trace_preemption_host_tier(tiny_api, tiny_params, sched):
    """Preempt → park-on-host → swap-in shows up as request events with a
    re-queued phase, and the trace stays gap-free through the round trip."""
    rng = np.random.default_rng(11)
    pages = 64 // R + 1
    eng = _engine(tiny_api, tiny_params, sched, trace=True,
                  num_blocks=1 + 2 * pages, host_blocks=24,
                  scheduler="priority")
    reqs = [Request(uid=i, prompt=rng.integers(0, 61, 24), max_new_tokens=8,
                    arrival_step=2 * i, priority=i) for i in range(5)]
    done = _run(eng, reqs)
    validate_trace(eng.tracer)
    assert eng.stats.preemptions > 0 and eng.stats.resumes > 0
    preempted = [rt for rt in eng.tracer.requests.values()
                 if any(e[1] == "preempt" for e in rt.events)]
    assert len(preempted) == len(done) == 5 or preempted
    rt = preempted[0]
    names = [e[1] for e in rt.events]
    assert "swap_in" in names or "recompute_replay" in names
    # a preempted request re-enters 'queued' after decoding started
    phases = [s.name for s in rt.phases]
    assert phases.count("queued") >= 2 and phases[-1] == "decode"


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 host devices (tests/conftest.py)")
def test_trace_on_mesh(tiny_params, sched):
    """Tracing composes with the sharded pool: identical outputs and a
    valid trace on an 8-device mesh."""
    from repro.launch.mesh import make_test_mesh

    cfg = ModelConfig(name="telemetry-mesh", family="dense", num_layers=2,
                      d_model=64, num_heads=16, num_kv_heads=8, d_ff=128,
                      vocab_size=61, q_chunk=16, kv_group_size=R)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    mesh = make_test_mesh(8)
    reqs = _reqs(n=4, max_new=6)
    ref = _run(_engine(api, params, sched, mesh=mesh), reqs)
    eng = _engine(api, params, sched, mesh=mesh, trace=True)
    done = _run(eng, _reqs(n=4, max_new=6))
    assert _outputs(done) == _outputs(ref)
    summary = validate_trace(eng.tracer)
    assert summary["statuses"] == ["done"]
    assert eng.stats.n_shards == 8


# ===================================================== tracer unit tests
def test_tracer_unterminated_request_fails_gate():
    t = Tracer()
    t.begin(0)
    with pytest.raises(TraceError, match="terminal"):
        validate_trace(t)
    validate_trace(t, require_terminal=False)    # mid-run view is fine


def test_tracer_detects_phase_gap():
    t = Tracer()
    t.begin(0)
    t.phase(0, "prefill")
    t.requests[0].phases[0].t1 -= 1e-3          # tamper: open a gap
    t.finish(0, "done")
    with pytest.raises(TraceError, match="gap"):
        validate_trace(t)


def test_tracer_phase_reentry_is_noop():
    t = Tracer()
    t.begin(0)
    t.phase(0, "decode")
    t.phase(0, "decode")
    t.finish(0, "done")
    assert [s.name for s in t.requests[0].phases] == ["queued", "decode"]
    validate_trace(t)


# ======================================================= perfetto export
def test_perfetto_roundtrip_and_counts(tiny_api, tiny_params, sched):
    eng = _engine(tiny_api, tiny_params, sched, trace=True)
    done = _run(eng, _reqs(n=3))
    doc = json.loads(json.dumps(to_perfetto(eng.tracer)))
    counts = validate_perfetto(doc)
    assert counts["X"] > 0
    # one engine process/thread pair + one thread-name row per request
    assert counts["M"] == 2 + len(done)
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert "request:done" in names and "decode_dispatch" in names


@pytest.mark.parametrize("corrupt", [
    lambda d: d.pop("traceEvents"),
    lambda d: d["traceEvents"].append({"ph": "Z", "pid": 0, "tid": 0,
                                       "name": "bad"}),
    lambda d: d["traceEvents"].append({"ph": "X", "pid": 0, "tid": 0,
                                       "name": "neg", "ts": -1, "dur": 1}),
    lambda d: d["traceEvents"].append({"ph": "i", "pid": "zero", "tid": 0,
                                       "name": "badpid", "ts": 0}),
], ids=["no-events", "unknown-ph", "negative-ts", "non-int-pid"])
def test_perfetto_rejects_corrupted(corrupt):
    t = Tracer()
    t.begin(0)
    t.finish(0, "done")
    doc = to_perfetto(t)
    corrupt(doc)
    with pytest.raises(TraceError):
        validate_perfetto(doc)


# ======================================================== quant probe
def test_probe_validation():
    with pytest.raises(ValueError, match="every"):
        QuantProbe(every=0)
    with pytest.raises(ValueError, match="sample_blocks"):
        QuantProbe(sample_blocks=0)


def test_probe_finite_errors_and_token_identity(tiny_api, tiny_params,
                                                sched):
    ref = _run(_engine(tiny_api, tiny_params, sched), _reqs())
    eng = _engine(tiny_api, tiny_params, sched, probe_every=2,
                  probe_blocks=4, probe_bits=(2, 2))
    done = _run(eng, _reqs())
    assert _outputs(done) == _outputs(ref)      # probe only READS the pool
    s = eng.probe.summary()
    assert s["samples"] > 0 and s["layers"] == [0, 1]
    assert np.all(np.isfinite(s["e_k"])) and np.all(np.isfinite(s["e_v"]))
    assert all(e > 0 for e in s["e_k"])         # 2-bit probe of 8-bit keys
    assert "probe.e_k.layer0" in eng.metrics.names()
    assert eng.metrics.counter("probe.samples").value == s["samples"]


def test_probe_at_stored_bits_reads_zero(tiny_api, tiny_params, sched):
    """RTN re-quantization at the stored precision is lossless, so probing
    at the schedule's own (8, 4) reads ~0 — the documented reason
    ``probe_bits`` must sit strictly below the stored pair."""
    eng = _engine(tiny_api, tiny_params, sched, probe_every=2,
                  probe_blocks=4, probe_bits=(8, 4))
    _run(eng, _reqs())
    s = eng.probe.summary()
    assert s["samples"] > 0
    assert max(s["e_k"] + s["e_v"]) < 1e-5


# ================================================== fault observability
def test_fault_events_reach_metrics_and_trace(tiny_api, tiny_params, sched):
    """Satellite (c): injected faults are observable — every fired fault
    increments its ``faults.*`` counter and lands on the engine trace
    track as a ``fault.*`` instant."""
    inj = FaultInjector(cancel_at=[(4, 1)], p_alloc_fail=0.4, seed=7)
    eng = _engine(tiny_api, tiny_params, sched, trace=True, faults=inj)
    _run(eng, _reqs())
    reg = eng.metrics
    assert reg.counter("faults.cancel").value == 1
    assert reg.counter("faults.alloc").value == inj.alloc_faults > 0
    events = [name for _, name, _ in eng.tracer.engine_events]
    assert events.count("fault.cancel") == 1
    assert events.count("fault.alloc") == inj.alloc_faults


def test_untraced_engine_has_no_tracer(tiny_api, tiny_params, sched):
    """trace=False keeps the hook sites dead (``tracer is None``) — the
    exact-zero-overhead contract."""
    eng = _engine(tiny_api, tiny_params, sched)
    assert eng.tracer is None and eng.probe is None
    _run(eng, _reqs(n=2))


# ===================================================== bench record files
def test_bench_json_write_and_validate(tmp_path):
    from benchmarks.common import validate_bench_json, write_bench_json

    path = write_bench_json(
        "unit", {"tokens_per_s": 10.0}, {"claim a": True, "claim b": True},
        config={"tiny": True}, seed=0, out_dir=str(tmp_path))
    rec = validate_bench_json(path)
    assert rec["bench"] == "unit" and rec["passed"] is True
    assert rec["result"]["tokens_per_s"] == 10.0

    rec["passed"] = False                       # passed must match claims
    with open(path, "w") as f:
        json.dump(rec, f)
    with pytest.raises(ValueError, match="passed"):
        validate_bench_json(path)

    with open(path, "w") as f:
        json.dump({"bench": "unit"}, f)         # missing required keys
    with pytest.raises(ValueError):
        validate_bench_json(path)
