"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles
(ref.py) across shape/dtype/bits sweeps, plus integration with the decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.kvcache import LayerKVCache
from repro.core import quant
from repro.core.precision import (MODE_KIVI, MODE_PER_CHANNEL, MODE_PER_TOKEN,
                                  PrecisionPair)
from repro.kernels import ops, ref
from repro.kernels.kvquant import kvquant as kvquant_raw
from repro.kernels.qdecode import qdecode

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=dtype)


# ------------------------------------------------------------------ kvquant
@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("mode", [MODE_PER_TOKEN, MODE_PER_CHANNEL])
@pytest.mark.parametrize("shape", [(2, 128, 64), (1, 256, 128), (3, 128, 32)])
def test_kvquant_matches_ref(bits, mode, shape):
    x = _rand(shape, seed=bits)
    codes, scale, zero = kvquant_raw(x, bits, mode, interpret=True)
    rc, rs, rz = ref.kvquant_ref(x, bits, mode)
    # RTN ties at the .5 boundary may flip by 1 code under different fusion
    # orders; require ≤1-code difference on <0.1% of elements, exact elsewhere.
    uk = np.asarray(quant.unpack_codes(codes, bits), np.int32)
    ur = np.asarray(quant.unpack_codes(rc, bits), np.int32)
    diff = np.abs(uk - ur)
    assert diff.max() <= 1
    assert (diff > 0).mean() < 1e-3
    np.testing.assert_allclose(np.asarray(scale), np.asarray(rs), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(zero), np.asarray(rz), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kvquant_dtypes(dtype):
    x = _rand((2, 128, 64), seed=7, dtype=dtype)
    codes, scale, zero = kvquant_raw(x, 4, MODE_PER_TOKEN, interpret=True)
    rc, rs, rz = ref.kvquant_ref(x, 4, MODE_PER_TOKEN)
    uk = np.asarray(quant.unpack_codes(codes, 4), np.int32)
    ur = np.asarray(quant.unpack_codes(rc, 4), np.int32)
    diff = np.abs(uk - ur)
    assert diff.max() <= 1 and (diff > 0).mean() < 2e-3


# ------------------------------------------------------------------ qdecode
def _mk_segments(b, hkv, s, d, k_bits, v_bits, mode, seed=0):
    k = _rand((b, hkv, s, d), seed=seed)
    v = _rand((b, hkv, s, d), seed=seed + 1)
    k_mode, v_mode = (MODE_PER_CHANNEL, MODE_PER_TOKEN) if mode == MODE_KIVI \
        else (mode, mode)

    def seg(x, bits, m):
        if bits >= 16:
            return x, jnp.zeros((1,), jnp.float32), jnp.zeros((1,), jnp.float32)
        qt = quant.quantize(x, bits, m, 32)
        return qt.codes, qt.scale, qt.zero

    kc, ks, kz = seg(k, k_bits, k_mode)
    vc, vs, vz = seg(v, v_bits, v_mode)
    return (k, v), (kc, ks, kz, vc, vs, vz), (k_mode, v_mode)


@pytest.mark.parametrize("pair", [(8, 8), (8, 4), (4, 2), (2, 2), (16, 8)])
@pytest.mark.parametrize("mode", [MODE_PER_TOKEN, MODE_KIVI])
def test_qdecode_matches_ref(pair, mode):
    b, hkv, g, d, s = 2, 2, 4, 64, 256
    kb, vb = pair
    q = _rand((b, hkv, g, d), seed=3)
    _, segs, (k_mode, v_mode) = _mk_segments(b, hkv, s, d, kb, vb, mode)
    n_valid = jnp.asarray([256, 128], jnp.int32)
    o, m, l = qdecode(q, *segs, n_valid, k_bits=kb, v_bits=vb, k_mode=k_mode,
                      v_mode=v_mode, interpret=True)
    ro, rm, rl = ref.qdecode_ref(q, *segs, n_valid, k_bits=kb, v_bits=vb,
                                 k_mode=k_mode, v_mode=v_mode)
    # compare normalized outputs (m offsets may differ; o/l are consistent)
    out = np.asarray(o / np.maximum(np.asarray(l)[..., None], 1e-20))
    rout = np.asarray(ro / np.maximum(np.asarray(rl)[..., None], 1e-20))
    np.testing.assert_allclose(out, rout, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("s", [192, 96, 320])
def test_qdecode_non_power_of_two_lengths(s):
    """Regression: lengths whose largest aligned tile does not divide them
    (192 with the default 128-row tile) must auto-select a working tile
    instead of tripping the divisibility assert."""
    b, hkv, g, d = 1, 2, 4, 64
    q = _rand((b, hkv, g, d), seed=13)
    _, segs, (km, vm) = _mk_segments(b, hkv, s, d, 4, 4, MODE_PER_TOKEN,
                                     seed=17)
    n_valid = jnp.asarray([s - 32], jnp.int32)
    o, m, l = qdecode(q, *segs, n_valid, k_bits=4, v_bits=4, k_mode=km,
                      v_mode=vm, interpret=True)
    ro, rm, rl = ref.qdecode_ref(q, *segs, n_valid, k_bits=4, v_bits=4,
                                 k_mode=km, v_mode=vm)
    out = np.asarray(o / np.maximum(np.asarray(l)[..., None], 1e-20))
    rout = np.asarray(ro / np.maximum(np.asarray(rl)[..., None], 1e-20))
    np.testing.assert_allclose(out, rout, rtol=2e-5, atol=2e-5)


def test_pick_block_s():
    from repro.kernels.qdecode import pick_block_s

    assert pick_block_s(192, 128, 32) == 96
    assert pick_block_s(256, 128, 32) == 128
    assert pick_block_s(64, 128, 32) == 64
    assert pick_block_s(32, 128, 32) == 32
    assert pick_block_s(160, 128, 32) == 32
    with pytest.raises(ValueError):
        pick_block_s(100, 128, 32)


@pytest.mark.parametrize("shape", [(1, 1, 2, 32, 128), (2, 4, 8, 128, 384)])
def test_qdecode_shape_sweep(shape):
    b, hkv, g, d, s = shape
    q = _rand((b, hkv, g, d), seed=11)
    _, segs, (km, vm) = _mk_segments(b, hkv, s, d, 4, 4, MODE_PER_TOKEN, seed=5)
    n_valid = jnp.full((b,), s, jnp.int32)
    o, m, l = qdecode(q, *segs, n_valid, k_bits=4, v_bits=4, k_mode=km,
                      v_mode=vm, interpret=True)
    ro, rm, rl = ref.qdecode_ref(q, *segs, n_valid, k_bits=4, v_bits=4,
                                 k_mode=km, v_mode=vm)
    out = np.asarray(o / np.asarray(l)[..., None])
    rout = np.asarray(ro / np.asarray(rl)[..., None])
    np.testing.assert_allclose(out, rout, rtol=2e-5, atol=2e-5)


def test_softmax_merge_equals_joint():
    """Merging two partial softmaxes == softmax over the concatenation."""
    b, hkv, g, d, s = 1, 1, 2, 32, 128
    q = _rand((b, hkv, g, d), seed=2)
    k = _rand((b, hkv, s, d), seed=3)
    v = _rand((b, hkv, s, d), seed=4)
    scores = jnp.einsum("bhgd,bhsd->bhgs", q, k) / jnp.sqrt(d)
    pfull = jax.nn.softmax(scores, -1)
    joint = jnp.einsum("bhgs,bhsd->bhgd", pfull, v)

    def part(lo, hi):
        sc = scores[..., lo:hi]
        m = jnp.max(sc, -1)
        p = jnp.exp(sc - m[..., None])
        return jnp.einsum("bhgs,bhsd->bhgd", p, v[:, :, lo:hi]), m, jnp.sum(p, -1)

    merged = ref.softmax_merge([part(0, 80), part(80, 128)])
    np.testing.assert_allclose(np.asarray(merged), np.asarray(joint),
                               rtol=1e-5, atol=1e-6)


# -------------------------------------------------- end-to-end decode parity
@pytest.mark.parametrize("pair", [(8, 8), (8, 4), (16, 16)])
def test_kernel_decode_vs_xla_decode(pair):
    """ops.qdecode_attention (Pallas path) == cache.dequant XLA attention."""
    b, hkv, h, d, s_cap = 2, 2, 4, 64, 128
    cache = LayerKVCache.init(b, hkv, d, s_cap, PrecisionPair(*pair),
                              mode=MODE_PER_TOKEN, dtype=jnp.float32)
    k = _rand((b, hkv, 96 + 7, d), seed=21)
    v = _rand((b, hkv, 96 + 7, d), seed=22)
    cache = cache.fill(k, v)  # 96 main + 7 residual

    q = _rand((b, 1, h, d), seed=23)
    out_pallas = ops.qdecode_attention(q, cache, jnp.full((b, 1), 103), "causal",
                                       0, interpret=True)

    k_all, v_all, valid = cache.dequant(dtype=jnp.float32)
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg, k_all) / jnp.sqrt(d)
    scores = jnp.where(valid[None, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, -1)
    ref_out = jnp.einsum("bhgs,bhsd->bhgd", p, v_all).reshape(b, 1, h, d)
    np.testing.assert_allclose(np.asarray(out_pallas), np.asarray(ref_out),
                               rtol=3e-5, atol=3e-5)
