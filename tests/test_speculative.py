"""Speculative multi-token decode tests.

Covers the draft–verify–commit engine end-to-end: prompt-lookup drafting,
multi-token pool commit (``append_tokens``) bitwise-identical to sequential
appends, speculative snapshot/rollback bitwise-identical to never having
appended (including a group-boundary flush mid-speculation), the fused
verify kernel's parity with the XLA oracle, greedy token-identity of
``speculate_k > 0`` against plain decode across engine configurations
(kernel on/off, horizon, prefix cache, batched admission,
preemption-under-overload), EOS landing mid-accepted-prefix, and the
speculative stats/byte accounting.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.paged import SCRATCH_BLOCK, PagedKVPool
from repro.configs.base import ModelConfig
from repro.core.precision import (MODE_KIVI, MODE_PER_TOKEN, KVTunerSchedule,
                                  PrecisionPair)
from repro.models import attention
from repro.models.registry import build_model
from repro.models.transformer import layer_params_at
from repro.serving.draft import Drafter, PromptLookupDrafter
from repro.serving.engine import ContinuousEngine, Request

jax.config.update("jax_platform_name", "cpu")

R = 8   # small quant group → flushes inside short speculative runs


# ============================================================== drafting
def test_prompt_lookup_basic_match():
    d = PromptLookupDrafter(max_ngram=3)
    h = np.array([1, 2, 3, 9, 9, 1, 2, 3], np.int32)
    # trailing 3-gram [1,2,3] recurs at the start; continuation is [9, 9, 1]
    np.testing.assert_array_equal(d.draft(h, 3), [9, 9, 1])
    # k truncates the proposal
    np.testing.assert_array_equal(d.draft(h, 1), [9])


def test_prompt_lookup_most_recent_occurrence_wins():
    d = PromptLookupDrafter(max_ngram=2)
    h = np.array([5, 6, 1, 5, 6, 2, 5, 6], np.int32)
    # [5,6] occurs at 0 (→1) and 3 (→2); the later occurrence wins
    np.testing.assert_array_equal(d.draft(h, 1), [2])


def test_prompt_lookup_falls_back_to_shorter_ngram():
    d = PromptLookupDrafter(max_ngram=3)
    h = np.array([7, 1, 2, 9, 4, 1, 2], np.int32)
    # no earlier [4,1,2] / [1,2]→ at 1 continues with 9
    np.testing.assert_array_equal(d.draft(h, 2), [9, 4])


def test_prompt_lookup_no_match_and_degenerate():
    d = PromptLookupDrafter()
    assert d.draft(np.array([1, 2, 3, 4], np.int32), 2).size == 0
    assert d.draft(np.array([1], np.int32), 2).size == 0
    assert d.draft(np.array([], np.int32), 2).size == 0
    assert d.draft(np.array([1, 1, 1], np.int32), 0).size == 0
    assert isinstance(d, Drafter)


# ===================================================== pool: multi-append
ARRAYS = ("k_codes", "k_scale", "k_zero", "v_codes", "v_scale", "v_zero",
          "k_res", "v_res")


def _pool_state(pair=(4, 4), mode=MODE_PER_TOKEN, seed=3):
    """Two-slot pool with committed lengths [6, 3] (both mid-group)."""
    hkv, d = 2, 16
    pool = PagedKVPool.init(num_blocks=6, max_slots=2, kv_heads=hkv,
                            head_dim=d, pair=PrecisionPair(*pair), mode=mode,
                            group_size=R)
    pt = jnp.array([[1, 2, 3, 0], [4, 5, 0, 0]], jnp.int32)
    rng = np.random.default_rng(seed)

    def rnd(k):
        return jnp.asarray(rng.standard_normal((2, hkv, k, d)), jnp.float32)

    lens = jnp.array([0, 0], jnp.int32)
    for i in range(6):
        alive = jnp.array([True, i < 3])
        pool = pool.append(rnd(1), rnd(1), lens, alive, pt)
        lens = lens + alive.astype(jnp.int32)
    return pool, pt, lens, rnd


def _diff(a, b, skip_block0=True):
    """Field names whose arrays differ. ``skip_block0`` drops the scratch
    block (id 0), whose contents are garbage by contract."""
    bad = []
    for n in ARRAYS:
        x, y = jnp.asarray(getattr(a, n)), jnp.asarray(getattr(b, n))
        if x.ndim > 1 and skip_block0:
            x, y = x[SCRATCH_BLOCK + 1:], y[SCRATCH_BLOCK + 1:]
        if not bool(jnp.array_equal(x, y)):
            bad.append(n)
    return bad


@pytest.mark.parametrize("pair,mode", [((4, 4), MODE_PER_TOKEN),
                                       ((8, 4), MODE_KIVI)])
def test_append_tokens_matches_sequential_appends_bitwise(pair, mode):
    pool, pt, lens, rnd = _pool_state(pair, mode)
    kt, vt = rnd(4), rnd(4)
    counts = jnp.array([3, 4], jnp.int32)   # slot1 crosses no boundary,
    multi = pool.append_tokens(kt, vt, lens, counts, pt)
    seq, cur = pool, lens
    for i in range(4):
        alive = counts > i
        seq = seq.append(kt[:, :, i:i + 1], vt[:, :, i:i + 1], cur, alive, pt)
        cur = cur + alive.astype(jnp.int32)
    # every live block + both residual windows bitwise; only the scratch
    # block (garbage by contract) may differ from the sequential loop
    assert _diff(multi, seq) == []


def test_append_tokens_zero_count_is_noop():
    pool, pt, lens, rnd = _pool_state()
    out = pool.append_tokens(rnd(3), rnd(3), lens,
                             jnp.array([0, 0], jnp.int32), pt)
    assert _diff(out, pool) == []


# ================================================== pool: snapshot/rollback
def test_rollback_bitwise_across_group_boundary():
    """Append 4 from lengths [6, 3]: slot 0 crosses 8 and flushes block 1
    mid-speculation; rollback must unflush it — post-rollback state is
    bitwise the never-appended pool."""
    pool, pt, lens, rnd = _pool_state()
    snap = pool.snapshot_spec(lens, pt)
    appended = pool.append_tokens(rnd(4), rnd(4), lens,
                                  jnp.array([4, 4], jnp.int32), pt)
    # the flush really happened (block 1 changed) — then vanishes
    assert "k_codes" in _diff(appended, pool)
    back = appended.rollback_spec(snap, jnp.array([True, True]))
    assert _diff(back, pool) == []


def test_rollback_partial_undo_mask():
    """Undoing only slot 0 must equal a run where slot 0 never appended
    while slot 1 appended the same tokens."""
    pool, pt, lens, rnd = _pool_state()
    kt, vt = rnd(4), rnd(4)
    snap = pool.snapshot_spec(lens, pt)
    both = pool.append_tokens(kt, vt, lens, jnp.array([4, 4], jnp.int32), pt)
    undone = both.rollback_spec(snap, jnp.array([True, False]))
    only1 = pool.append_tokens(kt, vt, lens, jnp.array([0, 4], jnp.int32), pt)
    assert _diff(undone, only1) == []


def test_rollback_noop_when_nothing_appended():
    pool, pt, lens, _ = _pool_state()
    snap = pool.snapshot_spec(lens, pt)
    back = pool.rollback_spec(snap, jnp.array([True, True]))
    assert _diff(back, pool) == []


def _append_seq(pool, lens, pt, kt, vt, counts):
    """Serial single-token appends — the sub-step commit path of the scan
    verify backend."""
    cur = lens
    for j in range(kt.shape[2]):
        alive = jnp.asarray(np.asarray(counts) > j)
        pool = pool.append(kt[:, :, j:j + 1], vt[:, :, j:j + 1], cur, alive,
                           pt)
        cur = cur + alive.astype(jnp.int32)
    return pool


@pytest.mark.parametrize("keep", [(0, 0), (1, 1), (2, 3), (4, 2), (5, 3)])
def test_rollback_tail_bitwise_vs_keep_only_appends(keep):
    """Serial-append 5/3 tokens from lengths [6, 3] (slot 0's flush fires
    at sub-step j_f=1, then wraps into the next group), then roll back all
    but ``keep``: the result must be bitwise the pool that only ever
    appended the kept prefix — covering unflush (flush in the rejected
    tail, keep<=1 for slot 0), flush-stands (flush in the kept prefix,
    keep>=2), wrapped-window restore, and the full/no-op corners."""
    pool, pt, lens, rnd = _pool_state()
    kt, vt = rnd(5), rnd(5)
    appended = (5, 3)
    snap = pool.snapshot_spec(lens, pt)
    full = _append_seq(pool, lens, pt, kt, vt, appended)
    assert "k_codes" in _diff(full, pool)       # the flush really happened
    rolled = full.rollback_tail(snap, lens, jnp.asarray(keep, jnp.int32),
                                jnp.asarray(appended, jnp.int32))
    ref = _append_seq(pool, lens, pt, kt, vt, keep)
    assert _diff(rolled, ref) == []


# ================================================= verify kernel parity
@pytest.mark.parametrize("pair,mode", [((4, 4), MODE_PER_TOKEN),
                                       ((8, 4), MODE_KIVI)])
def test_verify_attention_kernel_matches_oracle(pair, mode):
    """Fused ``qverify_paged`` (interpret mode) vs the gather/dense oracle,
    over ragged lengths including an empty-context lane and a dead lane."""
    cfg = ModelConfig(name="verify-par", family="dense", num_layers=1,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=61, q_chunk=16, kv_group_size=R)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    p = layer_params_at(params, cfg, 0)["attn"]

    s, k1, hkv, d = 3, 3, 2, cfg.head_dim
    pool = PagedKVPool.init(num_blocks=10, max_slots=s, kv_heads=hkv,
                            head_dim=d, pair=PrecisionPair(*pair), mode=mode,
                            group_size=R)
    pt = jnp.asarray(1 + np.arange(s * 3).reshape(s, 3), jnp.int32)
    rng = np.random.default_rng(11)
    lens = jnp.array([13, 0, 5], jnp.int32)     # ragged; lane 1 empty
    cur = jnp.zeros(s, jnp.int32)
    for i in range(13):
        alive = jnp.asarray(np.arange(s) * 0 + 1, bool) & (cur < lens)
        kv = [jnp.asarray(rng.standard_normal((s, hkv, 1, d)), jnp.float32)
              for _ in range(2)]
        pool = pool.append(kv[0], kv[1], cur, alive, pt)
        cur = cur + alive.astype(jnp.int32)
    x = jnp.asarray(rng.standard_normal((s, k1, cfg.d_model)), jnp.float32)
    alive = jnp.array([True, True, False])

    y_ref, (kr, vr) = attention.paged_verify_attention(
        p, cfg, x, pool, pt, lens, alive, 10000.0, use_pallas=False)
    y_ker, (kk, vk) = attention.paged_verify_attention(
        p, cfg, x, pool, pt, lens, alive, 10000.0, use_pallas=True)
    live = np.asarray(alive)
    np.testing.assert_allclose(np.asarray(y_ker)[live],
                               np.asarray(y_ref)[live],
                               rtol=3e-5, atol=3e-5)
    # candidate KV for the commit is path-independent
    np.testing.assert_array_equal(np.asarray(kr), np.asarray(kk))
    np.testing.assert_array_equal(np.asarray(vr), np.asarray(vk))


# =========================================================== engine fixtures
@pytest.fixture(scope="module")
def tiny_api():
    cfg = ModelConfig(name="spec-tiny", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=61, q_chunk=16, kv_group_size=R)
    return build_model(cfg)


@pytest.fixture(scope="module")
def tiny_params(tiny_api):
    return tiny_api.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sched():
    return KVTunerSchedule.uniform(2, PrecisionPair(8, 4))


def _workload(seed=1, n=6, max_new=10, eos_id=None):
    rng = np.random.default_rng(seed)
    tpl = rng.integers(1, 60, 16)
    prompts = [np.concatenate([tpl, rng.integers(1, 60, 1 + i % 4)])
               for i in range(n)]
    return [Request(uid=i, prompt=p.astype(np.int32), max_new_tokens=max_new,
                    eos_id=eos_id) for i, p in enumerate(prompts)]


def _run(engine, reqs):
    for r in reqs:
        engine.submit(r)
    done = sorted(engine.run(), key=lambda r: r.uid)
    engine.alloc.assert_consistent()
    return [list(r.output) for r in done]


def _engine(api, params, sched, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    return ContinuousEngine(api, params, sched, **kw)


@pytest.fixture(scope="module")
def reference(tiny_api, tiny_params, sched):
    """Plain (speculate_k=0) greedy outputs every spec config must match."""
    return _run(_engine(tiny_api, tiny_params, sched), _workload())


# ====================================================== engine: identity
@pytest.mark.parametrize("kw", [
    dict(speculate_k=2),
    dict(speculate_k=4),
    dict(speculate_k=2, use_pallas=True),
    dict(speculate_k=2, decode_horizon=3),
    dict(speculate_k=2, batched_admission=True),
    dict(speculate_k=2, prefix_cache=True),
    dict(speculate_k=3, prefix_cache=True, use_pallas=True,
         decode_horizon=2),
    # fused verify is only numerically (not bitwise) serial-equivalent, so
    # its identity is asserted on the short-horizon workload only
    dict(speculate_k=2, fused_verify=True),
], ids=["k2", "k4", "k2-pallas", "k2-horizon3", "k2-batched", "k2-prefix",
        "k3-all", "k2-fused"])
def test_speculative_token_identity(tiny_api, tiny_params, sched,
                                    reference, kw):
    """The acceptance property: speculation changes throughput, never
    tokens — greedy outputs are identical to ``speculate_k=0`` for every
    engine composition."""
    eng = _engine(tiny_api, tiny_params, sched, **kw)
    assert _run(eng, _workload()) == reference
    s = eng.stats
    assert s.spec_steps > 0 and s.drafted_tokens > 0
    assert 0.0 <= s.acceptance_rate <= 1.0
    # every commit emits at least the guaranteed token
    assert s.accepted_lengths and min(s.accepted_lengths) >= 1
    assert max(s.accepted_lengths) <= kw["speculate_k"] + 1


def test_speculative_with_preemption_under_overload(tiny_api, tiny_params,
                                                    sched):
    """Speculation composes with host-tier preemption: an undersized pool
    forces swap-out mid-run (never observing a speculative tail — rejected
    KV is rolled back inside the dispatch, before the host ever sees the
    state) and the resumed requests finish token-identically."""
    def work():
        rng = np.random.default_rng(5)
        tpl = rng.integers(1, 60, 24)
        prompts = [np.concatenate([tpl, rng.integers(1, 60, 5)])
                   for _ in range(6)]
        return [Request(uid=i, prompt=p.astype(np.int32),
                        max_new_tokens=[12, 12, 6, 6, 5, 5][i],
                        arrival_step=[0, 0, 3, 5, 8, 11][i],
                        priority=[0, 0, 2, 3, 4, 5][i])
                for i, p in enumerate(prompts)]

    base = _run(_engine(tiny_api, tiny_params, sched, prefix_cache=True,
                        prefill_chunk=16, scheduler="priority"), work())
    eng = _engine(tiny_api, tiny_params, sched, prefix_cache=True,
                  prefill_chunk=16, scheduler="priority", speculate_k=2,
                  num_blocks=14, host_blocks=10)
    assert _run(eng, work()) == base
    assert eng.stats.preemptions > 0 and eng.stats.resumes > 0
    assert eng.stats.spec_steps > 0


class OracleDrafter:
    """Test-only drafter that proposes the reference continuation for the
    request's history — forces full acceptance so EOS-handling inside an
    accepted prefix is actually exercised."""

    def __init__(self, refs):
        self.refs = refs   # list of (prompt, full_output) pairs

    def draft(self, history, k):
        for prompt, out in self.refs:
            full = np.concatenate([prompt, out])
            n = len(history)
            if n <= len(full) and np.array_equal(full[:n], history):
                return full[n:n + k].astype(np.int32)
        return np.zeros(0, np.int32)


def test_eos_mid_accepted_prefix(tiny_api, tiny_params, sched):
    """With an oracle drafter the EOS token arrives inside an accepted
    multi-token prefix; the engine must emit it, stop the request there,
    and not commit (or emit) anything past it."""
    reqs = _workload(seed=9, n=3, max_new=12)
    base = _run(_engine(tiny_api, tiny_params, sched), reqs)
    # pick each request's 4th generated token as its EOS → EOS fires
    # mid-run, never at the natural budget edge
    eos = {r.uid: base[r.uid][3] for r in reqs}
    assert all(base[u].count(t) for u, t in eos.items())

    def with_eos(out, e):
        return out[:out.index(e) + 1]

    def reqs_eos():
        return [Request(uid=r.uid, prompt=r.prompt, max_new_tokens=12,
                        eos_id=eos[r.uid]) for r in _workload(seed=9, n=3)]

    truth = _run(_engine(tiny_api, tiny_params, sched), reqs_eos())
    assert truth == [with_eos(base[i], eos[i]) for i in range(3)]
    refs = [(r.prompt, np.asarray(truth[r.uid], np.int32))
            for r in reqs_eos()]
    eng = _engine(tiny_api, tiny_params, sched, speculate_k=4,
                  drafter=OracleDrafter(refs))
    assert _run(eng, reqs_eos()) == truth
    # the oracle forces multi-token accepts, so EOS really did land
    # inside an accepted prefix at least once
    assert max(eng.stats.accepted_lengths) > 1
    assert eng.stats.acceptance_rate > 0.5


# ======================================================== stats & bytes
def test_speculative_stats_accounting(tiny_api, tiny_params, sched):
    eng = _engine(tiny_api, tiny_params, sched, speculate_k=2)
    outs = _run(eng, _workload(n=4))
    s = eng.stats
    total = sum(len(o) for o in outs)
    assert s.generated_tokens == total
    # each request's first token is emitted at admission (prefill); every
    # later one is a decode commit — multi-token commits fully credited
    assert s.decode_tokens == total - s.admitted
    assert s.decode_steps == s.spec_steps
    assert sum(s.accepted_lengths) == s.decode_tokens
    assert s.accepted_tokens == s.decode_tokens - len(s.accepted_lengths)
    assert s.accepted_tokens <= s.drafted_tokens
    assert 1.0 <= s.accepted_len_p50 <= s.accepted_len_p95 <= 3.0


def test_verify_stream_bytes_beats_serial_decode():
    pool, pt, lens, _ = _pool_state()
    k1 = 3
    verify = pool.verify_stream_bytes(lens, k1)
    serial = k1 * pool.decode_stream_bytes(lens)
    assert 0 < verify < serial
    # more verify lanes cost only the extra bf16 window, not more blocks
    assert pool.verify_stream_bytes(lens, 5) - pool.verify_stream_bytes(
        lens, 4) == pool.verify_stream_bytes(lens, 4) - verify != 0


def test_speculate_knob_validation(tiny_api, tiny_params, sched):
    with pytest.raises(ValueError, match="greedy"):
        _engine(tiny_api, tiny_params, sched, speculate_k=2, greedy=False)
    with pytest.raises(ValueError, match="group size"):
        _engine(tiny_api, tiny_params, sched, speculate_k=R)
    with pytest.raises(ValueError, match=">= 0"):
        _engine(tiny_api, tiny_params, sched, speculate_k=-1)
