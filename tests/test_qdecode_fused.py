"""Length-aware fused paged decode: parity + engine-identity suite.

Covers the single-launch decode kernel (main paged segment + in-kernel
residual merge, work proportional to live pages) against the XLA
gather-dequant reference across ragged slot lengths, dead slots, and empty
residual windows; the fused output against the legacy two-launch
partial+merge path; and the device-side multi-step decode horizon against
the per-step engine on greedy decode (token identity).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.codec import kv_modes
from repro.cache.paged import PagedKVPool
from repro.configs.base import ModelConfig
from repro.core.precision import (MODE_KIVI, MODE_PER_TOKEN, KVTunerSchedule,
                                  PrecisionPair)
from repro.kernels.qdecode import qdecode_paged
from repro.models.registry import build_model
from repro.serving.engine import ContinuousEngine, Request

jax.config.update("jax_platform_name", "cpu")

R = 8


def _rand(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


def _mk_pool(pair, mode, b, hkv, d, r, n_blocks, seed=0):
    pp = PrecisionPair(*pair)
    pool = PagedKVPool.init(n_blocks, b, hkv, d, pp, mode, r,
                            dtype=jnp.float32)
    c = pool.codec
    kc, ks, kz = c.k.encode(_rand((n_blocks, hkv, r, d), seed))
    vc, vs, vz = c.v.encode(_rand((n_blocks, hkv, r, d), seed + 1))
    return dataclasses.replace(
        pool, k_codes=kc, k_scale=ks, k_zero=kz, v_codes=vc, v_scale=vs,
        v_zero=vz, k_res=_rand((b, hkv, r, d), seed + 2),
        v_res=_rand((b, hkv, r, d), seed + 3))


def _reference(q, pool, pt, n_valid, n_res):
    """Masked softmax over [gathered main ; residual] — the XLA oracle."""
    d = q.shape[-1]
    r = pool.group_size
    s_main = pt.shape[1] * r
    kk, vv = pool.gather_dequant(pt, jnp.float32)
    kk = jnp.concatenate([kk, pool.k_res], axis=2)
    vv = jnp.concatenate([vv, pool.v_res], axis=2)
    idx = jnp.arange(s_main + r)
    valid = jnp.where(idx[None, :] < s_main,
                      idx[None, :] < n_valid[:, None],
                      (idx[None, :] - s_main) < n_res[:, None])
    scores = jnp.einsum("bhgd,bhsd->bhgs", q, kk) / jnp.sqrt(d)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    probs = jnp.where(valid[:, None, None, :],
                      jax.nn.softmax(scores, -1), 0.0)
    return jnp.einsum("bhgs,bhsd->bhgd", probs, vv)


def _run_kernel(q, pool, pt, n_valid, n_res):
    k_mode, v_mode = kv_modes(pool.mode)
    return qdecode_paged(
        q, pool.k_codes, pool.k_scale, pool.k_zero, pool.v_codes,
        pool.v_scale, pool.v_zero, pool.k_res, pool.v_res, pt, n_valid,
        n_res, k_bits=pool.k_bits, v_bits=pool.v_bits, k_mode=k_mode,
        v_mode=v_mode, group_size=pool.group_size, interpret=True)


# ============================================================ kernel parity
@pytest.mark.parametrize("pair,mode", [((8, 8), MODE_PER_TOKEN),
                                       ((4, 2), MODE_KIVI),
                                       ((16, 16), MODE_PER_TOKEN)])
def test_fused_ragged_lengths_match_reference(pair, mode):
    """Mixed live lengths — full table, partial, single page, dead slot —
    with mixed residual occupancy, one launch, vs the gather oracle."""
    b, hkv, g, d, r, p = 4, 2, 4, 64, 32, 4
    pool = _mk_pool(pair, mode, b, hkv, d, r, 1 + b * p, seed=7)
    pt = jnp.arange(1, 1 + b * p, dtype=jnp.int32).reshape(b, p)
    n_valid = jnp.asarray([4 * r, 2 * r, 1 * r, 0], jnp.int32)
    n_res = jnp.asarray([r // 2, 0, r, 0], jnp.int32)
    q = _rand((b, hkv, g, d), seed=11)

    o = _run_kernel(q, pool, pt, n_valid, n_res)
    ref = _reference(q, pool, pt, n_valid, n_res)
    np.testing.assert_allclose(np.asarray(o[:3]), np.asarray(ref[:3]),
                               rtol=3e-5, atol=3e-5)
    # dead slot: nothing streamed, exact zeros out
    np.testing.assert_array_equal(np.asarray(o[3]), 0.0)


def test_fused_empty_residual_matches_reference():
    b, hkv, g, d, r, p = 2, 2, 2, 64, 32, 3
    pool = _mk_pool((4, 4), MODE_PER_TOKEN, b, hkv, d, r, 1 + b * p, seed=3)
    pt = jnp.arange(1, 1 + b * p, dtype=jnp.int32).reshape(b, p)
    n_valid = jnp.asarray([3 * r, 2 * r], jnp.int32)
    n_res = jnp.zeros((b,), jnp.int32)
    q = _rand((b, hkv, g, d), seed=5)
    o = _run_kernel(q, pool, pt, n_valid, n_res)
    ref = _reference(q, pool, pt, n_valid, n_res)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_fused_residual_only_slot():
    """A freshly admitted slot (prompt shorter than one group): zero live
    pages, all context in the residual window."""
    b, hkv, g, d, r, p = 2, 2, 2, 64, 32, 2
    pool = _mk_pool((8, 4), MODE_KIVI, b, hkv, d, r, 1 + b * p, seed=9)
    pt = jnp.arange(1, 1 + b * p, dtype=jnp.int32).reshape(b, p)
    n_valid = jnp.zeros((b,), jnp.int32)
    n_res = jnp.asarray([5, r], jnp.int32)
    q = _rand((b, hkv, g, d), seed=13)
    o = _run_kernel(q, pool, pt, n_valid, n_res)
    ref = _reference(q, pool, pt, n_valid, n_res)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_fusion_matches_two_launch_merge():
    """The in-kernel residual merge reproduces the legacy pipeline —
    separate main partials + XLA residual partial + softmax_merge."""
    from repro.kernels import ref as kref
    from repro.kernels.ops import _residual_partial

    b, hkv, g, d, r, p = 3, 2, 4, 64, 32, 3
    pool = _mk_pool((4, 2), MODE_KIVI, b, hkv, d, r, 1 + b * p, seed=21)
    pt = jnp.arange(1, 1 + b * p, dtype=jnp.int32).reshape(b, p)
    n_valid = jnp.asarray([3 * r, r, 2 * r], jnp.int32)
    n_res = jnp.asarray([3, r, 0], jnp.int32)
    q = _rand((b, hkv, g, d), seed=23)

    fused = _run_kernel(q, pool, pt, n_valid, n_res)

    # legacy two-launch path, from the dequantized main segment
    kk, vv = pool.gather_dequant(pt, jnp.float32)
    s_main = p * r
    scores = jnp.einsum("bhgd,bhsd->bhgs", q, kk) / jnp.sqrt(d)
    mask = (jnp.arange(s_main)[None, :] < n_valid[:, None])[:, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    m_main = jnp.max(scores, axis=-1)
    pm = jnp.exp(scores - m_main[..., None]) * mask
    l_main = jnp.sum(pm, axis=-1)
    o_main = jnp.einsum("bhgs,bhsd->bhgd", pm, vv)
    res = _residual_partial(q, pool.k_res, pool.v_res, n_res)
    merged = kref.softmax_merge([(o_main, m_main, l_main), res])
    np.testing.assert_allclose(np.asarray(fused), np.asarray(merged),
                               rtol=3e-5, atol=3e-5)


def test_fused_ignores_garbage_past_live_pages():
    """Work-proportionality safety: entries of the page table past a slot's
    live range must not affect its output (those grid steps alias the last
    live block and are compute-skipped)."""
    b, hkv, g, d, r, p = 2, 2, 2, 64, 32, 4
    pool = _mk_pool((4, 4), MODE_PER_TOKEN, b, hkv, d, r, 1 + b * p, seed=31)
    n_valid = jnp.asarray([2 * r, r], jnp.int32)
    n_res = jnp.asarray([4, 2], jnp.int32)
    q = _rand((b, hkv, g, d), seed=33)
    pt_a = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    pt_b = jnp.asarray([[1, 2, 8, 7], [5, 1, 2, 3]], jnp.int32)  # junk tail
    o_a = _run_kernel(q, pool, pt_a, n_valid, n_res)
    o_b = _run_kernel(q, pool, pt_b, n_valid, n_res)
    np.testing.assert_array_equal(np.asarray(o_a), np.asarray(o_b))


# ========================================================= engine identity
@pytest.fixture(scope="module")
def tiny_api():
    cfg = ModelConfig(name="fused-tiny", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=61, q_chunk=16, kv_group_size=R)
    return build_model(cfg)


@pytest.fixture(scope="module")
def tiny_params(tiny_api):
    return tiny_api.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sched():
    return KVTunerSchedule.uniform(2, PrecisionPair(8, 4))


def _engine_outputs(api, params, sched, prompts, max_new=6, eos_id=None,
                    arrivals=None, **kw):
    eng = ContinuousEngine(api, params, sched, max_batch=2, max_seq=40, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=np.asarray(p), max_new_tokens=max_new,
                           eos_id=eos_id,
                           arrival_step=0 if arrivals is None else arrivals[i]))
    done = sorted(eng.run(), key=lambda r: r.uid)
    return [r.output for r in done], eng


@pytest.mark.parametrize("use_pallas", [False, True])
def test_horizon_token_identity(tiny_api, tiny_params, sched, use_pallas):
    """Greedy outputs must be identical for H=1 and H>1, pallas on/off."""
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, 61, n) for n in (12, 7, 19)]
    base, _ = _engine_outputs(tiny_api, tiny_params, sched, prompts)
    for h in (2, 4):
        out, eng = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                                   use_pallas=use_pallas, decode_horizon=h)
        assert out == base, f"h={h} use_pallas={use_pallas}"
        assert eng.alloc.free_blocks == eng.num_blocks - 1


def test_horizon_eos_mid_chunk(tiny_api, tiny_params, sched):
    """EOS inside a horizon chunk: the device liveness mask must stop the
    slot exactly where the per-step engine would."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 61, 11) for _ in range(3)]
    dry, _ = _engine_outputs(tiny_api, tiny_params, sched, prompts, max_new=8)
    eos = dry[0][1]  # request 0 finishes after 2 tokens

    ref, _ = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                             max_new=8, eos_id=eos)
    out, eng = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                               max_new=8, eos_id=eos, decode_horizon=3,
                               use_pallas=True)
    assert out == ref
    assert eng.alloc.free_blocks == eng.num_blocks - 1


def test_horizon_with_arrivals(tiny_api, tiny_params, sched):
    """Requests arriving mid-horizon are admitted at the next host sync;
    outputs stay identical to the per-step engine."""
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, 61, n) for n in (8, 8, 16)]
    ref, _ = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                             max_new=4)
    out, eng = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                               max_new=4, arrivals=[0, 3, 6],
                               decode_horizon=4)
    assert out == ref
    assert eng.stats.decode_steps % 4 == 0


def test_horizon_stats_populated(tiny_api, tiny_params, sched):
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, 61, 10) for _ in range(2)]
    _, eng = _engine_outputs(tiny_api, tiny_params, sched, prompts,
                             max_new=5, decode_horizon=2)
    st = eng.stats
    # one wall sample per DISPATCH (horizon=2 → half the step count), with
    # the fused step counts carried alongside instead of smeared samples
    assert len(st.step_wall_times) == st.decode_dispatches > 0
    assert st.decode_steps == 2 * st.decode_dispatches
    assert st.decode_p95_ms >= st.decode_p50_ms > 0.0
    assert st.decode_tokens_per_s > 0.0


def test_invalid_horizon_rejected(tiny_api, tiny_params, sched):
    with pytest.raises(ValueError):
        ContinuousEngine(tiny_api, tiny_params, sched, decode_horizon=0)
