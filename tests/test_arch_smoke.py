"""Per-architecture smoke tests: instantiate the REDUCED same-family config,
run one forward + one train step on CPU, assert output shapes + no NaNs.
(The FULL configs are exercised via the dry-run on placeholder devices.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_CONFIGS, SMOKE_CONFIGS
from repro.configs.base import supported_shapes
from repro.core.precision import KVTunerSchedule, PrecisionPair
from repro.models.registry import build_model
from repro.training.optimizer import AdamW
from repro.training.trainer import TrainState, make_train_step

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 32


def _batch_for(cfg, rng):
    if cfg.is_encoder:
        return {
            "frames": jax.random.normal(rng, (B, S, cfg.frontend_dim),
                                        jnp.bfloat16),
            "mask": jax.random.bernoulli(rng, 0.2, (B, S)),
            "targets": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        }
    if cfg.family == "vlm":
        s_img = min(cfg.image_tokens, 8)
        return {
            "tokens": jax.random.randint(rng, (B, S - s_img), 0, cfg.vocab_size),
            "patch_embeds": jax.random.normal(rng, (B, s_img, cfg.vision_dim),
                                              jnp.bfloat16),
        }
    return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", sorted(SMOKE_CONFIGS))
def test_smoke_forward_and_train_step(arch):
    cfg = SMOKE_CONFIGS[arch]()
    api = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = api.init(rng)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))

    logits, aux = api.forward(params, batch)
    b_eff = B
    s_eff = S if cfg.family != "vlm" else batch["tokens"].shape[1] + \
        batch["patch_embeds"].shape[1]
    assert logits.shape == (b_eff, s_eff, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any()), \
        f"{arch}: NaN logits"

    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(api, opt))
    state = TrainState(params=params, opt=opt.init(params), ef=None)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: non-finite loss"
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", [a for a in sorted(SMOKE_CONFIGS)
                                  if not SMOKE_CONFIGS[a]().is_encoder])
def test_smoke_prefill_decode(arch):
    """Decode parity: one decode step after prefill ≈ the full-forward logits
    at that position (mixed-precision cache ⇒ bounded deviation)."""
    cfg = SMOKE_CONFIGS[arch]()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    toks = batch["tokens"]
    n_attn = len(cfg.attention_layers())
    sched = KVTunerSchedule.uniform(n_attn, PrecisionPair(8, 8)) if n_attn \
        else None

    pre = dict(batch)
    pre["tokens"] = toks[:, :-1]
    _, state = api.prefill(params, pre, sched, capacity=S + 8)
    logits, state2 = api.decode_step(params, state, toks[:, -1:])
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    full, _ = api.forward(params, batch)
    err = float(jnp.max(jnp.abs(logits.astype(jnp.float32) -
                                full[:, -1].astype(jnp.float32))))
    assert err < 0.75, f"{arch}: decode diverges from forward ({err})"
    assert int(state2.pos[0]) == int(state.pos[0]) + 1


@pytest.mark.parametrize("arch", sorted(ARCH_CONFIGS))
def test_full_config_metadata(arch):
    """Full configs build, expose the assigned hyperparameters, and report
    plausible parameter counts (no allocation — metadata only)."""
    cfg = ARCH_CONFIGS[arch]()
    assert cfg.num_layers >= 12
    assert cfg.vocab_size > 0
    n = cfg.param_count()
    expected = {
        "tinyllama-1.1b": 1.1e9, "llava-next-mistral-7b": 7.2e9,
        "gemma3-27b": 27e9, "deepseek-67b": 67e9, "gemma3-12b": 12e9,
        "xlstm-125m": 0.125e9, "arctic-480b": 480e9, "grok-1-314b": 314e9,
        "jamba-v0.1-52b": 52e9, "hubert-xlarge": 1.0e9,
        "paper-llama3.1-8b": 8e9,
    }[arch]
    assert 0.5 * expected < n < 1.7 * expected, \
        f"{arch}: param count {n/1e9:.2f}B vs expected ~{expected/1e9:.1f}B"
    shapes = supported_shapes(cfg)
    assert any(s.name == "train_4k" for s in shapes)
    if cfg.is_encoder:
        assert all(s.kind != "decode" for s in shapes)


def test_shape_cell_skip_rules():
    """Exact applicability table from DESIGN.md §5."""
    expect_long = {"gemma3-27b", "gemma3-12b", "xlstm-125m", "jamba-v0.1-52b"}
    for arch, cfg_fn in ARCH_CONFIGS.items():
        if arch == "paper-llama3.1-8b":
            continue
        names = {s.name for s in supported_shapes(cfg_fn())}
        assert ("long_500k" in names) == (arch in expect_long), arch
        assert ("decode_32k" in names) == (arch != "hubert-xlarge"), arch
