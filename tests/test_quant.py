"""Unit + property tests for the quantization core (paper eq. 2 semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean machine: sample a deterministic grid instead
    import random

    class _Strategies:
        @staticmethod
        def sampled_from(vals):
            return list(vals)

        @staticmethod
        def integers(lo, hi):
            return [lo, hi, (lo + hi) // 2, min(lo + 7, hi)]

    st = _Strategies()

    def settings(**_kw):
        return lambda fn: fn

    def given(**strategies):
        names = sorted(strategies)
        rng = random.Random(0)
        cases = [{n: rng.choice(strategies[n]) for n in names}
                 for _ in range(10)]

        def deco(fn):
            def wrapper(*a, **kw):
                for case in cases:
                    fn(*a, **case, **kw)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

from repro.core import quant
from repro.core.precision import (
    MODE_KIVI, MODE_PER_CHANNEL, MODE_PER_TOKEN, KVTunerSchedule, PrecisionPair,
    pareto_front,
)

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=dtype)


# ------------------------------------------------------------------ packing
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_pack_roundtrip(bits):
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 2 ** bits, size=(3, 5, 16, 64), dtype=np.uint8)
    packed = quant.pack_codes(jnp.asarray(codes), bits)
    assert packed.dtype == jnp.uint8
    assert packed.shape[-1] == 64 * bits // 8
    out = quant.unpack_codes(packed, bits)
    np.testing.assert_array_equal(np.asarray(out), codes)


def test_pack_rejects_bad_dim():
    with pytest.raises(ValueError):
        quant.pack_codes(jnp.zeros((4, 3), jnp.uint8), 4)  # 3 % 2 != 0


# -------------------------------------------------------------- quant/dequant
@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("mode", [MODE_PER_TOKEN, MODE_PER_CHANNEL])
def test_quantize_dequantize_matches_fake_quant(bits, mode):
    x = _rand((2, 4, 64, 32), seed=1)
    qt = quant.quantize(x, bits, mode, group_size=32)
    deq = quant.dequantize(qt)
    fq = quant.fake_quant(x, bits, mode, group_size=32)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(fq), rtol=1e-5, atol=1e-5)
    assert deq.shape == x.shape and deq.dtype == x.dtype


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_error_decreases_with_bits(bits):
    x = _rand((2, 2, 128, 64), seed=2)
    e = float(quant.relative_error(x, quant.fake_quant(x, bits, MODE_PER_TOKEN)))
    if bits > 2:
        e_lower = float(
            quant.relative_error(x, quant.fake_quant(x, bits // 2, MODE_PER_TOKEN)))
        assert e < e_lower


def test_8bit_nearly_lossless():
    x = _rand((1, 2, 64, 64), seed=3)
    e = float(quant.relative_error(x, quant.fake_quant(x, 8, MODE_PER_TOKEN)))
    assert e < 0.05  # paper Table 9: KV8 errors ~1e-2


def test_per_channel_beats_per_token_with_channel_outliers():
    """Paper §4.2: keys have strong channel-wise outliers → per-channel wins."""
    x = _rand((1, 2, 128, 64), seed=4)
    outlier_scale = jnp.where(jnp.arange(64) % 16 == 0, 20.0, 1.0)
    x = x * outlier_scale  # inflate a few channels, as observed for key caches
    e_tok = float(quant.relative_error(x, quant.fake_quant(x, 4, MODE_PER_TOKEN)))
    e_ch = float(quant.relative_error(x, quant.fake_quant(x, 4, MODE_PER_CHANNEL)))
    assert e_ch < e_tok


def test_dynamic_matches_static():
    x = _rand((2, 2, 64, 32), seed=5)
    for bits in (2, 4, 8):
        a = quant.fake_quant(x, bits, MODE_PER_TOKEN)
        b = quant.fake_quant_dynamic(x, jnp.float32(bits), MODE_PER_TOKEN)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    # bits >= 16 is a passthrough
    c = quant.fake_quant_dynamic(x, jnp.float32(16), MODE_PER_TOKEN)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(x))


def test_dynamic_single_jit_no_retrace():
    x = _rand((1, 2, 64, 32), seed=6)
    traces = []

    @jax.jit
    def f(x, bits):
        traces.append(1)
        return quant.fake_quant_dynamic(x, bits, MODE_PER_TOKEN)

    for b in (2.0, 4.0, 8.0, 16.0):
        f(x, jnp.float32(b)).block_until_ready()
    assert len(traces) == 1  # the whole point of the dynamic path


def test_kivi_mode_resolution():
    x = _rand((1, 2, 64, 32), seed=7)
    k_hat, v_hat = quant.fake_quant_kv_dynamic(
        x, x, jnp.float32(4), jnp.float32(4), MODE_KIVI)
    k_ref = quant.fake_quant(x, 4, MODE_PER_CHANNEL)
    v_ref = quant.fake_quant(x, 4, MODE_PER_TOKEN)
    np.testing.assert_allclose(np.asarray(k_hat), np.asarray(k_ref), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(v_hat), np.asarray(v_ref), rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------- property tests
@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8]),
    seq=st.sampled_from([32, 64, 128]),
    dim=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2 ** 16),
)
def test_property_dequant_within_one_level(bits, seq, dim, seed):
    """|x - x̂| ≤ scale/2 + float slack everywhere (RTN invariant)."""
    x = np.asarray(_rand((1, 1, seq, dim), seed=seed))
    qt = quant.quantize(jnp.asarray(x), bits, MODE_PER_TOKEN, group_size=dim)
    deq = np.asarray(quant.dequantize(qt))
    scale = np.broadcast_to(np.asarray(qt.scale), (1, 1, seq, 1, 1)).max()
    assert np.max(np.abs(x - deq)) <= scale / 2 + 1e-4


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2 ** 16),
)
def test_property_quant_idempotent(bits, seed):
    """fake_quant(fake_quant(x)) == fake_quant(x): quantized grids are fixed points."""
    x = _rand((1, 1, 32, 32), seed=seed)
    once = quant.fake_quant(x, bits, MODE_PER_TOKEN)
    twice = quant.fake_quant(once, bits, MODE_PER_TOKEN)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice), rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(kb=st.sampled_from([2, 4, 8, 16]), vb=st.sampled_from([2, 4, 8, 16]))
def test_property_schedule_equivalent_bits(kb, vb):
    sched = KVTunerSchedule.uniform(12, PrecisionPair(kb, vb))
    assert sched.equivalent_bits == pytest.approx((kb + vb) / 2)


# ------------------------------------------------------------------ datatypes
def test_precision_pair_names():
    assert PrecisionPair(8, 4).name == "K8V4"
    assert PrecisionPair(4, 4).name == "KV4"
    assert PrecisionPair.from_name("K8V2") == PrecisionPair(8, 2)
    assert PrecisionPair.from_name("KV8") == PrecisionPair(8, 8)
    with pytest.raises(ValueError):
        PrecisionPair(3, 4)


def test_schedule_roundtrip(tmp_path):
    sched = KVTunerSchedule.from_groups(
        4, groups=[[0, 3], [1, 2]],
        group_pairs=[PrecisionPair(8, 4), PrecisionPair(4, 2)], model_name="t")
    p = tmp_path / "sched.json"
    sched.save(p)
    back = KVTunerSchedule.load(p)
    assert back.pairs == sched.pairs
    assert back.groups == [[0, 3], [1, 2]]
    assert back.equivalent_bits == pytest.approx((8 + 4 + 4 + 2 + 4 + 2 + 8 + 4) / 8)


def test_pareto_front_basic():
    pts = [(1, 5), (2, 2), (3, 3), (5, 1), (4, 4)]
    assert sorted(pareto_front(pts)) == [0, 1, 3]
