"""KVTuner pipeline tests: sensitivity → pruning → clustering → NSGA-II,
end-to-end on a tiny model. Validates the paper's qualitative claims at
miniature scale (K > V importance, Pareto structure, search-space reduction)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import sensitivity
from repro.core.clustering import cluster_layers, dbscan
from repro.core.moo import NSGA2, crowding_distance, non_dominated_sort
from repro.core.precision import (CANDIDATE_PAIRS, MODE_PER_TOKEN,
                                  PrecisionPair)
from repro.core.pruning import prune_intra_layer
from repro.core.tuner import KVTuner
from repro.models.registry import build_model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = ModelConfig(name="tiny", family="dense", num_layers=4, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                      q_chunk=16)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(i), (2, 32), 0, 97)}
               for i in range(2)]
    return api, params, batches


@pytest.fixture(scope="module")
def tiny_errors(tiny_setup):
    api, params, batches = tiny_setup
    caps = sensitivity.capture_activations(api, params, batches)
    errors = sensitivity.layer_errors(caps, api.cfg, MODE_PER_TOKEN)
    return api, params, batches, caps, errors


# ------------------------------------------------------------- sensitivity
def test_capture_shapes(tiny_errors):
    api, params, batches, caps, _ = tiny_errors
    assert len(caps) == 4
    assert caps[0]["k"].shape == (4, 32, 2, 16)  # [B*, S, Hkv, hd]
    assert caps[0]["q"].shape == (4, 32, 4, 16)


def test_errors_monotone_in_bits(tiny_errors):
    *_, errors = tiny_errors
    pairs = {p.name: i for i, p in enumerate(errors.pairs)}
    eo = errors.e_o.mean(axis=0)
    assert eo[pairs["KV8"]] < eo[pairs["KV4"]] < eo[pairs["KV2"]]


def test_key_more_important_than_value_kivi():
    """Paper §4.3 / Table 3: at equal memory, high-K beats high-V.

    Uses synthetic captures with trained-LLM key statistics (channel-wise
    outliers, content-dependent attention — §4.2); a randomly-initialized
    model has flat attention and cannot exhibit the asymmetry. The full claim
    on a *trained* model is exercised in tests/test_trained_claims.py and
    benchmarks/table3_output_error.py.
    """
    from repro.core.precision import MODE_KIVI

    rng = np.random.default_rng(0)
    b, s, h, hkv, hd = 2, 64, 4, 2, 32
    out_scale = np.where(rng.random(hd) < 0.1, 8.0, 1.0)
    k = rng.normal(size=(b, s, hkv, hd)) * out_scale
    v = rng.normal(size=(b, s, hkv, hd))
    q = np.zeros((b, s, h, hd))
    for bi in range(b):
        for t in range(s):
            for hi in range(h):
                kk = k[bi, :, hi % hkv]
                i1, i2 = rng.integers(0, s, 2)
                q[bi, t, hi] = 1.2 * (kk[i1] + 0.7 * kk[i2]) / np.sqrt(hd) \
                    + 0.1 * rng.normal(size=hd)
    caps = [{"q": jnp.asarray(q, jnp.float32), "k": jnp.asarray(k, jnp.float32),
             "v": jnp.asarray(v, jnp.float32), "o": jnp.zeros((b, s, h, hd))}]

    class C:
        q_per_kv = h // hkv
        kv_group_size = 32

    errors = sensitivity.layer_errors(caps, C, MODE_KIVI)
    names = {p.name: i for i, p in enumerate(errors.pairs)}
    eo = errors.e_o.mean(axis=0)
    assert eo[names["K8V4"]] < eo[names["K4V8"]]
    assert eo[names["K4V2"]] < eo[names["K2V4"]]
    # and per-token key error exceeds value error under channel outliers
    errors_tok = sensitivity.layer_errors(caps, C, MODE_PER_TOKEN)
    ek = errors_tok.e_k.mean(axis=0)
    ev = errors_tok.e_v.mean(axis=0)
    assert ek[names["KV4"]] > ev[names["KV4"]]


def test_attention_score_error_scales(tiny_errors):
    *_, errors = tiny_errors
    pairs = {p.name: i for i, p in enumerate(errors.pairs)}
    ea = errors.e_a.mean(axis=0)
    assert ea[pairs["KV2"]] > 3 * ea[pairs["KV8"]]  # paper: ~64x at full scale


# ----------------------------------------------------------------- pruning
def test_pruning_keeps_pareto_only(tiny_errors):
    *_, errors = tiny_errors
    pruned = prune_intra_layer(errors)
    assert pruned.num_layers == 4
    for l in range(4):
        kept = pruned.keep[l]
        assert len(kept) >= 2
        bits = [errors.pairs[i].equivalent_bits for i in kept]
        eo = [errors.e_o[l, i] for i in kept]
        # frontier property: sorted by bits desc → error must increase
        order = np.argsort(bits)[::-1]
        eo_sorted = np.asarray(eo)[order]
        assert all(eo_sorted[i] <= eo_sorted[i + 1] + 1e-9
                   for i in range(len(eo_sorted) - 1))
    assert pruned.space_size() < len(CANDIDATE_PAIRS) ** 4


# -------------------------------------------------------------- clustering
def test_dbscan_basic():
    x = np.concatenate([np.zeros((3, 2)), np.ones((3, 2)),
                        np.asarray([[5.0, 5.0]])])
    labels = dbscan(x, eps=0.5, min_samples=2)
    assert labels[0] == labels[1] == labels[2]
    assert labels[3] == labels[4] == labels[5] != labels[0]
    assert labels[6] == -1


def test_cluster_layers(tiny_errors):
    *_, errors = tiny_errors
    pruned = prune_intra_layer(errors)
    groups = cluster_layers(pruned, eps=0.3)
    all_layers = sorted(l for g in groups.groups for l in g)
    assert all_layers == [0, 1, 2, 3]  # partition property
    assert groups.search_space_size() <= pruned.space_size()


# ------------------------------------------------------------------- NSGA2
def test_non_dominated_sort():
    obj = np.asarray([[1, 5], [2, 2], [3, 3], [5, 1], [4, 4]], float)
    fronts = non_dominated_sort(obj)
    assert sorted(fronts[0].tolist()) == [0, 1, 3]


def test_crowding_extremes_infinite():
    obj = np.asarray([[1, 4], [2, 3], [3, 2], [4, 1]], float)
    cd = crowding_distance(obj)
    assert np.isinf(cd[0]) and np.isinf(cd[3])


def test_nsga2_finds_known_frontier():
    """Synthetic separable problem with a known Pareto front."""
    weights = [3, 2, 1, 1]

    def evaluate(g):
        bits = sum((c + 1) * 2 * w for c, w in zip(g, weights))
        loss = sum((3 - c) ** 2 * w for c, w in zip(g, weights))
        return float(bits), float(loss)

    nsga = NSGA2([4, 4, 4, 4], evaluate, pop_size=24, seed=1)
    # seeded with uniform extremes, as tuner.search seeds uniform schedules
    res = nsga.run(generations=15, seeds=[(0, 0, 0, 0), (3, 3, 3, 3)])
    front_objs = res.objectives[res.front]
    # frontier must include both extremes of the trade-off
    assert front_objs[:, 0].min() == pytest.approx(2 * sum(weights))
    assert front_objs[:, 1].min() == pytest.approx(0.0)
    assert res.evaluations <= 4 ** 4  # memoization caps total evals
    # every front point is actually non-dominated in the true problem
    for i in res.front:
        b0, l0 = res.objectives[i]
        assert not any((b1 <= b0 and l1 < l0) or (b1 < b0 and l1 <= l0)
                       for b1, l1 in res.objectives)


# ------------------------------------------------------------- end-to-end
def test_tuner_end_to_end(tiny_setup):
    api, params, batches = tiny_setup
    tuner = KVTuner(api, params, mode=MODE_PER_TOKEN)
    report = tuner.search(batches, generations=3, pop_size=8, seed=0)
    assert report.frontier, "empty Pareto frontier"
    full, pruned, grouped = report.space_reduction()
    assert grouped <= pruned <= full
    for sched in report.frontier:
        assert len(sched) == 4
        assert 2.0 <= sched.equivalent_bits <= 8.0
        assert sched.objectives is not None
    # frontier is sorted by bits and non-dominated
    bits = [s.objectives["bits"] for s in report.frontier]
    losses = [s.objectives["loss"] for s in report.frontier]
    assert bits == sorted(bits)
    for i in range(len(losses) - 1):
        assert losses[i] >= losses[i + 1] - 1e-9


def test_schedule_applies_to_serving(tiny_setup):
    """A searched schedule runs through prefill/decode (deployment path)."""
    api, params, batches = tiny_setup
    sched = pytest.importorskip("repro.core.precision").KVTunerSchedule.uniform(
        4, PrecisionPair(4, 2))
    toks = batches[0]["tokens"]
    _, state = api.prefill(params, {"tokens": toks[:, :-1]}, sched,
                           capacity=40)
    logits, state = api.decode_step(params, state, toks[:, -1:])
    assert logits.shape == (2, 97)
    assert not bool(jnp.isnan(logits).any())
