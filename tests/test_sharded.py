"""Multi-device paged serving tests (8 forced host CPU devices — see
``tests/conftest.py``).

Covers the mesh-sharded serving stack end-to-end: the paged pool's packed
codes/scales and residual windows split by KV head over the ``model`` mesh
axis while page table, lengths and weights replicate; greedy outputs of
``ContinuousEngine(mesh=...)`` are token-identical to the single-device
engine across kernel on/off × decode horizon × speculative decode (plain
and fused verify) and under the full feature composition (prefix cache,
batched admission, host-tier preemption, audit); per-shard analytic KV
stream bytes are exactly 1/N of the global counters (no KV all-gather on
the decode path); and the infeasible-shard fallback (KV heads not
divisible by the mesh axis) degrades to replicated, still-identical
serving instead of crashing.
"""
import jax
import numpy as np
import pytest

from repro.cache.paged import PagedKVPool
from repro.configs.base import ModelConfig
from repro.core.precision import KVTunerSchedule, PrecisionPair
from repro.launch.mesh import make_test_mesh
from repro.models.registry import build_model
from repro.serving.engine import ContinuousEngine, Request

jax.config.update("jax_platform_name", "cpu")

R = 8
N_DEV = 8

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < N_DEV,
    reason="needs 8 host devices (tests/conftest.py sets XLA_FLAGS before "
           "jax init; something initialized jax earlier)")


# =========================================================== fixtures
@pytest.fixture(scope="module")
def tiny_api():
    # num_kv_heads=8 divides the 8-wide model axis exactly (1 KV head per
    # device); num_heads=16 keeps GQA (2 q heads per KV head) in play
    cfg = ModelConfig(name="sharded-tiny", family="dense", num_layers=2,
                      d_model=64, num_heads=16, num_kv_heads=8, d_ff=128,
                      vocab_size=61, q_chunk=16, kv_group_size=R)
    return build_model(cfg)


@pytest.fixture(scope="module")
def tiny_params(tiny_api):
    return tiny_api.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sched():
    return KVTunerSchedule.uniform(2, PrecisionPair(8, 4))


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh(N_DEV)


def _workload(seed=1, n=4, max_new=8):
    rng = np.random.default_rng(seed)
    tpl = rng.integers(1, 60, 16)
    prompts = [np.concatenate([tpl, rng.integers(1, 60, 1 + i % 4)])
               for i in range(n)]
    return [Request(uid=i, prompt=p.astype(np.int32), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]


def _run(engine, reqs):
    for r in reqs:
        engine.submit(r)
    done = sorted(engine.run(), key=lambda r: r.uid)
    engine.alloc.assert_consistent()
    return [list(r.output) for r in done]


def _engine(api, params, sched, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    return ContinuousEngine(api, params, sched, **kw)


@pytest.fixture(scope="module")
def reference(tiny_api, tiny_params, sched):
    """Single-device greedy outputs every mesh config must reproduce."""
    return _run(_engine(tiny_api, tiny_params, sched), _workload())


# ====================================================== state placement
def test_pool_arrays_sharded_by_kv_head(tiny_api, tiny_params, sched, mesh):
    """Packed codes/scales and residual windows split Hkv over `model`
    (one head per device here); page table and lengths replicate."""
    eng = _engine(tiny_api, tiny_params, sched, mesh=mesh)
    assert eng.stats.n_shards == N_DEV
    pool = eng.state.pools[0]
    hkv = tiny_api.cfg.num_kv_heads
    for name in ("k_codes", "v_codes", "k_res", "v_res"):
        arr = getattr(pool, name)
        spec = arr.sharding.spec
        assert spec[1] == "model", (name, spec)
        local = arr.addressable_shards[0].data.shape
        assert local[1] == hkv // N_DEV, (name, local)
    # quantized scales shard too (dim 1 is Hkv whenever ndim >= 2)
    if pool.k_scale.ndim >= 2:
        assert pool.k_scale.sharding.spec[1] == "model"
    for name in ("page_table", "lengths"):
        spec = getattr(eng.state, name).sharding.spec
        assert all(p is None for p in spec), (name, spec)


def test_infeasible_heads_fall_back_replicated(sched, mesh):
    """KV heads not divisible by the axis (2 % 8): the engine serves
    replicated (n_shards=1) instead of crashing, outputs unchanged."""
    cfg = ModelConfig(name="sharded-odd", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=61, q_chunk=16, kv_group_size=R)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    sch = KVTunerSchedule.uniform(2, PrecisionPair(8, 4))
    ref = _run(_engine(api, params, sch), _workload())
    eng = _engine(api, params, sch, mesh=mesh)
    assert eng.stats.n_shards == 1
    assert _run(eng, _workload()) == ref


# ================================================== greedy token identity
@pytest.mark.parametrize("kw", [
    dict(),
    dict(use_pallas=True),
    dict(decode_horizon=3),
    dict(use_pallas=True, decode_horizon=3),
    dict(speculate_k=2),
    dict(speculate_k=2, fused_verify=True),
    dict(speculate_k=2, use_pallas=True),
], ids=["xla", "pallas", "horizon3", "pallas-h3", "spec2", "spec2-fused",
        "spec2-pallas"])
def test_mesh_token_identity(tiny_api, tiny_params, sched, mesh, reference,
                             kw):
    """The acceptance property: sharding the pool over 8 devices changes
    where bytes live, never which tokens come out — across kernel on/off ×
    decode horizon × speculative decode (plain + fused verify)."""
    eng = _engine(tiny_api, tiny_params, sched, mesh=mesh, **kw)
    assert _run(eng, _workload()) == reference
    assert eng.stats.n_shards == N_DEV
    # the decode step still compiles exactly once on the mesh
    if not kw.get("speculate_k") and kw.get("decode_horizon", 1) == 1:
        assert eng.decode_compilations == 1


def test_mesh_full_composition_with_preemption(tiny_api, tiny_params, sched,
                                               mesh):
    """Prefix cache + batched admission path + priority scheduler +
    undersized pool forcing host-tier swap-out/swap-in, auditor on: the
    mesh engine survives the same gauntlet as the single-device engine,
    token-identically."""
    def work():
        rng = np.random.default_rng(5)
        tpl = rng.integers(1, 60, 24)
        prompts = [np.concatenate([tpl, rng.integers(1, 60, 5)])
                   for _ in range(6)]
        return [Request(uid=i, prompt=p.astype(np.int32),
                        max_new_tokens=[12, 12, 6, 6, 5, 5][i],
                        arrival_step=[0, 0, 3, 5, 8, 11][i],
                        priority=[0, 0, 2, 3, 4, 5][i])
                for i, p in enumerate(prompts)]

    base = _run(_engine(tiny_api, tiny_params, sched, prefix_cache=True,
                        prefill_chunk=16, scheduler="priority"), work())
    eng = _engine(tiny_api, tiny_params, sched, mesh=mesh, prefix_cache=True,
                  prefill_chunk=16, scheduler="priority", num_blocks=14,
                  host_blocks=10, audit=True)
    assert _run(eng, work()) == base
    assert eng.stats.preemptions > 0 and eng.stats.resumes > 0
    assert eng.stats.swap_out_blocks > 0


def test_mesh_batched_admission_identity(tiny_api, tiny_params, sched, mesh,
                                         reference):
    eng = _engine(tiny_api, tiny_params, sched, mesh=mesh,
                  prefix_cache=True, batched_admission=True)
    assert _run(eng, _workload()) == reference
    assert eng.stats.prefix_hits + eng.stats.prefix_misses > 0


# ===================================================== per-shard accounting
def test_per_shard_stream_bytes_exact_fraction():
    """Every analytic byte counter is proportional to Hkv, so a KV-head
    shard streams EXACTLY total/N — the "no KV all-gather" invariant."""
    pool = PagedKVPool.init(num_blocks=10, max_slots=2, kv_heads=8,
                            head_dim=16, pair=PrecisionPair(8, 4),
                            group_size=R)
    lens = [37, 12]
    for n in (2, 4, 8):
        assert pool.block_bytes(n_shards=n) * n == pool.block_bytes()
        assert pool.decode_stream_bytes(lens, n_shards=n) * n == \
            pool.decode_stream_bytes(lens)
        assert pool.verify_stream_bytes(lens, 3, n_shards=n) * n == \
            pool.verify_stream_bytes(lens, 3)
        assert pool.prefill_stream_bytes(lens, 16, n_shards=n) * n == \
            pool.prefill_stream_bytes(lens, 16)
    with pytest.raises(ValueError):
        pool.decode_stream_bytes(lens, n_shards=3)   # 8 % 3 != 0


def test_engine_shard_stats(tiny_api, tiny_params, sched, mesh):
    eng = _engine(tiny_api, tiny_params, sched, mesh=mesh)
    _run(eng, _workload())
    s = eng.stats
    assert s.n_shards == N_DEV
    assert len(s.shard_pool_utilization) == N_DEV
    assert len(s.shard_pool_high_watermark) == N_DEV
    # allocation is global: per-shard occupancy is uniform and matches it
    assert all(u == s.pool_utilization for u in s.shard_pool_utilization)
    assert all(w == s.pool_high_watermark
               for w in s.shard_pool_high_watermark)
    assert max(s.shard_pool_high_watermark) > 0
