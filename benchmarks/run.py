"""Benchmark harness — one module per paper table. Prints
``name,us_per_call,derived`` CSV rows plus per-table claim checks; full
structured results land in experiments/artifacts/bench_results.json.

Run: PYTHONPATH=src python -m benchmarks.run [--fast] [--tables t2,t5,...]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "artifacts", "bench_results.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced search budgets")
    ap.add_argument("--tables", default="all")
    args = ap.parse_args()

    from benchmarks import (common, kernels_micro, table2_ppl,
                            table3_output_error, table4_pruning,
                            table5_accuracy, table8_throughput,
                            table9_error, table10_clustering,
                            table11_prefix, table12_offload, table13_chaos,
                            table14_sharded, table15_telemetry)

    print("# KVTuner reproduction benchmarks (paper tables)", flush=True)
    ctx = common.get_bench_model(log=lambda *a: print(*a, flush=True))

    tables = {
        "t9_error": lambda: table9_error.run(ctx),
        "t3_output_error": lambda: table3_output_error.run(ctx),
        "t2_ppl": lambda: table2_ppl.run(ctx),
        "t4_pruning": lambda: table4_pruning.run(ctx),
        "t10_clustering": lambda: table10_clustering.run(ctx),
        "t5_accuracy": lambda: table5_accuracy.run(
            ctx, generations=3 if args.fast else 6,
            pop=8 if args.fast else 16),
        "t8_throughput": lambda: table8_throughput.run(
            ctx, n_prompts=4 if args.fast else 8),
        "t8_engines": lambda: table8_throughput.run_engines(
            ctx, n_requests=6 if args.fast else 10,
            max_new=6 if args.fast else 8),
        "t8_speculative": lambda: table8_throughput.run_speculative(
            ctx, per_template=2 if args.fast else 3,
            max_new=64 if args.fast else 96),
        "t11_prefix": lambda: table11_prefix.run(
            ctx, per_template=2 if args.fast else 4,
            max_new=4 if args.fast else 8),
        "t12_offload": lambda: table12_offload.run(
            ctx, per_template=2 if args.fast else 4,
            max_new=4 if args.fast else 8),
        "t13_chaos": lambda: table13_chaos.run(
            ctx, per_template=2 if args.fast else 4,
            max_new=6 if args.fast else 10),
        # runs in a subprocess: the 8-device host flag must be set before
        # jax initializes, and this parent already initialized it
        "t14_sharded": lambda: table14_sharded.run_subprocess(
            tiny=args.fast),
        "t15_telemetry": lambda: table15_telemetry.run(
            ctx, per_template=2 if args.fast else 3,
            max_new=8 if args.fast else 16),
        "kernels_micro": lambda: kernels_micro.run(ctx),
        "kernels_paged": lambda: kernels_micro.run_paged(ctx),
        "kernels_prefill": lambda: kernels_micro.run_prefill(ctx),
        "kernels_verify": lambda: kernels_micro.run_verify(ctx),
    }
    checkers = {
        "t9_error": table9_error.check_paper_claims,
        "t3_output_error": table3_output_error.check_paper_claims,
        "t2_ppl": table2_ppl.check_paper_claims,
        "t4_pruning": table4_pruning.check_paper_claims,
        "t10_clustering": table10_clustering.check_paper_claims,
        "t5_accuracy": table5_accuracy.check_paper_claims,
        "t8_throughput": table8_throughput.check_paper_claims,
        "t8_engines": table8_throughput.check_engine_claims,
        "t11_prefix": table11_prefix.check_paper_claims,
        "t12_offload": table12_offload.check_paper_claims,
        "t13_chaos": table13_chaos.check_paper_claims,
        "t14_sharded": table14_sharded.check_paper_claims,
        "kernels_micro": kernels_micro.check_paper_claims,
        "kernels_paged": kernels_micro.check_paged_claims,
        "kernels_prefill": kernels_micro.check_prefill_claims,
        "kernels_verify": kernels_micro.check_verify_claims,
        "t8_speculative": table8_throughput.check_speculative_claims,
        "t15_telemetry": table15_telemetry.check_paper_claims,
    }
    wanted = set(tables) if args.tables == "all" else \
        set(args.tables.split(","))

    all_results: dict = {}
    all_claims: dict = {}
    print("name,us_per_call,derived")
    for name, fn in tables.items():
        if name not in wanted:
            continue
        t0 = time.time()
        result = fn()
        us = (time.time() - t0) * 1e6
        all_results[name] = result
        claims = checkers[name](result) if name in checkers else {}
        all_claims[name] = claims
        ok = sum(claims.values())
        print(f"{name},{us:.0f},claims_pass={ok}/{len(claims)}", flush=True)
        for claim, passed in claims.items():
            print(f"#   [{'PASS' if passed else 'FAIL'}] {claim}", flush=True)
        # one machine-readable record per entry: the perf trajectory across
        # PRs is tracked from these files, not stdout
        common.write_bench_json(
            name, result, claims,
            config={"fast": args.fast,
                    "seed_note": "workload seeds are fixed per table"},
            seed=result.get("seed") if isinstance(result, dict) else None)

    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump({"results": all_results, "claims": all_claims}, f, indent=2,
                  default=str)
    total = sum(len(c) for c in all_claims.values())
    passed = sum(sum(c.values()) for c in all_claims.values())
    print(f"# paper-claim checks: {passed}/{total} pass "
          f"(details: {os.path.normpath(RESULTS_PATH)})", flush=True)


if __name__ == "__main__":
    main()
