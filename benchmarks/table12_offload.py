"""Table 12 (systems extension): tiered KV block store under overload.

The workload deliberately exceeds device pool capacity: shared-template
Poisson arrivals whose combined live context cannot fit in ``num_blocks``,
with later arrivals outranking earlier ones (climbing priorities) so the
scheduler must preempt. Two engines run the identical request stream:

* **unconstrained** (baseline): a pool large enough that no pressure ever
  builds — no eviction, no preemption, no tiers.
* **tiered**: a deliberately undersized pool plus a host-RAM block store
  (``host_blocks``), the preemptive ``priority`` scheduler, and the prefix
  cache. Under pressure the engine spills evicted radix chains to the host
  tier (later matches swap them back in — *host-tier prefix hits*) and
  parks preempted victims' packed blocks there (bitwise swap-out/swap-in).

Because swaps are bitwise and preemption/resume replays nothing the device
already holds, the tiered engine must finish **every** request with greedy
outputs token-identical to the unconstrained run — KVTuner's compressed
blocks make the capacity wall soft without touching the math.

Reported: completion, token-identity, swap in/out counts, host-tier prefix
hits, preemptions/resumes, pool+host utilization, tokens/s.

Standalone: ``PYTHONPATH=src python -m benchmarks.table12_offload [--tiny]``
(``--tiny`` drives a milliseconds-scale random model — the CI smoke mode).
"""
from __future__ import annotations

import numpy as np

from repro.core.precision import KVTunerSchedule, PrecisionPair
from repro.serving.engine import ContinuousEngine, Request


def build_workload(vocab: int, n_templates: int, per_template: int,
                   template_len: int, suffix_len: int, seed: int = 0,
                   arrival_rate: float = 2.0):
    """(prompts, arrival_steps, priorities): template-interleaved shared
    prefixes, Poisson inter-arrivals, and monotonically climbing priorities
    (each arrival outranks everything running — the preemption-heavy
    regime). The explicit ``seed`` pins the workload bit-for-bit."""
    from benchmarks.common import poisson_arrivals, shared_template_prompts

    rng = np.random.default_rng(seed)
    prompts = shared_template_prompts(vocab, n_templates, per_template,
                                      template_len, suffix_len, rng)
    arrivals = poisson_arrivals(len(prompts), arrival_rate, rng)
    priorities = list(range(len(prompts)))
    return prompts, arrivals, priorities


def run(ctx, n_templates: int = 3, per_template: int = 4,
        template_len: int = 64, suffix_len: int = 16, max_new: int = 8,
        max_batch: int = 2, seed: int = 0, sched=None,
        prefill_chunk: int | None = None, scheduler: str = "priority",
        use_pallas: bool = False) -> dict:
    cfg = ctx.api.cfg
    if sched is None:
        from repro.launch.steps import default_schedule
        sched = default_schedule(cfg, "kvtuner")
    if prefill_chunk is None:
        prefill_chunk = cfg.kv_group_size
    prompts, arrivals, priorities = build_workload(
        cfg.vocab_size, n_templates, per_template, template_len, suffix_len,
        seed=seed)
    max_seq = template_len + suffix_len + max_new
    r = cfg.kv_group_size
    pages_per_req = max_seq // r + 1
    # undersized device pool: exactly the live batch, NO headroom for cached
    # templates — every admission fights the radix tree for blocks, so
    # chains spill to the host tier and later template reuses must swap in
    small_blocks = 1 + max_batch * pages_per_req
    host_blocks = 2 * n_templates * (template_len // r) + \
        max_batch * pages_per_req

    def drive(num_blocks, tiered: bool):
        eng = ContinuousEngine(
            ctx.api, ctx.params, sched, max_batch=max_batch, max_seq=max_seq,
            num_blocks=num_blocks, prefix_cache=True,
            prefill_chunk=prefill_chunk, seed=seed, use_pallas=use_pallas,
            scheduler=scheduler if tiered else "fcfs",
            host_blocks=host_blocks if tiered else 0)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new,
                               arrival_step=arrivals[i],
                               priority=priorities[i]))
        done = sorted(eng.run(), key=lambda q: q.uid)
        eng.alloc.assert_consistent()
        return done, eng

    base_done, base = drive(num_blocks=None, tiered=False)
    tier_done, tier = drive(num_blocks=small_blocks, tiered=True)

    s = tier.stats
    return {
        "workload": {"n_templates": n_templates,
                     "per_template": per_template,
                     "template_len": template_len, "suffix_len": suffix_len,
                     "max_new": max_new, "seed": seed,
                     "scheduler": scheduler, "use_pallas": use_pallas,
                     "arrival_steps": arrivals},
        "pool": {"unconstrained_blocks": base.num_blocks,
                 "tiered_blocks": small_blocks, "host_blocks": host_blocks,
                 "pages_per_request": pages_per_req},
        "unconstrained": {"tokens_per_s": base.stats.throughput,
                          "prefill_tokens": base.stats.prefill_tokens,
                          "prefix_hits": base.stats.prefix_hits,
                          "pool_high_watermark":
                              base.stats.pool_high_watermark},
        "tiered": {"tokens_per_s": s.throughput,
                   "prefill_tokens": s.prefill_tokens,
                   "prefix_hits": s.prefix_hits,
                   "host_prefix_hits": s.host_prefix_hits,
                   "host_prefix_hit_tokens": s.host_prefix_hit_tokens,
                   "swap_out_blocks": s.swap_out_blocks,
                   "swap_in_blocks": s.swap_in_blocks,
                   "preemptions": s.preemptions, "resumes": s.resumes,
                   "recompute_resumes": s.recompute_resumes,
                   "replay_steps": s.replay_steps,
                   "prefix_spilled_blocks": s.prefix_spilled_blocks,
                   "prefix_dropped_blocks": s.prefix_dropped_blocks,
                   "host_evicted_blocks": s.host_evicted_blocks,
                   "pool_high_watermark": s.pool_high_watermark,
                   "n_shards": s.n_shards,
                   "shard_pool_high_watermark": s.shard_pool_high_watermark,
                   "host_utilization": s.host_utilization,
                   "host_resident_bytes": tier.host.stored_bytes(),
                   "terminal_counts": s.terminal_counts},
        "completed": {"unconstrained": sum(q.done for q in base_done),
                      "tiered": sum(q.done for q in tier_done),
                      "submitted": len(prompts)},
        "outputs_identical": [q.output for q in tier_done]
                             == [q.output for q in base_done],
        "metrics": s.registry.snapshot(),
    }


def check_paper_claims(result: dict) -> dict[str, bool]:
    t, c = result["tiered"], result["completed"]
    return {
        "tiered engine completes the whole overload workload":
            c["tiered"] == c["submitted"],
        "tiered outputs token-identical to the unconstrained pool":
            result["outputs_identical"],
        "host tier actually used (swap-ins > 0)":
            t["swap_in_blocks"] > 0,
        "spilled prefixes revived as hits (host-tier hits > 0)":
            t["host_prefix_hits"] > 0,
        "pool pressure triggered tier traffic (spills or preemptions)":
            t["prefix_spilled_blocks"] + t["preemptions"] > 0,
    }


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="random tiny model + small workload (CI smoke)")
    args = ap.parse_args()

    if args.tiny:
        from benchmarks.common import tiny_serving_ctx
        ctx = tiny_serving_ctx("t12-tiny")
        result = run(ctx, n_templates=3, per_template=4, template_len=32,
                     suffix_len=5, max_new=5, max_batch=2,
                     sched=KVTunerSchedule.uniform(2, PrecisionPair(8, 4)),
                     prefill_chunk=16)
    else:
        from benchmarks.common import get_bench_model
        ctx = get_bench_model(log=lambda *a: print(*a, flush=True))
        result = run(ctx)

    claims = check_paper_claims(result)
    print(json.dumps(result, indent=2, default=str))
    for claim, passed in claims.items():
        print(f"# [{'PASS' if passed else 'FAIL'}] {claim}", flush=True)
    if not all(claims.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
