"""Table 11 (systems extension): prefix-cached paged serving.

Shared-prefix Poisson workload — N few-shot templates × M requests each
(identical template prompt + per-request suffix), the traffic shape where
production servers win big from block-level prefix reuse. Both engines run
chunked in-pool prefill over the paged quantized pool; the measured variable
is the radix-tree prefix cache:

* **prefix off** (baseline): every admission prefills its full prompt.
* **prefix on**: admissions pin the longest cached block chain and prefill
  only the suffix; hit/evict accounting comes from ``EngineStats``.

Reported: prefill tokens (and the saved fraction), cache hits, end-to-end
tokens/s. Greedy outputs must be token-identical between the two runs —
prefix caching is a pure work-elimination optimization. ``speculate_k > 0``
layers speculative multi-token decode on top of both engines (same drafter,
same identity requirement) and the per-engine speculation accounting
(drafted/accepted tokens, acceptance rate, accepted-length percentiles)
rides along in the report.

Standalone: ``PYTHONPATH=src python -m benchmarks.table11_prefix [--tiny]``
(``--tiny`` drives a milliseconds-scale random model — the CI smoke mode).
"""
from __future__ import annotations

import numpy as np

from repro.core.precision import KVTunerSchedule, PrecisionPair
from repro.serving.engine import ContinuousEngine, Request


def build_workload(vocab: int, n_templates: int, per_template: int,
                   template_len: int, suffix_len: int, seed: int = 0,
                   arrival_rate: float = 1.5):
    """(prompts, arrival_steps): per-template shared prefixes + random
    suffixes, interleaved across templates, Poisson inter-arrivals. The
    explicit ``seed`` pins the workload bit-for-bit (shared helpers in
    ``benchmarks.common`` — no module-level RNG state)."""
    from benchmarks.common import poisson_arrivals, shared_template_prompts

    rng = np.random.default_rng(seed)
    prompts = shared_template_prompts(vocab, n_templates, per_template,
                                      template_len, suffix_len, rng)
    return prompts, poisson_arrivals(len(prompts), arrival_rate, rng)


def _spec_fields(stats) -> dict:
    """Speculative-decode accounting (zeros when ``speculate_k=0``)."""
    return {"spec_steps": stats.spec_steps,
            "drafted_tokens": stats.drafted_tokens,
            "accepted_tokens": stats.accepted_tokens,
            "acceptance_rate": stats.acceptance_rate,
            "accepted_len_p50": stats.accepted_len_p50,
            "accepted_len_p95": stats.accepted_len_p95}


def run(ctx, n_templates: int = 3, per_template: int = 4,
        template_len: int = 64, suffix_len: int = 16, max_new: int = 8,
        max_batch: int = 4, seed: int = 0, sched=None,
        prefill_chunk: int | None = None, speculate_k: int = 0) -> dict:
    cfg = ctx.api.cfg
    if sched is None:
        from repro.launch.steps import default_schedule
        sched = default_schedule(cfg, "kvtuner")
    if prefill_chunk is None:
        # one quant group per chunk → finest chunk-aligned sharing
        prefill_chunk = cfg.kv_group_size
    prompts, arrivals = build_workload(
        cfg.vocab_size, n_templates, per_template, template_len, suffix_len,
        seed=seed)
    max_seq = template_len + suffix_len + max_new

    results = {}
    for on in (False, True):
        eng = ContinuousEngine(
            ctx.api, ctx.params, sched, max_batch=max_batch, max_seq=max_seq,
            prefill_paged=True, prefix_cache=on, prefill_chunk=prefill_chunk,
            seed=seed, speculate_k=speculate_k)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new,
                               arrival_step=arrivals[i]))
        done = sorted(eng.run(), key=lambda r: r.uid)
        results[on] = ([r.output for r in done], eng.stats)

    (out_off, off), (out_on, on) = results[False], results[True]
    saved = 1.0 - on.prefill_tokens / max(off.prefill_tokens, 1)
    return {
        "workload": {"n_templates": n_templates,
                     "per_template": per_template,
                     "template_len": template_len, "suffix_len": suffix_len,
                     "max_new": max_new, "arrival_steps": arrivals,
                     "speculate_k": speculate_k},
        "prefix_off": {"prefill_tokens": off.prefill_tokens,
                       "tokens_per_s": off.throughput,
                       "decode_tokens_per_s": off.decode_tokens_per_s,
                       "decode_p50_ms": off.decode_p50_ms,
                       "decode_p95_ms": off.decode_p95_ms,
                       "prefill_p50_ms": off.prefill_p50_ms,
                       "prefill_p95_ms": off.prefill_p95_ms,
                       "admit_p50_ms": off.admit_p50_ms,
                       "admit_p95_ms": off.admit_p95_ms,
                       "prefill_dispatches": off.prefill_dispatches,
                       "decode_steps": off.decode_steps,
                       "pool_utilization": off.pool_utilization,
                       "pool_high_watermark": off.pool_high_watermark,
                       "terminal_counts": off.terminal_counts,
                       **_spec_fields(off)},
        "prefix_on": {"prefill_tokens": on.prefill_tokens,
                      "tokens_per_s": on.throughput,
                      "decode_tokens_per_s": on.decode_tokens_per_s,
                      "decode_p50_ms": on.decode_p50_ms,
                      "decode_p95_ms": on.decode_p95_ms,
                      "prefill_p50_ms": on.prefill_p50_ms,
                      "prefill_p95_ms": on.prefill_p95_ms,
                      "admit_p50_ms": on.admit_p50_ms,
                      "admit_p95_ms": on.admit_p95_ms,
                      "prefill_dispatches": on.prefill_dispatches,
                      "decode_steps": on.decode_steps,
                      "pool_utilization": on.pool_utilization,
                      "pool_high_watermark": on.pool_high_watermark,
                      "terminal_counts": on.terminal_counts,
                      "hits": on.prefix_hits, "misses": on.prefix_misses,
                      "hit_tokens": on.prefix_hit_tokens,
                      "evicted_blocks": on.prefix_evicted_blocks,
                      **_spec_fields(on)},
        "prefill_tokens_saved_frac": saved,
        "outputs_identical": out_on == out_off,
        "metrics": on.registry.snapshot(),
    }


def check_paper_claims(result: dict) -> dict[str, bool]:
    on = result["prefix_on"]
    return {
        "prefix-cached outputs token-identical to cache-off":
            result["outputs_identical"],
        "shared-template admissions hit the cache": on["hits"] > 0,
        "prefill tokens reduced >= 30% on shared-prefix workload":
            result["prefill_tokens_saved_frac"] >= 0.30,
        "hit tokens account for the whole saving":
            on["prefill_tokens"] + on["hit_tokens"]
            == result["prefix_off"]["prefill_tokens"],
    }


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="random tiny model + small workload (CI smoke)")
    args = ap.parse_args()

    if args.tiny:
        from benchmarks.common import tiny_serving_ctx
        ctx = tiny_serving_ctx("t11-tiny")
        result = run(ctx, n_templates=2, per_template=3, template_len=16,
                     suffix_len=5, max_new=4, max_batch=2,
                     sched=KVTunerSchedule.uniform(2, PrecisionPair(8, 4)),
                     prefill_chunk=16)
    else:
        from benchmarks.common import get_bench_model
        ctx = get_bench_model(log=lambda *a: print(*a, flush=True))
        result = run(ctx)

    claims = check_paper_claims(result)
    print(json.dumps(result, indent=2, default=str))
    for claim, passed in claims.items():
        print(f"# [{'PASS' if passed else 'FAIL'}] {claim}", flush=True)
    if not all(claims.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
