"""Paper Table 8: decode throughput vs KV cache precision.

Three views (the container has no TPU):
1. **Measured (CPU, relative)**: end-to-end ServeEngine tokens/s with the
   packed deployment cache at KV16 / KV8 / KV4 / KVTuner-mixed — includes
   quant/dequant overhead, as the paper specifies.
2. **Projected (TPU v5e, roofline)**: decode attention is HBM-bound; step
   time ∝ KV bytes moved. We report per-token cache bytes per schedule and
   the implied throughput gain over KIVI-KV8 — the paper's +21.25% claim is
   a bytes-ratio effect (8-bit → 3.25-bit ≈ 2.1× fewer cache bytes at the
   attention-read fraction of step time).
3. **Engine comparison** (``run_engines``): wave vs continuous batching on
   a mixed-length Poisson-arrival workload — the serving regime the paper's
   throughput claim targets. Greedy outputs must be token-identical and the
   continuous decode step must compile at most twice across the whole run.
"""
from __future__ import annotations

import numpy as np

from repro.core.precision import KVTunerSchedule, PrecisionPair
from repro.launch.steps import default_schedule
from repro.serving.engine import (ContinuousEngine, Request, ServeEngine,
                                  generate)


def cache_bytes_per_token(cfg, schedule: KVTunerSchedule | None) -> float:
    """Packed KV cache bytes per token per sequence (scales incl.)."""
    hd = cfg.head_dim
    hkv = cfg.num_kv_heads
    g = cfg.kv_group_size
    total = 0.0
    n_attn = len(cfg.attention_layers())
    for i in range(n_attn):
        pair = schedule[i] if schedule is not None else PrecisionPair(16, 16)
        for bits in (pair.k_bits, pair.v_bits):
            if bits >= 16:
                total += hkv * hd * 2
            else:
                total += hkv * hd * bits / 8 + hkv * (hd / g) * 8
    return total


def projected_gain(cfg, schedule, baseline_sched, attn_fraction=0.45) -> float:
    """Amdahl-style projection: decode step = attn-read (∝ cache bytes) +
    weight-read (constant). attn_fraction = attention share of the baseline
    step at 32k context (from the §Roofline decode analysis)."""
    b0 = cache_bytes_per_token(cfg, baseline_sched)
    b1 = cache_bytes_per_token(cfg, schedule)
    t_rel = (1 - attn_fraction) + attn_fraction * (b1 / b0)
    return 1.0 / t_rel


def run(ctx, n_prompts: int = 8, prompt_len: int = 48,
        max_new: int = 16, seed: int = 0) -> dict:
    cfg = ctx.api.cfg
    n_attn = len(cfg.attention_layers())
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size, size=(n_prompts, prompt_len))

    schedules = {
        "KV16": KVTunerSchedule.uniform(n_attn, PrecisionPair(16, 16)),
        "KV8": KVTunerSchedule.uniform(n_attn, PrecisionPair(8, 8)),
        "KV4": KVTunerSchedule.uniform(n_attn, PrecisionPair(4, 4)),
        "K4V2": KVTunerSchedule.uniform(n_attn, PrecisionPair(4, 2)),
        "KVTuner-mixed": default_schedule(cfg, "kvtuner"),
    }
    rows = []
    for name, sched in schedules.items():
        # measured twice; second run reuses compiled steps (steady-state)
        _, _ = generate(ctx.api, ctx.params, sched, prompts[:2], 4)
        out, stats = generate(ctx.api, ctx.params, sched, prompts, max_new)
        rows.append({
            "schedule": name,
            "equiv_bits": sched.equivalent_bits,
            "tokens_per_s_cpu": stats.throughput,
            "cache_bytes_per_token": cache_bytes_per_token(cfg, sched),
            "projected_gain_vs_kv8": projected_gain(
                cfg, sched, schedules["KV8"]),
        })
    return {"rows": rows}


def run_engines(ctx, n_requests: int = 10, max_new: int = 8,
                max_batch: int = 4, seed: int = 0) -> dict:
    """Wave vs continuous engines, mixed-length Poisson arrival workload.

    Prompt lengths are drawn from three buckets (so the wave engine pays its
    per-bucket recompiles) and arrival times follow a Poisson process in
    decode-step units (the continuous engine admits mid-decode; the wave
    engine only sees the queue after all requests have arrived — it has no
    streaming admission at all, which is the point)."""
    from benchmarks.common import poisson_arrivals

    cfg = ctx.api.cfg
    sched = default_schedule(cfg, "kvtuner")
    rng = np.random.default_rng(seed)
    plens = rng.choice([32, 48, 64], size=n_requests)
    arrivals = poisson_arrivals(n_requests, 1.5, rng)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)) for n in plens]

    wave = ServeEngine(ctx.api, ctx.params, sched, max_batch=max_batch,
                       seed=seed)
    for i, p in enumerate(prompts):
        wave.submit(Request(uid=i, prompt=p, max_new_tokens=max_new))
    wave_done = sorted(wave.run(), key=lambda r: r.uid)

    cont = ContinuousEngine(ctx.api, ctx.params, sched, max_batch=max_batch,
                            max_seq=int(plens.max()) + max_new, seed=seed)
    for i, p in enumerate(prompts):
        cont.submit(Request(uid=i, prompt=p, max_new_tokens=max_new,
                            arrival_step=int(arrivals[i])))
    cont_done = sorted(cont.run(), key=lambda r: r.uid)

    return {
        "workload": {"n_requests": n_requests, "max_new": max_new,
                     "seed": seed, "prompt_lens": plens.tolist(),
                     "arrival_steps": list(arrivals)},
        "wave": {"tokens_per_s": wave.stats.throughput,
                 "decode_tokens_per_s": wave.stats.decode_tokens_per_s,
                 "decode_steps": wave.stats.decode_steps,
                 "decode_p50_ms": wave.stats.decode_p50_ms,
                 "decode_p95_ms": wave.stats.decode_p95_ms,
                 "prefill_p50_ms": wave.stats.prefill_p50_ms,
                 "prefill_p95_ms": wave.stats.prefill_p95_ms,
                 "admit_p50_ms": wave.stats.admit_p50_ms,
                 "admit_p95_ms": wave.stats.admit_p95_ms,
                 "prefill_dispatches": wave.stats.prefill_dispatches,
                 "decode_compilations": wave.decode_compilations,
                 "waves": wave.stats.waves},
        "continuous": {"tokens_per_s": cont.stats.throughput,
                       "decode_tokens_per_s": cont.stats.decode_tokens_per_s,
                       "decode_steps": cont.stats.decode_steps,
                       "decode_p50_ms": cont.stats.decode_p50_ms,
                       "decode_p95_ms": cont.stats.decode_p95_ms,
                       "prefill_p50_ms": cont.stats.prefill_p50_ms,
                       "prefill_p95_ms": cont.stats.prefill_p95_ms,
                       "admit_p50_ms": cont.stats.admit_p50_ms,
                       "admit_p95_ms": cont.stats.admit_p95_ms,
                       "prefill_dispatches": cont.stats.prefill_dispatches,
                       "pool_utilization": cont.stats.pool_utilization,
                       "pool_high_watermark":
                           cont.stats.pool_high_watermark,
                       "decode_compilations": cont.decode_compilations},
        "outputs_identical": all(
            w.output == c.output for w, c in zip(wave_done, cont_done)),
    }


def check_engine_claims(result: dict) -> dict[str, bool]:
    w, c = result["wave"], result["continuous"]
    return {
        "continuous outputs token-identical to wave":
            result["outputs_identical"],
        "continuous decode step compiles at most twice":
            c["decode_compilations"] <= 2,
        "wave engine recompiles per (batch, capacity) bucket":
            w["decode_compilations"] > c["decode_compilations"],
    }


def check_paper_claims(result: dict) -> dict[str, bool]:
    rows = {r["schedule"]: r for r in result["rows"]}
    mixed = rows["KVTuner-mixed"]
    return {
        "cache bytes shrink with bits": rows["KV4"]["cache_bytes_per_token"]
        < rows["KV8"]["cache_bytes_per_token"]
        < rows["KV16"]["cache_bytes_per_token"],
        # paper: KVTuner-C3.25 +16.8%~21.3% over KIVI-KV8 — our projected
        # gain for the ~3.1-bit mixed schedule must land in that band
        "projected gain vs KV8 in paper band (1.10-1.35)":
            1.10 <= mixed["projected_gain_vs_kv8"] <= 1.35,
        "mixed schedule smaller than KV8 cache":
            mixed["cache_bytes_per_token"] < rows["KV8"]["cache_bytes_per_token"],
    }
