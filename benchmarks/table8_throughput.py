"""Paper Table 8: decode throughput vs KV cache precision.

Three views (the container has no TPU):
1. **Measured (CPU, relative)**: end-to-end ServeEngine tokens/s with the
   packed deployment cache at KV16 / KV8 / KV4 / KVTuner-mixed — includes
   quant/dequant overhead, as the paper specifies.
2. **Projected (TPU v5e, roofline)**: decode attention is HBM-bound; step
   time ∝ KV bytes moved. We report per-token cache bytes per schedule and
   the implied throughput gain over KIVI-KV8 — the paper's +21.25% claim is
   a bytes-ratio effect (8-bit → 3.25-bit ≈ 2.1× fewer cache bytes at the
   attention-read fraction of step time).
3. **Engine comparison** (``run_engines``): wave vs continuous batching on
   a mixed-length Poisson-arrival workload — the serving regime the paper's
   throughput claim targets. Greedy outputs must be token-identical and the
   continuous decode step must compile at most twice across the whole run.
"""
from __future__ import annotations

import numpy as np

from repro.core.precision import KVTunerSchedule, PrecisionPair
from repro.launch.steps import default_schedule
from repro.serving.engine import (ContinuousEngine, EngineStats, Request,
                                  ServeEngine, generate)


def cache_bytes_per_token(cfg, schedule: KVTunerSchedule | None) -> float:
    """Packed KV cache bytes per token per sequence (scales incl.)."""
    hd = cfg.head_dim
    hkv = cfg.num_kv_heads
    g = cfg.kv_group_size
    total = 0.0
    n_attn = len(cfg.attention_layers())
    for i in range(n_attn):
        pair = schedule[i] if schedule is not None else PrecisionPair(16, 16)
        for bits in (pair.k_bits, pair.v_bits):
            if bits >= 16:
                total += hkv * hd * 2
            else:
                total += hkv * hd * bits / 8 + hkv * (hd / g) * 8
    return total


def projected_gain(cfg, schedule, baseline_sched, attn_fraction=0.45) -> float:
    """Amdahl-style projection: decode step = attn-read (∝ cache bytes) +
    weight-read (constant). attn_fraction = attention share of the baseline
    step at 32k context (from the §Roofline decode analysis)."""
    b0 = cache_bytes_per_token(cfg, baseline_sched)
    b1 = cache_bytes_per_token(cfg, schedule)
    t_rel = (1 - attn_fraction) + attn_fraction * (b1 / b0)
    return 1.0 / t_rel


def run(ctx, n_prompts: int = 8, prompt_len: int = 48,
        max_new: int = 16, seed: int = 0) -> dict:
    cfg = ctx.api.cfg
    n_attn = len(cfg.attention_layers())
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size, size=(n_prompts, prompt_len))

    schedules = {
        "KV16": KVTunerSchedule.uniform(n_attn, PrecisionPair(16, 16)),
        "KV8": KVTunerSchedule.uniform(n_attn, PrecisionPair(8, 8)),
        "KV4": KVTunerSchedule.uniform(n_attn, PrecisionPair(4, 4)),
        "K4V2": KVTunerSchedule.uniform(n_attn, PrecisionPair(4, 2)),
        "KVTuner-mixed": default_schedule(cfg, "kvtuner"),
    }
    rows = []
    for name, sched in schedules.items():
        # measured twice; second run reuses compiled steps (steady-state)
        _, _ = generate(ctx.api, ctx.params, sched, prompts[:2], 4)
        out, stats = generate(ctx.api, ctx.params, sched, prompts, max_new)
        rows.append({
            "schedule": name,
            "equiv_bits": sched.equivalent_bits,
            "tokens_per_s_cpu": stats.throughput,
            "cache_bytes_per_token": cache_bytes_per_token(cfg, sched),
            "projected_gain_vs_kv8": projected_gain(
                cfg, sched, schedules["KV8"]),
        })
    return {"rows": rows}


def run_engines(ctx, n_requests: int = 10, max_new: int = 8,
                max_batch: int = 4, seed: int = 0) -> dict:
    """Wave vs continuous engines, mixed-length Poisson arrival workload.

    Prompt lengths are drawn from three buckets (so the wave engine pays its
    per-bucket recompiles) and arrival times follow a Poisson process in
    decode-step units (the continuous engine admits mid-decode; the wave
    engine only sees the queue after all requests have arrived — it has no
    streaming admission at all, which is the point)."""
    from benchmarks.common import poisson_arrivals

    cfg = ctx.api.cfg
    sched = default_schedule(cfg, "kvtuner")
    rng = np.random.default_rng(seed)
    plens = rng.choice([32, 48, 64], size=n_requests)
    arrivals = poisson_arrivals(n_requests, 1.5, rng)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)) for n in plens]

    wave = ServeEngine(ctx.api, ctx.params, sched, max_batch=max_batch,
                       seed=seed)
    for i, p in enumerate(prompts):
        wave.submit(Request(uid=i, prompt=p, max_new_tokens=max_new))
    wave_done = sorted(wave.run(), key=lambda r: r.uid)

    cont = ContinuousEngine(ctx.api, ctx.params, sched, max_batch=max_batch,
                            max_seq=int(plens.max()) + max_new, seed=seed)
    for i, p in enumerate(prompts):
        cont.submit(Request(uid=i, prompt=p, max_new_tokens=max_new,
                            arrival_step=int(arrivals[i])))
    cont_done = sorted(cont.run(), key=lambda r: r.uid)

    return {
        "workload": {"n_requests": n_requests, "max_new": max_new,
                     "seed": seed, "prompt_lens": plens.tolist(),
                     "arrival_steps": list(arrivals)},
        "wave": {"tokens_per_s": wave.stats.throughput,
                 "decode_tokens_per_s": wave.stats.decode_tokens_per_s,
                 "decode_steps": wave.stats.decode_steps,
                 "decode_p50_ms": wave.stats.decode_p50_ms,
                 "decode_p95_ms": wave.stats.decode_p95_ms,
                 "prefill_p50_ms": wave.stats.prefill_p50_ms,
                 "prefill_p95_ms": wave.stats.prefill_p95_ms,
                 "admit_p50_ms": wave.stats.admit_p50_ms,
                 "admit_p95_ms": wave.stats.admit_p95_ms,
                 "prefill_dispatches": wave.stats.prefill_dispatches,
                 "decode_compilations": wave.decode_compilations,
                 "waves": wave.stats.waves},
        "continuous": {"tokens_per_s": cont.stats.throughput,
                       "decode_tokens_per_s": cont.stats.decode_tokens_per_s,
                       "decode_steps": cont.stats.decode_steps,
                       "decode_p50_ms": cont.stats.decode_p50_ms,
                       "decode_p95_ms": cont.stats.decode_p95_ms,
                       "prefill_p50_ms": cont.stats.prefill_p50_ms,
                       "prefill_p95_ms": cont.stats.prefill_p95_ms,
                       "admit_p50_ms": cont.stats.admit_p50_ms,
                       "admit_p95_ms": cont.stats.admit_p95_ms,
                       "prefill_dispatches": cont.stats.prefill_dispatches,
                       "pool_utilization": cont.stats.pool_utilization,
                       "pool_high_watermark":
                           cont.stats.pool_high_watermark,
                       "n_shards": cont.stats.n_shards,
                       "shard_pool_utilization":
                           cont.stats.shard_pool_utilization,
                       "shard_pool_high_watermark":
                           cont.stats.shard_pool_high_watermark,
                       "decode_compilations": cont.decode_compilations,
                       "terminal_counts": cont.stats.terminal_counts},
        "outputs_identical": all(
            w.output == c.output for w, c in zip(wave_done, cont_done)),
        "metrics": cont.metrics.snapshot(),
    }


def _spec_stats(stats) -> dict:
    return {"tokens_per_s": stats.throughput,
            "decode_tokens_per_s": stats.decode_tokens_per_s,
            "decode_steps": stats.decode_steps,
            "decode_tokens": stats.decode_tokens,
            "spec_steps": stats.spec_steps,
            "drafted_tokens": stats.drafted_tokens,
            "accepted_tokens": stats.accepted_tokens,
            "acceptance_rate": stats.acceptance_rate,
            "accepted_len_p50": stats.accepted_len_p50,
            "accepted_len_p95": stats.accepted_len_p95}


def run_speculative(ctx, n_templates: int = 2, per_template: int = 3,
                    template_len: int = 32, suffix_len: int = 4,
                    max_new: int = 96, max_batch: int = 4,
                    speculate_k: int = 4, seed: int = 0,
                    sched: KVTunerSchedule | None = None) -> dict:
    """Speculative vs plain decode on the shared-template serving workload.

    Same engine, same pool — the only difference is ``speculate_k``: the
    prompt-lookup drafter proposes continuations from each request's own
    history and one dispatch verifies k+1 positions, so every accepted
    draft removes one device round-trip. BOTH verification backends run:

    * the engine default (sub-step scan + bitwise rollback) carries the
      token-identity claim — its outputs must equal plain decode exactly.
      Its speedup is reported but not gated: the scan amortizes HOST
      round-trips, which a CPU-only run barely pays, so on this rig it
      hovers near 1x while an accelerator small-batch serve is where it
      wins;
    * ``fused_verify=True`` (one wide pass over the quantized pool) carries
      the throughput claim — it also drops the per-candidate pool passes
      the scan backend still pays, at the cost of wide-matmul rounding that
      is only numerically (not bitwise) equal to serial decode, so its
      identity flag is informational.

    Decode throughput is committed decode tokens over decode wall time
    (``EngineStats.decode_tokens_per_s``), the serving metric the speedup
    claim gates on. Every engine runs the workload twice and reports the
    warm second round, so one-time jit compilation does not drown the
    ~milliseconds-scale dispatches being compared. The analytic bytes ratio
    is the same fused-vs-serial accounting the kernel sweep
    (``kernels_micro --verify``) times in isolation."""
    from benchmarks.common import shared_template_prompts

    cfg = ctx.api.cfg
    if sched is None:
        sched = default_schedule(cfg, "kvtuner")
    rng = np.random.default_rng(seed)
    prompts = shared_template_prompts(cfg.vocab_size, n_templates,
                                      per_template, template_len, suffix_len,
                                      rng)
    max_seq = template_len + suffix_len + max_new + cfg.kv_group_size

    def drive(k, fused=False):
        eng = ContinuousEngine(ctx.api, ctx.params, sched,
                               max_batch=max_batch, max_seq=max_seq,
                               seed=seed, speculate_k=k, fused_verify=fused)
        outs: list = []
        for rnd in range(2):           # round 0 warms the jit caches
            eng.stats = EngineStats()
            for i, p in enumerate(prompts):
                eng.submit(Request(uid=1000 * rnd + i,
                                   prompt=np.asarray(p, np.int32),
                                   max_new_tokens=max_new))
            done = sorted(eng.run(), key=lambda r: r.uid)
            outs = [list(r.output) for r in done]
        return outs, eng

    base_out, base = drive(0)
    spec_out, spec = drive(speculate_k)
    fused_out, fused = drive(speculate_k, fused=True)
    final_lens = [len(p) + len(o) - 1 for p, o in zip(prompts, spec_out)]
    pool = spec.state.pools[0]
    return {
        "workload": {"n_requests": len(prompts), "max_new": max_new,
                     "template_len": template_len, "suffix_len": suffix_len,
                     "speculate_k": speculate_k, "seed": seed},
        "baseline": _spec_stats(base.stats),
        "speculative": _spec_stats(spec.stats),
        "speculative_fused": _spec_stats(fused.stats),
        "decode_speedup": spec.stats.decode_tokens_per_s
        / max(base.stats.decode_tokens_per_s, 1e-9),
        "decode_speedup_fused": fused.stats.decode_tokens_per_s
        / max(base.stats.decode_tokens_per_s, 1e-9),
        "verify_bytes_per_dispatch": int(pool.verify_stream_bytes(
            final_lens, speculate_k + 1)),
        "serial_bytes_per_k1_steps": int(
            (speculate_k + 1) * pool.decode_stream_bytes(final_lens)),
        "outputs_identical": base_out == spec_out,
        "fused_outputs_identical": base_out == fused_out,
    }


def check_speculative_claims(result: dict) -> dict[str, bool]:
    spec = result["speculative"]
    return {
        "speculative outputs token-identical to plain decode":
            result["outputs_identical"],
        "fused-verify decode throughput >= 1.5x plain decode":
            result["decode_speedup_fused"] >= 1.5,
        "drafts are actually accepted (acceptance rate > 0.3)":
            spec["acceptance_rate"] > 0.3,
        "multi-token commits happen (accepted-length p95 > 1)":
            spec["accepted_len_p95"] > 1.0,
        "fused verify streams fewer bytes than k+1 serial decode steps":
            result["verify_bytes_per_dispatch"]
            < result["serial_bytes_per_k1_steps"],
        "fewer device dispatches than tokens decoded":
            spec["spec_steps"] < spec["decode_tokens"],
    }


def check_engine_claims(result: dict) -> dict[str, bool]:
    w, c = result["wave"], result["continuous"]
    return {
        "continuous outputs token-identical to wave":
            result["outputs_identical"],
        "continuous decode step compiles at most twice":
            c["decode_compilations"] <= 2,
        "wave engine recompiles per (batch, capacity) bucket":
            w["decode_compilations"] > c["decode_compilations"],
    }


def check_paper_claims(result: dict) -> dict[str, bool]:
    rows = {r["schedule"]: r for r in result["rows"]}
    mixed = rows["KVTuner-mixed"]
    return {
        "cache bytes shrink with bits": rows["KV4"]["cache_bytes_per_token"]
        < rows["KV8"]["cache_bytes_per_token"]
        < rows["KV16"]["cache_bytes_per_token"],
        # paper: KVTuner-C3.25 +16.8%~21.3% over KIVI-KV8 — our projected
        # gain for the ~3.1-bit mixed schedule must land in that band
        "projected gain vs KV8 in paper band (1.10-1.35)":
            1.10 <= mixed["projected_gain_vs_kv8"] <= 1.35,
        "mixed schedule smaller than KV8 cache":
            mixed["cache_bytes_per_token"] < rows["KV8"]["cache_bytes_per_token"],
    }


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--speculative", action="store_true",
                    help="speculative vs plain decode comparison")
    ap.add_argument("--tiny", action="store_true",
                    help="random tiny model + small workload (CI smoke)")
    args = ap.parse_args()

    if not args.speculative:
        raise SystemExit("table8 CLI currently drives the speculative "
                         "comparison only: pass --speculative "
                         "(other views run via benchmarks.run)")
    if args.tiny:
        from benchmarks.common import tiny_serving_ctx
        ctx = tiny_serving_ctx("t8-spec-tiny")
        # max_new well past the tiny random model's output cycle (~8): the
        # prompt-lookup drafter only starts hitting once generation revisits
        # its own history — the stand-in for the templated/repetitive
        # continuations where speculation pays on real models. k = R-1 lets
        # a deep-cycle dispatch commit a whole quant group at once.
        result = run_speculative(
            ctx, n_templates=2, per_template=2, template_len=16,
            suffix_len=4, max_new=128, max_batch=2, speculate_k=7,
            sched=KVTunerSchedule.uniform(2, PrecisionPair(8, 4)))
    else:
        from benchmarks.common import get_bench_model
        ctx = get_bench_model(log=lambda *a: print(*a, flush=True))
        result = run_speculative(ctx)

    claims = check_speculative_claims(result)
    print(json.dumps(result, indent=2, default=str))
    for claim, passed in claims.items():
        print(f"# [{'PASS' if passed else 'FAIL'}] {claim}", flush=True)
    if not all(claims.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
