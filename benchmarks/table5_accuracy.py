"""Paper Table 5 + Fig. 5: end-task (mathematical-reasoning proxy) accuracy —
uniform KV precision pairs vs KVTuner's searched Pareto frontier.

The metric is chain exact-match (one flipped intermediate token fails the
sample — the paper's GSM8K error-accumulation setting, Table 1). KVTuner runs
the full offline pipeline (capture → prune → cluster → NSGA-II) and must
dominate uniform pairs at matched equivalent bits.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.precision import CANDIDATE_PAIRS, MODE_PER_TOKEN
from repro.core.tuner import KVTuner
from repro.data import synthetic


def _accuracy(ctx, bits: np.ndarray, batches, mode=MODE_PER_TOKEN) -> float:
    accs = []
    for b in batches:
        logits, _ = ctx.api.forward(ctx.params, b,
                                    sim_bits=jnp.asarray(bits, jnp.float32),
                                    sim_mode=mode)
        accs.append(synthetic.exact_match_accuracy(
            logits, {k: np.asarray(v) for k, v in b.items()}))
    return float(np.mean(accs))


def run(ctx, generations: int = 6, pop: int = 16) -> dict:
    n_attn = len(ctx.api.cfg.attention_layers())
    eval_batches = ctx.eval_batches(n=2, batch=48, seed=5100, kind="chain")
    test_batches = ctx.eval_batches(n=2, batch=48, seed=6200, kind="chain")

    rows = []
    for pair in CANDIDATE_PAIRS:
        bits = np.tile([[pair.k_bits, pair.v_bits]], (n_attn, 1))
        rows.append({"name": pair.name, "bits": pair.equivalent_bits,
                     "acc": _accuracy(ctx, bits, test_batches),
                     "kind": "uniform"})
    bf16 = np.full((n_attn, 2), 16.0)
    rows.append({"name": "BF16", "bits": 16.0,
                 "acc": _accuracy(ctx, bf16, test_batches), "kind": "uniform"})

    # accuracy-driven NSGA-II search (negated EM accuracy as the loss)
    def metric(logits, batch):
        # smooth surrogate inside jit: masked NLL (EM is np-side, used for
        # final reporting); matches the paper's use of task accuracy as a
        # black box with NLL tie-breaking at tiny calibration sizes.
        from repro.models import common
        mask = batch.get("loss_mask")
        return common.softmax_cross_entropy(
            logits[:, :-1], batch["tokens"][:, 1:],
            None if mask is None else mask[:, 1:])

    tuner = KVTuner(ctx.api, ctx.params, mode=MODE_PER_TOKEN)
    report = tuner.search(ctx.calib_batches(), eval_batches=eval_batches,
                          metric=metric, generations=generations,
                          pop_size=pop, seed=0)
    frontier_rows = []
    for sched in report.frontier:
        bits = sched.bits_array()
        acc = _accuracy(ctx, bits, test_batches)
        frontier_rows.append({"name": sched.name,
                              "bits": sched.equivalent_bits, "acc": acc,
                              "kind": "kvtuner",
                              "pairs": [p.name for p in sched.pairs]})
    rows.extend(frontier_rows)
    full, pruned, grouped = report.space_reduction()
    return {"rows": rows, "space": {"full": full, "pruned": pruned,
                                    "grouped": grouped},
            "groups": report.groups.groups}


def check_paper_claims(result: dict) -> dict[str, bool]:
    rows = result["rows"]
    uni = {r["name"]: r for r in rows if r["kind"] == "uniform"}
    kvt = [r for r in rows if r["kind"] == "kvtuner"]
    base = uni["BF16"]["acc"]
    claims = {
        "KV8 nearly lossless": uni["KV8"]["acc"] >= base - 0.05,
        "KV2 collapses": uni["KV2"]["acc"] <= base * 0.7 + 0.05,
    }
    # KVTuner finds a ≤4.5-bit schedule within 5 points of BF16 (paper: ~4-bit
    # nearly lossless) and dominates the uniform pair at comparable bits.
    low = [r for r in kvt if r["bits"] <= 4.5]
    claims["kvtuner <=4.5-bit nearly lossless"] = bool(
        low and max(r["acc"] for r in low) >= base - 0.08)
    if low:
        best = max(low, key=lambda r: r["acc"])
        uni_at = uni["KV4"]["acc"]
        claims["kvtuner beats uniform KV4 at <=4.5 bits"] = \
            best["acc"] >= uni_at - 0.02
    return claims
