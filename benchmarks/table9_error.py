"""Paper Table 9: KV quantization error (e_k, e_v, e_a, e_o) by quant mode ×
precision, averaged over layers — on the trained bench model's captured
calibration activations."""
from __future__ import annotations


from repro.core import sensitivity
from repro.core.precision import (MODE_PER_CHANNEL, MODE_PER_TOKEN,
                                  PrecisionPair)


def run(ctx) -> list[dict]:
    caps = sensitivity.capture_activations(ctx.api, ctx.params,
                                           ctx.calib_batches())
    pairs = [PrecisionPair(8, 8), PrecisionPair(4, 4), PrecisionPair(2, 2)]
    rows = []
    for mode in (MODE_PER_CHANNEL, MODE_PER_TOKEN):
        errs = sensitivity.layer_errors(caps, ctx.api.cfg, mode, pairs)
        m = sensitivity.model_errors(errs)
        for i, p in enumerate(pairs):
            rows.append({
                "pair": p.name, "mode": mode,
                "e_k": float(m["e_k"][i]), "e_v": float(m["e_v"][i]),
                "e_a": float(m["e_a"][i]), "e_o": float(m["e_o"][i]),
            })
    return rows


def check_paper_claims(rows: list[dict]) -> dict[str, bool]:
    """Orderings the paper reports (§4.2 / Table 9)."""
    by = {(r["pair"], r["mode"]): r for r in rows}
    tok = MODE_PER_TOKEN
    ch = MODE_PER_CHANNEL
    return {
        # per-channel keys beat per-token keys at every precision
        "e_k per-channel < per-token @8": by[("KV8", ch)]["e_k"] < by[("KV8", tok)]["e_k"],
        "e_k per-channel < per-token @4": by[("KV4", ch)]["e_k"] < by[("KV4", tok)]["e_k"],
        "e_k per-channel < per-token @2": by[("KV2", ch)]["e_k"] < by[("KV2", tok)]["e_k"],
        # value cache barely cares about the quant dimension
        "e_v mode-insensitive": abs(by[("KV4", ch)]["e_v"] - by[("KV4", tok)]["e_v"])
        < 0.5 * by[("KV4", tok)]["e_v"],
        # errors grow as precision drops
        "e_o monotone": by[("KV8", tok)]["e_o"] < by[("KV4", tok)]["e_o"]
        < by[("KV2", tok)]["e_o"],
    }
