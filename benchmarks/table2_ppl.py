"""Paper Table 2: perplexity across the 9 uniform KV precision pairs
(held-out synthetic corpus standing in for wikitext)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import ppl_from_nll
from repro.core.precision import CANDIDATE_PAIRS, MODE_KIVI, MODE_PER_TOKEN
from repro.core.tuner import make_sim_evaluator


def run(ctx) -> list[dict]:
    eval_batches = ctx.eval_batches(n=2, batch=32, seed=9001, kind="mixed")
    rows = []
    n_attn = len(ctx.api.cfg.attention_layers())
    for mode in (MODE_PER_TOKEN, MODE_KIVI):
        evaluator = make_sim_evaluator(ctx.api, ctx.params, eval_batches,
                                       mode=mode)
        base = evaluator(np.full((n_attn, 2), 16.0, np.float32))
        for pair in CANDIDATE_PAIRS:
            bits = np.tile([[pair.k_bits, pair.v_bits]], (n_attn, 1)) \
                .astype(np.float32)
            nll = evaluator(bits)
            rows.append({"mode": mode, "pair": pair.name,
                         "nll": float(nll), "ppl": ppl_from_nll(nll),
                         "ppl_bf16": ppl_from_nll(base)})
    return rows


def check_paper_claims(rows: list[dict]) -> dict[str, bool]:
    tok = {r["pair"]: r["ppl"] for r in rows if r["mode"] == MODE_PER_TOKEN}
    base = next(r["ppl_bf16"] for r in rows)
    return {
        # KV8 ≈ lossless; K8V4 ≈ KV8 (paper: same ppl level)
        "KV8 nearly lossless": tok["KV8"] < base * 1.02,
        "K8V4 ~ KV8": tok["K8V4"] < tok["KV8"] * 1.10,
        "K4V2 ~ KV4 band": tok["K4V2"] < tok["KV4"] * 1.5 + 1e-9,
        "K2* degrades sharply": min(tok["K2V8"], tok["K2V4"], tok["KV2"])
        > tok["KV8"] * 1.05,
    }
