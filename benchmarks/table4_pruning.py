"""Paper Table 4 + §D.1.1: intra-layer Pareto-pruned precision-pair sets per
layer, per quant mode — including the "key-first set" structure check."""
from __future__ import annotations

from repro.core import sensitivity
from repro.core.precision import (KEY_FIRST_SET, MODE_KIVI, MODE_PER_TOKEN)
from repro.core.pruning import prune_intra_layer


def run(ctx) -> dict:
    caps = sensitivity.capture_activations(ctx.api, ctx.params,
                                           ctx.calib_batches())
    out = {}
    for mode in (MODE_PER_TOKEN, MODE_KIVI):
        errs = sensitivity.layer_errors(caps, ctx.api.cfg, mode)
        pruned = prune_intra_layer(errs)
        per_layer = []
        for l in range(pruned.num_layers):
            per_layer.append([p.name for p in pruned.layer_candidates(l)])
        out[mode] = {
            "per_layer_sets": per_layer,
            "space_full": float(len(errs.pairs)) ** pruned.num_layers,
            "space_pruned": pruned.space_size(),
        }
    return out


def check_paper_claims(result: dict) -> dict[str, bool]:
    key_first = {p.name for p in KEY_FIRST_SET}
    tok_sets = result[MODE_PER_TOKEN]["per_layer_sets"]
    # In the paper most per-token layers keep exactly the key-first Pareto set;
    # at our scale we check the structural versions of that claim.
    contains_kv8 = all("KV8" in s for s in tok_sets)
    contains_kv2 = all("KV2" in s for s in tok_sets)
    reduced = result[MODE_PER_TOKEN]["space_pruned"] < \
        result[MODE_PER_TOKEN]["space_full"]
    keyfirst_overlap = sum(
        len(key_first & set(s)) >= 3 for s in tok_sets) / len(tok_sets)
    return {
        "every layer keeps KV8 (frontier top)": contains_kv8,
        "every layer keeps KV2 (frontier bottom)": contains_kv2,
        "search space strictly reduced": bool(reduced),
        "key-first set majority overlap": keyfirst_overlap >= 0.5,
    }
