"""Kernel microbenchmarks: interpret-mode timing (CPU; correctness-weighted)
plus the structural VMEM/HBM accounting the TPU roofline uses — per
(k_bits, v_bits) specialization of the fused decode kernel."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.precision import MODE_PER_TOKEN
from repro.kernels.qdecode import qdecode
from repro.kernels.kvquant import kvquant


def _time(fn, *args, reps=3, **kw):
    jax.block_until_ready(fn(*args, **kw))  # compile/warm, off the clock
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def _time_min(fn, *args, reps=5, **kw):
    """Best-of-reps µs/call: the minimum filters scheduler noise, which
    CI-gating wall-clock ratio claims need on shared runners."""
    jax.block_until_ready(fn(*args, **kw))  # compile/warm, off the clock
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(ctx=None) -> dict:
    b, hkv, g, d, s = 1, 2, 4, 64, 512
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, hkv, g, d))
    k = jax.random.normal(key, (b, hkv, s, d))
    v = jax.random.normal(key, (b, hkv, s, d))
    n_valid = jnp.full((b,), s, jnp.int32)

    rows = []
    for bits in (8, 4, 2):
        kq = quant.quantize(k, bits, MODE_PER_TOKEN, 32)
        vq = quant.quantize(v, bits, MODE_PER_TOKEN, 32)
        us = _time(qdecode, q, kq.codes, kq.scale, kq.zero, vq.codes,
                   vq.scale, vq.zero, n_valid, k_bits=bits, v_bits=bits,
                   k_mode=MODE_PER_TOKEN, v_mode=MODE_PER_TOKEN,
                   interpret=True)
        # HBM bytes the kernel streams per call (codes + scales, both K and V)
        hbm = 2 * (kq.codes.size + 4 * kq.scale.size + 4 * kq.zero.size)
        rows.append({"kernel": "qdecode", "bits": bits,
                     "us_per_call_interpret": us, "hbm_bytes_streamed": hbm,
                     "vmem_tile_bytes": 128 * d * bits // 8})
        usq = _time(kvquant, k.reshape(b * hkv, s, d), bits, MODE_PER_TOKEN,
                    interpret=True)
        rows.append({"kernel": "kvquant", "bits": bits,
                     "us_per_call_interpret": usq,
                     "hbm_bytes_streamed": k.size * 2 + kq.codes.size,
                     "vmem_tile_bytes": 128 * d * 4})
    return {"rows": rows}


def check_paper_claims(result: dict) -> dict[str, bool]:
    dec = {r["bits"]: r for r in result["rows"] if r["kernel"] == "qdecode"}
    return {
        "streamed bytes scale with bits":
            dec[2]["hbm_bytes_streamed"] < dec[4]["hbm_bytes_streamed"]
            < dec[8]["hbm_bytes_streamed"],
        "4-bit halves 8-bit traffic (±20%)":
            0.4 < dec[4]["hbm_bytes_streamed"] / dec[8]["hbm_bytes_streamed"] < 0.72,
    }


# ==================================================================== paged
def run_paged(ctx=None, max_slots: int = 4, max_pages: int = 32,
              hkv: int = 2, g: int = 4, d: int = 64, r: int = 32,
              bits: int = 4, reps: int = 3) -> dict:
    """Work-proportionality sweep for the length-aware fused paged decode
    kernel: one pool geometry (``max_pages`` per slot), timed at 25/50/100%
    fill and with half the slots dead — µs/call and analytic bytes-streamed
    must track **live** pages, not the pool capacity the page table was
    sized for."""
    import dataclasses

    from repro.cache.codec import kv_modes
    from repro.cache.paged import PagedKVPool
    from repro.core.precision import PrecisionPair
    from repro.kernels.qdecode import qdecode_paged

    num_blocks = 1 + max_slots * max_pages
    pp = PrecisionPair(bits, bits)
    pool = PagedKVPool.init(num_blocks, max_slots, hkv, d, pp,
                            MODE_PER_TOKEN, r, dtype=jnp.bfloat16)
    key = jax.random.PRNGKey(0)
    ks_ = jax.random.split(key, 5)
    c = pool.codec
    kc, ksc, kz = c.k.encode(jax.random.normal(ks_[0], (num_blocks, hkv, r, d)))
    vc, vsc, vz = c.v.encode(jax.random.normal(ks_[1], (num_blocks, hkv, r, d)))
    pool = dataclasses.replace(
        pool, k_codes=kc, k_scale=ksc, k_zero=kz, v_codes=vc, v_scale=vsc,
        v_zero=vz,
        k_res=jax.random.normal(ks_[2], (max_slots, hkv, r, d), jnp.bfloat16),
        v_res=jax.random.normal(ks_[3], (max_slots, hkv, r, d), jnp.bfloat16))
    q = jax.random.normal(ks_[4], (max_slots, hkv, g, d))
    # slot s's logical page j lives in physical block 1 + s·P + j
    pt = jnp.asarray(
        [[1 + s * max_pages + j for j in range(max_pages)]
         for s in range(max_slots)], jnp.int32)
    k_mode, v_mode = kv_modes(MODE_PER_TOKEN)

    def call(n_valid, n_res):
        return qdecode_paged(
            q, pool.k_codes, pool.k_scale, pool.k_zero, pool.v_codes,
            pool.v_scale, pool.v_zero, pool.k_res, pool.v_res, pt,
            n_valid, n_res, k_bits=bits, v_bits=bits, k_mode=k_mode,
            v_mode=v_mode, group_size=r, interpret=True)

    rows = []
    cases = [("fill", 0.25, 0.0), ("fill", 0.50, 0.0), ("fill", 1.00, 0.0),
             ("dead", 1.00, 0.5)]
    for kind, fill, dead_frac in cases:
        live_pages_per_slot = max(int(max_pages * fill), 1)
        n_dead = int(max_slots * dead_frac)
        lens = [0 if s < n_dead else live_pages_per_slot * r
                for s in range(max_slots)]
        n_valid = jnp.asarray(lens, jnp.int32)
        n_res = jnp.asarray([0 if ln == 0 else r // 2 for ln in lens],
                            jnp.int32)
        us = _time(call, n_valid, n_res, reps=reps)
        rows.append({
            "kernel": "qdecode_paged", "case": kind, "fill": fill,
            "dead_slot_frac": dead_frac,
            "live_pages": int(sum(ln // r for ln in lens)),
            "max_pages_total": max_slots * max_pages,
            "us_per_call_interpret": us,
            "hbm_bytes_streamed": pool.decode_stream_bytes(lens),
        })
    return {"rows": rows, "geometry": {
        "max_slots": max_slots, "max_pages": max_pages, "hkv": hkv, "g": g,
        "d": d, "r": r, "bits": bits,
        "block_bytes": pool.block_bytes()}}


def check_paged_claims(result: dict) -> dict[str, bool]:
    rows = result["rows"]
    by_fill = {r["fill"]: r for r in rows if r["case"] == "fill"}
    dead = next(r for r in rows if r["case"] == "dead")
    full, quarter = by_fill[1.0], by_fill[0.25]
    return {
        "us/call scales with live pages (25% fill >= 2x faster than 100%)":
            full["us_per_call_interpret"]
            >= 2.0 * quarter["us_per_call_interpret"],
        "bytes streamed track live pages, not max_pages":
            quarter["hbm_bytes_streamed"] < by_fill[0.5]["hbm_bytes_streamed"]
            < full["hbm_bytes_streamed"]
            and quarter["hbm_bytes_streamed"]
            < 0.35 * full["hbm_bytes_streamed"],
        "dead slots stream ~nothing (one aliased block each)":
            dead["hbm_bytes_streamed"] < 0.6 * full["hbm_bytes_streamed"]
            and dead["live_pages"] == full["live_pages"] // 2,
    }


# ================================================================== prefill
def run_prefill(ctx=None, max_slots: int = 4, max_pages: int = 32,
                hkv: int = 2, g: int = 4, d: int = 64, r: int = 32,
                bits: int = 4, chunk: int = 32, reps: int = 5) -> dict:
    """Work-proportionality + batched-admission sweep for the fused paged
    prefill kernel.

    Part 1 times ``qprefill_paged`` at 25/50/100% context fill (one chunk
    wave over a pool sized for ``max_pages`` pages per slot) — µs/call and
    the analytic ``PagedKVPool.prefill_stream_bytes`` must track **live**
    context, not the pool capacity the page table was sized for. Part 2
    drives a 4-request burst through a tiny ``ContinuousEngine`` with
    batched admission on/off × prefill kernel on/off: batched admission
    must cost fewer device dispatches, with greedy outputs token-identical
    across all four modes."""
    import dataclasses

    from repro.cache.codec import kv_modes
    from repro.cache.paged import PagedKVPool
    from repro.core.precision import PrecisionPair
    from repro.kernels.qprefill import (DEFAULT_BLOCK_Q, pick_block_q,
                                        qprefill_paged)

    num_blocks = 1 + max_slots * max_pages
    pp = PrecisionPair(bits, bits)
    pool = PagedKVPool.init(num_blocks, max_slots, hkv, d, pp,
                            MODE_PER_TOKEN, r, dtype=jnp.bfloat16)
    key = jax.random.PRNGKey(0)
    ks_ = jax.random.split(key, 5)
    c = pool.codec
    kc, ksc, kz = c.k.encode(jax.random.normal(ks_[0], (num_blocks, hkv, r, d)))
    vc, vsc, vz = c.v.encode(jax.random.normal(ks_[1], (num_blocks, hkv, r, d)))
    pool = dataclasses.replace(
        pool, k_codes=kc, k_scale=ksc, k_zero=kz, v_codes=vc, v_scale=vsc,
        v_zero=vz)
    q = jax.random.normal(ks_[2], (max_slots, hkv, chunk * g, d))
    k_ch = jax.random.normal(ks_[3], (max_slots, hkv, chunk, d))
    v_ch = jax.random.normal(ks_[4], (max_slots, hkv, chunk, d))
    pt = jnp.asarray(
        [[1 + s * max_pages + j for j in range(max_pages)]
         for s in range(max_slots)], jnp.int32)
    k_mode, v_mode = kv_modes(MODE_PER_TOKEN)

    def call(n_ctx, n_chunk):
        return qprefill_paged(
            q, pool.k_codes, pool.k_scale, pool.k_zero, pool.v_codes,
            pool.v_scale, pool.v_zero, k_ch, v_ch, pt, n_ctx, n_chunk,
            k_bits=bits, v_bits=bits, k_mode=k_mode, v_mode=v_mode,
            group_size=r, interpret=True)

    # each q tile re-streams the context (index maps are q-tile-independent)
    n_q_tiles = (chunk * g) // pick_block_q(chunk * g, DEFAULT_BLOCK_Q, g)
    rows = []
    for fill in (0.25, 0.50, 1.00):
        ctx_pages = max(int(max_pages * fill), 1)
        lens = [ctx_pages * r] * max_slots
        n_ctx = jnp.asarray(lens, jnp.int32)
        n_chunk = jnp.full((max_slots,), chunk, jnp.int32)
        us = _time_min(call, n_ctx, n_chunk, reps=reps)
        rows.append({
            "kernel": "qprefill_paged", "fill": fill,
            "live_ctx_pages": ctx_pages * max_slots,
            "max_pages_total": max_slots * max_pages,
            "us_per_call_interpret": us,
            "hbm_bytes_streamed": pool.prefill_stream_bytes(
                lens, chunk, q_tiles=n_q_tiles),
        })

    return {"rows": rows, "admission": _admission_burst(),
            "geometry": {"max_slots": max_slots, "max_pages": max_pages,
                         "hkv": hkv, "g": g, "d": d, "r": r, "bits": bits,
                         "chunk": chunk, "block_bytes": pool.block_bytes()}}


def _admission_burst(n_requests: int = 4, prompt_len: int = 12,
                     max_new: int = 4, seed: int = 0) -> dict:
    """4-request burst through a tiny engine: batched vs serial admission
    × prefill kernel on/off. Prompts fit one prefill chunk, so the batched
    path admits the whole burst in ONE wave dispatch where the serial path
    pays one dispatch per request."""
    import jax as _jax

    from repro.configs.base import ModelConfig
    from repro.core.precision import KVTunerSchedule, PrecisionPair
    from repro.models.registry import build_model
    from repro.serving.engine import ContinuousEngine, Request

    r = 8
    cfg = ModelConfig(name="prefill-burst-tiny", family="dense",
                      num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=61, q_chunk=16, kv_group_size=r)
    api = build_model(cfg)
    params = api.init(_jax.random.PRNGKey(0))
    sched = KVTunerSchedule.uniform(2, PrecisionPair(8, 4))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len)
               for _ in range(n_requests)]

    results = {}
    for batched in (False, True):
        for pallas in (False, True):
            eng = ContinuousEngine(
                api, params, sched, max_batch=n_requests,
                max_seq=prompt_len + max_new + r, prefill_paged=True,
                prefill_chunk=2 * r, batched_admission=batched,
                use_pallas=pallas)
            for i, p in enumerate(prompts):
                eng.submit(Request(uid=i, prompt=np.asarray(p),
                                   max_new_tokens=max_new))
            done = sorted(eng.run(), key=lambda q_: q_.uid)
            results[(batched, pallas)] = (
                [q_.output for q_ in done], eng.stats.prefill_dispatches)

    base = results[(False, False)][0]
    return {
        "n_requests": n_requests, "prompt_len": prompt_len,
        "serial_dispatches": results[(False, False)][1],
        "batched_dispatches": results[(True, False)][1],
        "serial_pallas_dispatches": results[(False, True)][1],
        "batched_pallas_dispatches": results[(True, True)][1],
        "outputs_identical": all(out == base
                                 for out, _ in results.values()),
    }


# =================================================================== verify
def run_verify(ctx=None, max_slots: int = 4, max_pages: int = 32,
               hkv: int = 2, g: int = 4, d: int = 64, r: int = 32,
               bits: int = 4, reps: int = 5) -> dict:
    """Fused speculative-verify sweep: one ``qverify_paged`` call scoring
    k+1 candidate positions per slot vs the k+1 serial ``qdecode_paged``
    calls it replaces, for k ∈ {2, 4, 8} × 25/50/100% context fill. The
    fused pass streams each live context block ONCE for all candidates
    (the candidate window rides in a bf16 side buffer), so both µs/call
    and the analytic bytes (``PagedKVPool.verify_stream_bytes``) must beat
    k+1 × the serial decode numbers."""
    import dataclasses

    from repro.cache.codec import kv_modes
    from repro.cache.paged import PagedKVPool
    from repro.core.precision import PrecisionPair
    from repro.kernels.qdecode import qdecode_paged
    from repro.kernels.qprefill import qverify_paged

    num_blocks = 1 + max_slots * max_pages
    pp = PrecisionPair(bits, bits)
    pool = PagedKVPool.init(num_blocks, max_slots, hkv, d, pp,
                            MODE_PER_TOKEN, r, dtype=jnp.bfloat16)
    key = jax.random.PRNGKey(0)
    ks_ = jax.random.split(key, 7)
    c = pool.codec
    kc, ksc, kz = c.k.encode(jax.random.normal(ks_[0], (num_blocks, hkv, r, d)))
    vc, vsc, vz = c.v.encode(jax.random.normal(ks_[1], (num_blocks, hkv, r, d)))
    pool = dataclasses.replace(
        pool, k_codes=kc, k_scale=ksc, k_zero=kz, v_codes=vc, v_scale=vsc,
        v_zero=vz,
        k_res=jax.random.normal(ks_[2], (max_slots, hkv, r, d), jnp.bfloat16),
        v_res=jax.random.normal(ks_[3], (max_slots, hkv, r, d), jnp.bfloat16))
    pt = jnp.asarray(
        [[1 + s * max_pages + j for j in range(max_pages)]
         for s in range(max_slots)], jnp.int32)
    k_mode, v_mode = kv_modes(MODE_PER_TOKEN)
    kwq = dict(k_bits=bits, v_bits=bits, k_mode=k_mode, v_mode=v_mode,
               group_size=r, interpret=True)

    def serial(q1, n_valid, n_res):
        return qdecode_paged(
            q1, pool.k_codes, pool.k_scale, pool.k_zero, pool.v_codes,
            pool.v_scale, pool.v_zero, pool.k_res, pool.v_res, pt,
            n_valid, n_res, **kwq)

    def fused(qv, k_win, v_win, n_main, n_res, n_win):
        return qverify_paged(
            qv, pool.k_codes, pool.k_scale, pool.k_zero, pool.v_codes,
            pool.v_scale, pool.v_zero, pool.k_res, pool.v_res, k_win, v_win,
            pt, n_main, n_res, n_win, **kwq)

    rows = []
    for k in (2, 4, 8):
        k1 = k + 1
        qv = jax.random.normal(ks_[4], (max_slots, hkv, k1 * g, d))
        q1 = qv[:, :, :g]
        k_win = jax.random.normal(ks_[5], (max_slots, hkv, k1, d),
                                  jnp.bfloat16)
        v_win = jax.random.normal(ks_[6], (max_slots, hkv, k1, d),
                                  jnp.bfloat16)
        n_win = jnp.full((max_slots,), k1, jnp.int32)
        for fill in (0.25, 0.50, 1.00):
            pages = max(int(max_pages * fill), 1)
            lens = [pages * r] * max_slots
            n_main = jnp.asarray(lens, jnp.int32)
            n_res = jnp.asarray([r // 2] * max_slots, jnp.int32)

            def serial_k1():
                # the k+1 single-token decode dispatches the fused verify
                # replaces — each re-streams every live context block
                out = None
                for _ in range(k1):
                    out = serial(q1, n_main + n_res, n_res)
                return out

            us_fused = _time_min(fused, qv, k_win, v_win, n_main, n_res,
                                 n_win, reps=reps)
            us_serial = _time_min(serial_k1, reps=reps)
            rows.append({
                "kernel": "qverify_paged", "k": k, "fill": fill,
                "live_pages": pages * max_slots,
                "us_fused": us_fused, "us_serial_k1": us_serial,
                "fused_bytes": pool.verify_stream_bytes(
                    [ln + r // 2 for ln in lens], k1),
                "serial_bytes": k1 * pool.decode_stream_bytes(
                    [ln + r // 2 for ln in lens]),
            })
    return {"rows": rows, "geometry": {
        "max_slots": max_slots, "max_pages": max_pages, "hkv": hkv, "g": g,
        "d": d, "r": r, "bits": bits, "block_bytes": pool.block_bytes()}}


def check_verify_claims(result: dict) -> dict[str, bool]:
    rows = result["rows"]
    full = [r for r in rows if r["fill"] == 1.0]
    k4 = {r["fill"]: r for r in rows if r["k"] == 4}
    return {
        "fused verify streams fewer bytes than k+1 serial decodes (all k)":
            all(r["fused_bytes"] < r["serial_bytes"] for r in rows),
        "fused byte advantage grows with k (context amortized once)":
            full[0]["serial_bytes"] / full[0]["fused_bytes"]
            < full[-1]["serial_bytes"] / full[-1]["fused_bytes"],
        "fused verify faster than k+1 serial decode calls (100% fill)":
            all(r["us_fused"] < r["us_serial_k1"] for r in full),
        "fused bytes track live context fill":
            k4[0.25]["fused_bytes"] < k4[0.5]["fused_bytes"]
            < k4[1.0]["fused_bytes"]
            and k4[0.25]["fused_bytes"] < 0.35 * k4[1.0]["fused_bytes"],
    }


def check_prefill_claims(result: dict) -> dict[str, bool]:
    by_fill = {r["fill"]: r for r in result["rows"]}
    full, quarter = by_fill[1.0], by_fill[0.25]
    adm = result["admission"]
    return {
        "us/call scales with live ctx (25% fill >= 2x faster than 100%)":
            full["us_per_call_interpret"]
            >= 2.0 * quarter["us_per_call_interpret"],
        "prefill bytes streamed track live ctx, not pool capacity":
            quarter["hbm_bytes_streamed"] < by_fill[0.5]["hbm_bytes_streamed"]
            < full["hbm_bytes_streamed"]
            and quarter["hbm_bytes_streamed"]
            < 0.5 * full["hbm_bytes_streamed"],
        "batched admission >= 2x fewer dispatches for a 4-request burst":
            adm["serial_dispatches"] >= 2 * adm["batched_dispatches"]
            and adm["serial_pallas_dispatches"]
            >= 2 * adm["batched_pallas_dispatches"],
        "greedy outputs identical across kernel x batched admission":
            adm["outputs_identical"],
    }


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--paged", action="store_true",
                    help="paged decode work-proportionality sweep (CI smoke)")
    ap.add_argument("--prefill", action="store_true",
                    help="fused prefill + batched admission sweep (CI smoke)")
    ap.add_argument("--verify", action="store_true",
                    help="fused speculative-verify vs serial decode sweep "
                         "(CI smoke)")
    args = ap.parse_args()

    if args.verify:
        result = run_verify()
        claims = check_verify_claims(result)
    elif args.prefill:
        result = run_prefill()
        claims = check_prefill_claims(result)
    elif args.paged:
        result = run_paged()
        claims = check_paged_claims(result)
    else:
        result = run()
        claims = check_paper_claims(result)
    print(json.dumps(result, indent=2, default=str))
    for claim, passed in claims.items():
        print(f"# [{'PASS' if passed else 'FAIL'}] {claim}", flush=True)
    if not all(claims.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
