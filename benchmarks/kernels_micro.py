"""Kernel microbenchmarks: interpret-mode timing (CPU; correctness-weighted)
plus the structural VMEM/HBM accounting the TPU roofline uses — per
(k_bits, v_bits) specialization of the fused decode kernel."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.precision import MODE_PER_TOKEN
from repro.kernels.qdecode import qdecode
from repro.kernels.kvquant import kvquant


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def run(ctx=None) -> dict:
    b, hkv, g, d, s = 1, 2, 4, 64, 512
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, hkv, g, d))
    k = jax.random.normal(key, (b, hkv, s, d))
    v = jax.random.normal(key, (b, hkv, s, d))
    n_valid = jnp.full((b,), s, jnp.int32)

    rows = []
    for bits in (8, 4, 2):
        kq = quant.quantize(k, bits, MODE_PER_TOKEN, 32)
        vq = quant.quantize(v, bits, MODE_PER_TOKEN, 32)
        us = _time(qdecode, q, kq.codes, kq.scale, kq.zero, vq.codes,
                   vq.scale, vq.zero, n_valid, k_bits=bits, v_bits=bits,
                   k_mode=MODE_PER_TOKEN, v_mode=MODE_PER_TOKEN,
                   interpret=True)
        # HBM bytes the kernel streams per call (codes + scales, both K and V)
        hbm = 2 * (kq.codes.size + 4 * kq.scale.size + 4 * kq.zero.size)
        rows.append({"kernel": "qdecode", "bits": bits,
                     "us_per_call_interpret": us, "hbm_bytes_streamed": hbm,
                     "vmem_tile_bytes": 128 * d * bits // 8})
        usq = _time(kvquant, k.reshape(b * hkv, s, d), bits, MODE_PER_TOKEN,
                    interpret=True)
        rows.append({"kernel": "kvquant", "bits": bits,
                     "us_per_call_interpret": usq,
                     "hbm_bytes_streamed": k.size * 2 + kq.codes.size,
                     "vmem_tile_bytes": 128 * d * 4})
    return {"rows": rows}


def check_paper_claims(result: dict) -> dict[str, bool]:
    dec = {r["bits"]: r for r in result["rows"] if r["kernel"] == "qdecode"}
    return {
        "streamed bytes scale with bits":
            dec[2]["hbm_bytes_streamed"] < dec[4]["hbm_bytes_streamed"]
            < dec[8]["hbm_bytes_streamed"],
        "4-bit halves 8-bit traffic (±20%)":
            0.4 < dec[4]["hbm_bytes_streamed"] / dec[8]["hbm_bytes_streamed"] < 0.72,
    }
