"""Table 15 (systems extension): serving telemetry — trace completeness,
tracing overhead, and the online quant-quality probe vs the offline
sensitivity table.

Three gated properties of the telemetry layer (ISSUE 10):

* **Completeness** — a traced run covering admission, prefix hits,
  cancellation and deadline expiry yields, for every submitted request, a
  gap-free properly-nested span tree ending in a terminal status; the
  Perfetto export round-trips through JSON and validates against the
  trace-event schema.
* **Overhead** — the tracer-gated per-dispatch hook path, measured
  directly (best-of-R microbenchmark) and amortized against the measured
  per-dispatch decode-wall floor, costs <3% decode tokens/s; and traced
  greedy outputs are token-identical to untraced ones (interleaved A/B
  rounds on warmed engines, so jit never lands in a timed round).
* **Probe fidelity** — the online per-layer e_k/e_v probe (sampled from
  live pool blocks during serving) orders layers consistently with the
  offline ``core/sensitivity.py`` table computed on the same prompts at
  the same reference precision — KVTuner's layer-sensitivity story,
  measured from the serving pool instead of calibration captures.

Standalone: ``PYTHONPATH=src python -m benchmarks.table15_telemetry
[--tiny]`` — writes ``experiments/artifacts/trace_t15.json`` (open in
https://ui.perfetto.dev) and ``BENCH_t15_telemetry.json``.
"""
from __future__ import annotations

import gc
import json
import time

import numpy as np

from repro.core.precision import KVTunerSchedule, PrecisionPair
from repro.core.quant import MODE_PER_TOKEN
from repro.core.sensitivity import capture_activations, layer_errors
from repro.serving.engine import ContinuousEngine, EngineStats, Request
from repro.serving.faults import FaultInjector
from repro.serving.trace import (Tracer, to_perfetto, validate_perfetto,
                                 validate_trace)

OVERHEAD_BUDGET = 0.03     # tracing may cost at most 3% decode tokens/s
ORDER_TIE_REL = 0.05       # offline errors within 5% count as a tie


def _order_consistent(offline, online, tie_rel: float = ORDER_TIE_REL) -> bool:
    """True when every layer pair the OFFLINE table separates by more than
    ``tie_rel`` relative error is ordered the same way by the online probe
    (near-ties are unconstrained — both tables estimate the same quantity
    from different samples)."""
    off = np.asarray(offline, float)
    on = np.asarray(online, float)
    for i in range(len(off)):
        for j in range(i + 1, len(off)):
            if abs(off[i] - off[j]) <= tie_rel * max(off[i], off[j]):
                continue
            if (off[i] - off[j]) * (on[i] - on[j]) < 0:
                return False
    return True


def run(ctx, n_templates: int = 3, per_template: int = 3,
        template_len: int = 32, suffix_len: int = 8, max_new: int = 16,
        max_batch: int = 3, seed: int = 0, pair: tuple = (8, 4),
        probe_bits: tuple = (2, 2), probe_every: int = 2,
        rounds: int = 5, prefill_chunk: int | None = None,
        trace_path: str | None = None) -> dict:
    from benchmarks.common import poisson_arrivals, shared_template_prompts

    cfg = ctx.api.cfg
    # uniform schedule: the probe/offline comparison needs one (mode, bits)
    # story across layers, and probe_bits must sit strictly below the
    # stored pair (RTN re-quantization at the stored bits is lossless)
    sched = KVTunerSchedule.uniform(cfg.num_layers, PrecisionPair(*pair),
                                    mode=MODE_PER_TOKEN)
    r = cfg.kv_group_size
    if prefill_chunk is None:
        prefill_chunk = 2 * r
    max_seq = template_len + suffix_len + max_new + r

    def make_prompts():
        rng = np.random.default_rng(seed)
        prompts = shared_template_prompts(cfg.vocab_size, n_templates,
                                          per_template, template_len,
                                          suffix_len, rng)
        arrivals = poisson_arrivals(len(prompts), 2.0, rng)
        return prompts, arrivals

    prompts, arrivals = make_prompts()
    n = len(prompts)

    def build(uid0: int = 0, lifecycle: bool = False, **kw):
        eng = kw.pop("engine", None)
        if eng is None:
            eng = ContinuousEngine(
                ctx.api, ctx.params, sched, max_batch=max_batch,
                max_seq=max_seq, prefix_cache=True,
                prefill_chunk=prefill_chunk, seed=seed, **kw)
        for i, p in enumerate(prompts):
            # lifecycle coverage: one request times out mid-run; a second
            # is cancelled by the injector's scheduled client churn
            deadline = arrivals[i] + 2 if lifecycle and i == n - 1 else None
            eng.submit(Request(uid=uid0 + i, prompt=p,
                               max_new_tokens=max_new,
                               arrival_step=arrivals[i],
                               deadline_step=deadline))
        done = sorted(eng.run(), key=lambda q: q.uid)
        return done, eng

    # ---- phase 1: coverage run (traced + probed + lifecycle endings) ----
    inj = FaultInjector(seed=seed, cancel_at=[(3, n // 2)])
    cov_done, cov = build(lifecycle=True, trace=True, faults=inj,
                          probe_every=probe_every,
                          probe_blocks=2 * max_batch,
                          probe_bits=probe_bits)
    trace_summary = validate_trace(cov.tracer)
    doc = to_perfetto(cov.tracer)
    round_trip = json.loads(json.dumps(doc))
    perfetto_counts = validate_perfetto(round_trip)
    if trace_path is not None:
        with open(trace_path, "w") as f:
            json.dump(doc, f)
    probe_summary = cov.probe.summary()

    # offline reference on the same prompts at the probe's reference pair
    # (prompts are equal-length → stackable into one capture batch)
    captures = capture_activations(
        ctx.api, ctx.params,
        [{"tokens": np.stack(prompts).astype(np.int32)}])
    offline = layer_errors(captures, cfg, MODE_PER_TOKEN,
                           pairs=[PrecisionPair(*probe_bits)])

    # ---- phase 2: overhead (direct hook-path cost / dispatch floor) ------
    # the <3% gate covers TRACING; the probe is a separately-knobbed
    # sampler whose cost scales with 1/probe_every (documented in
    # docs/observability.md, not gated here). Tracing adds no device work
    # and no extra dispatches (the token-identity claim proves semantics
    # unchanged), so its entire decode cost is the tracer-gated host hook
    # on each dispatch: ``_ctx_lens`` + analytic bytes + ``_note_dispatch``,
    # plus a handful of per-request span ops amortized over that request's
    # dispatches. Differencing two wall-clock runs cannot resolve that
    # ~10us effect here — host speed drifts several percent on sub-second
    # timescales, swamping A/B medians, per-round pairs AND best-of-N
    # floors — so the gate measures the hook path DIRECTLY (best-of-R
    # microbench, stable to ~1%) and divides by the measured per-dispatch
    # decode-wall floor of the untraced engine. The interleaved A/B rounds
    # still run for the identity claim and the reported (noisy) floors.
    engines: dict = {}
    outputs: dict = {}
    for mode, kw in (("off", {}), ("on", {"trace": True})):
        done, engines[mode] = build(**kw)    # warm round: jit compiles here
        outputs[mode] = [list(q.output) for q in done]
    walls: dict = {"off": [], "on": []}
    rates: dict = {"off": [], "on": []}
    for rd in range(1, rounds + 1):
        order = ("off", "on") if rd % 2 else ("on", "off")
        for mode in order:
            eng = engines[mode]
            eng.stats = EngineStats()
            if eng.tracer is not None:
                eng.tracer = Tracer()       # bound tracer state per round
            gc.collect()
            gc.disable()                    # no gen2 pauses inside a round
            try:
                build(uid0=rd * n, engine=eng)
            finally:
                gc.enable()
            walls[mode].append(list(eng.stats.step_wall_times))
            rates[mode].append(eng.stats.decode_tokens_per_s)
    floor = {}
    for mode, per_round in walls.items():
        depth = min(len(r) for r in per_round)
        floor[mode] = np.array([r[:depth] for r in per_round]).min(axis=0)
    per_mode = {m: float(np.median(v)) for m, v in rates.items()}

    eng = engines["on"]
    n_disp = len(floor["on"])
    lens = np.full(max_batch, max_seq - 1)

    def _best_of(fn, reps: int = 7, iters: int = 500) -> float:
        best = float("inf")
        for _ in range(reps):
            eng.tracer = Tracer()
            t0 = time.perf_counter()
            for _ in range(iters):
                fn()
            best = min(best, (time.perf_counter() - t0) / iters)
        return best

    def _dispatch_hook():
        eng._ctx_lens()
        eng._note_dispatch("decode", 0.0, 1e-3, eng._decode_bytes(lens),
                           slots=max_batch)

    def _request_hook():
        eng.tracer.begin(0)
        eng.tracer.phase(0, "prefill")
        eng.tracer.phase(0, "decode")
        eng.tracer.finish(0, "done")

    hook_cost = _best_of(_dispatch_hook)
    req_cost = _best_of(_request_hook)
    eng.tracer = Tracer()
    per_dispatch = hook_cost + req_cost * n / max(n_disp, 1)
    floor_mean = float(floor["off"].mean())
    overhead = per_dispatch / max(floor_mean, 1e-12)
    ab_ratio = float(floor["on"].sum()) / max(float(floor["off"].sum()),
                                              1e-12) - 1.0

    return {
        "workload": {"n_requests": n, "n_templates": n_templates,
                     "template_len": template_len, "suffix_len": suffix_len,
                     "max_new": max_new, "seed": seed, "pair": list(pair),
                     "probe_bits": list(probe_bits), "rounds": rounds},
        "trace": trace_summary,
        "statuses": trace_summary["statuses"],
        "perfetto": perfetto_counts,
        "probe": probe_summary,
        "offline": {"e_k": offline.e_k[:, 0].tolist(),
                    "e_v": offline.e_v[:, 0].tolist()},
        "order_consistent": {
            "e_k": _order_consistent(offline.e_k[:, 0], probe_summary["e_k"]),
            "e_v": _order_consistent(offline.e_v[:, 0], probe_summary["e_v"]),
        },
        "bandwidth": {
            name: cov.metrics.gauge(f"engine.{name}_achieved_gbps").value
            for name in ("decode", "prefill")},
        "decode_tokens_per_s": per_mode,
        "decode_dispatch_floor_ms": {m: float(v.sum()) * 1e3
                                     for m, v in floor.items()},
        "hook_cost_us": hook_cost * 1e6,
        "request_hook_cost_us": req_cost * 1e6,
        "ab_floor_ratio": ab_ratio,
        "trace_overhead_frac": overhead,
        "outputs_identical": outputs["on"] == outputs["off"],
    }


def check_paper_claims(result: dict) -> dict[str, bool]:
    tr = result["trace"]
    return {
        "every request traced to a gap-free terminal span tree":
            tr["terminal"] == result["workload"]["n_requests"],
        "trace covers done + cancelled + timed-out endings":
            {"done", "cancelled", "timed_out"} <= set(tr["statuses"]),
        "perfetto export round-trips and validates":
            result["perfetto"]["X"] > 0 and result["perfetto"]["M"] > 0,
        "probe sampled live pool blocks on every layer":
            result["probe"]["samples"] > 0
            and len(result["probe"]["layers"]) > 0
            and all(np.isfinite(result["probe"]["e_k"]))
            and all(np.isfinite(result["probe"]["e_v"])),
        "online probe orders layers like the offline table (e_k)":
            result["order_consistent"]["e_k"],
        "online probe orders layers like the offline table (e_v)":
            result["order_consistent"]["e_v"],
        "achieved-bandwidth gauges populated":
            result["bandwidth"]["decode"] > 0
            and result["bandwidth"]["prefill"] > 0,
        "traced outputs token-identical to untraced":
            result["outputs_identical"],
        f"tracing overhead < {OVERHEAD_BUDGET:.0%} decode tokens/s":
            result["trace_overhead_frac"] < OVERHEAD_BUDGET,
    }


def main() -> None:
    import argparse
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="random tiny model + small workload (CI smoke)")
    args = ap.parse_args()

    from benchmarks.common import BENCH_DIR, write_bench_json
    os.makedirs(BENCH_DIR, exist_ok=True)
    trace_path = os.path.join(BENCH_DIR, "trace_t15.json")

    if args.tiny:
        from benchmarks.common import tiny_serving_ctx
        ctx = tiny_serving_ctx("t15-tiny")
        result = run(ctx, n_templates=2, per_template=4, template_len=24,
                     suffix_len=8, max_new=24, max_batch=3, rounds=7,
                     prefill_chunk=16, trace_path=trace_path)
    else:
        from benchmarks.common import get_bench_model
        ctx = get_bench_model(log=lambda *a: print(*a, flush=True))
        result = run(ctx, trace_path=trace_path)

    claims = check_paper_claims(result)
    print(json.dumps(result, indent=2, default=str))
    for claim, passed in claims.items():
        print(f"# [{'PASS' if passed else 'FAIL'}] {claim}", flush=True)
    path = write_bench_json("t15_telemetry", result, claims,
                            config={"tiny": args.tiny},
                            seed=result["workload"]["seed"])
    print(f"# trace: {trace_path}\n# bench record: {path}", flush=True)
    if not all(claims.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
