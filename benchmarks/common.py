"""Shared benchmark context: a small transformer *trained from scratch* on the
error-amplifying synthetic reasoning tasks (DESIGN.md §6), cached under
experiments/artifacts so every table reuses the same model.

All paper-table benchmarks run against this trained model — randomly
initialized nets have flat attention and cannot exhibit the sensitivity
structure the paper measures (verified in tests/test_kvtuner.py).
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data import synthetic
from repro.data.pipeline import SyntheticSource
from repro.models.registry import ModelApi, build_model
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.trainer import Trainer

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "artifacts", "bench_model")
# 700 steps leaves the chain task half-solved (exact-match ~0.56, loss still
# falling); the paper-claim tests gate on a model that actually solves it
# (tests/test_trained_claims.py needs exact-match > 0.9, reached by ~2000).
TRAIN_STEPS = 2000


def bench_config() -> ModelConfig:
    return ModelConfig(
        name="bench-lm", family="dense", num_layers=6, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=64, q_chunk=64)


def bench_task() -> synthetic.TaskConfig:
    return synthetic.TaskConfig(vocab_size=64, chain_len=8, seq_len=64)


@dataclasses.dataclass
class BenchContext:
    api: ModelApi
    params: dict
    task: synthetic.TaskConfig

    def calib_batches(self, n: int = 2, batch: int = 8, seed: int = 1000):
        """Small capture-friendly calibration prompts (paper: first 20)."""
        rng = np.random.default_rng(seed)
        return [{k: jnp.asarray(v) for k, v in
                 synthetic.mixed_batch(self.task, batch, rng).items()}
                for _ in range(n)]

    def eval_batches(self, n: int = 3, batch: int = 64, seed: int = 7000,
                     kind: str = "chain"):
        rng = np.random.default_rng(seed)
        fn = {"chain": synthetic.chain_batch,
              "recall": synthetic.recall_batch,
              "mixed": synthetic.mixed_batch}[kind]
        return [{k: jnp.asarray(v) for k, v in
                 fn(self.task, batch, rng).items()} for _ in range(n)]


def get_bench_model(train_steps: int = TRAIN_STEPS,
                    log=lambda *a: None) -> BenchContext:
    cfg = bench_config()
    api = build_model(cfg)
    task = bench_task()
    ckpt = CheckpointManager(ART_DIR, keep=1)
    opt = AdamW(lr=cosine_schedule(1e-3, 50, train_steps))
    src = SyntheticSource(task=task, batch_size=32, kind="mixed", seed=0)
    trainer = Trainer(api=api, optimizer=opt, source=src, ckpt=ckpt,
                      ckpt_every=200, log_every=100, log_fn=log)
    abstract = jax.eval_shape(trainer.init_state, jax.random.PRNGKey(0))
    latest = ckpt.latest_step()
    if latest is not None and latest >= train_steps:
        _, state, _ = ckpt.restore_latest(abstract)
        log(f"[bench] loaded trained model from step {latest}")
        return BenchContext(api=api, params=state.params, task=task)
    t0 = time.time()
    state, _ = trainer.run(train_steps)
    log(f"[bench] trained {train_steps} steps in {time.time() - t0:.0f}s")
    return BenchContext(api=api, params=state.params, task=task)


def ppl_from_nll(nll: float) -> float:
    return float(np.exp(min(nll, 30.0)))


# -------------------------------------------------- machine-readable output
BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "artifacts")
BENCH_SCHEMA_KEYS = ("bench", "passed", "claims", "result", "config", "seed")


def write_bench_json(name: str, result, claims: dict, config: dict | None =
                     None, seed: int | None = None, out_dir: str | None =
                     None) -> str:
    """Write one benchmark entry's machine-readable record
    (``BENCH_<name>.json``): its claim checks with overall pass/fail, the
    measured result payload, and the run's config + seed — the perf
    trajectory across PRs lives in these files, not in stdout. Returns the
    path written."""
    import json

    out_dir = BENCH_DIR if out_dir is None else out_dir
    os.makedirs(out_dir, exist_ok=True)
    doc = {
        "bench": name,
        "passed": all(claims.values()) if claims else True,
        "claims": {k: bool(v) for k, v in claims.items()},
        "result": result,
        "config": config or {},
        "seed": seed,
    }
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, default=str)
    return path


def validate_bench_json(path: str) -> dict:
    """Schema-check one ``BENCH_<name>.json`` file (CI gate); returns the
    parsed document or raises ``ValueError`` listing every violation."""
    import json

    with open(path) as f:
        doc = json.load(f)
    issues = [f"missing key {k!r}" for k in BENCH_SCHEMA_KEYS if k not in doc]
    if not issues:
        if not isinstance(doc["bench"], str):
            issues.append("'bench' is not a string")
        if not isinstance(doc["passed"], bool):
            issues.append("'passed' is not a bool")
        if not isinstance(doc["claims"], dict) or \
                not all(isinstance(v, bool) for v in doc["claims"].values()):
            issues.append("'claims' is not a {name: bool} map")
        if not isinstance(doc["config"], dict):
            issues.append("'config' is not an object")
        if doc["claims"] and doc["passed"] != all(doc["claims"].values()):
            issues.append("'passed' disagrees with the claim values")
    if issues:
        raise ValueError(f"{path}: " + "; ".join(issues))
    return doc


# ------------------------------------------------------- serving workloads
# Every serving benchmark builds its request stream through these helpers
# with an EXPLICIT seed (no module-level RNG state anywhere on the path), so
# a (seed, shape) pair pins the workload bit-for-bit across table8 / table11
# / table12 runs and CI reruns.

def tiny_serving_ctx(name: str):
    """Milliseconds-scale random-weight model context for CI smoke runs of
    the serving benchmarks (table11/table12 ``--tiny``) — scheduling, tier,
    and token-identity behavior do not depend on trained weights."""
    import jax

    @dataclasses.dataclass
    class TinyCtx:
        api: ModelApi
        params: dict

    cfg = ModelConfig(name=name, family="dense", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=61,
                      q_chunk=16, kv_group_size=8)
    api = build_model(cfg)
    return TinyCtx(api=api, params=api.init(jax.random.PRNGKey(0)))


def poisson_arrivals(n: int, rate: float,
                     rng: np.random.Generator) -> list[int]:
    """Cumulative Poisson inter-arrival offsets in decode-step units; the
    first request arrives at step 0."""
    if n <= 0:
        return []
    return np.concatenate(
        [[0], np.cumsum(rng.poisson(rate, n - 1))]).tolist()


def shared_template_prompts(vocab: int, n_templates: int, per_template: int,
                            template_len: int, suffix_len: int,
                            rng: np.random.Generator) -> list[np.ndarray]:
    """Template-interleaved shared-prefix prompts: request ``i`` uses
    template ``i % n_templates`` plus a fresh random suffix — the traffic
    shape where prefix caching (and, under pool pressure, host-tier spills)
    matters."""
    templates = [rng.integers(0, vocab, template_len)
                 for _ in range(n_templates)]
    return [np.concatenate([templates[i % n_templates],
                            rng.integers(0, vocab, suffix_len)])
            for i in range(n_templates * per_template)]
