"""Paper Table 3 + Fig. 3/13: layer-wise relative attention output error e_o
for the 9 uniform precision pairs (per-token-asym), and the K-vs-V importance
ordering on the trained model. Also validates prompt-independence (§4.5) and
the attention-pattern correlation (§4.4 / Lemma 1)."""
from __future__ import annotations

import numpy as np

from repro.core import sensitivity
from repro.core.precision import MODE_PER_TOKEN


def run(ctx) -> dict:
    caps_a = sensitivity.capture_activations(ctx.api, ctx.params,
                                             ctx.calib_batches(seed=1000))
    errs_a = sensitivity.layer_errors(caps_a, ctx.api.cfg, MODE_PER_TOKEN)
    # second, disjoint prompt set → prompt-independence check
    caps_b = sensitivity.capture_activations(ctx.api, ctx.params,
                                             ctx.calib_batches(seed=4242))
    errs_b = sensitivity.layer_errors(caps_b, ctx.api.cfg, MODE_PER_TOKEN)

    names = [p.name for p in errs_a.pairs]
    model_eo = errs_a.e_o.mean(axis=0)
    rows = [{"pair": n, "e_o": float(model_eo[i]),
             "per_layer": [float(x) for x in errs_a.e_o[:, i]]}
            for i, n in enumerate(names)]

    # layer-sensitivity profile correlation across prompt sets (§4.5)
    prof_a = errs_a.e_o[:, names.index("KV4")]
    prof_b = errs_b.e_o[:, names.index("KV4")]
    corr = float(np.corrcoef(prof_a, prof_b)[0, 1])

    # attention-pattern correlation (Lemma 1): sparse/concentrated layers
    # should be LESS sensitive → negative corr(sparsity, e_o)
    sparsity = sensitivity.attention_pattern_stats(caps_a, ctx.api.cfg.q_per_kv)
    pat_corr = float(np.corrcoef(sparsity, prof_a)[0, 1])

    by = dict(zip(names, model_eo))
    result = {
        "rows": rows,
        "prompt_independence_corr": corr,
        "sparsity_eo_corr": pat_corr,
        "claims": {
            "K8V4 < K4V8 (K more important)": bool(by["K8V4"] < by["K4V8"]),
            "K4V2 < K2V4 (K more important)": bool(by["K4V2"] < by["K2V4"]),
            "K8V2 <= K4V8 region (5-bit vs 6-bit)":
                bool(by["K8V2"] <= by["K4V8"] * 1.5),
            "prompt-independent layer profile (corr>0.8)": bool(corr > 0.8),
            "sparser layers more robust (corr<0)": bool(pat_corr < 0),
        },
    }
    return result


def check_paper_claims(result: dict) -> dict[str, bool]:
    return result["claims"]
