"""Paper Table 10 + §D.1.2: inter-layer clustering — search-space reduction
from 9^L through Π|S_p| (pruning) to Π over clustered groups."""
from __future__ import annotations

from repro.core import sensitivity
from repro.core.clustering import cluster_layers
from repro.core.precision import MODE_KIVI, MODE_PER_TOKEN
from repro.core.pruning import prune_intra_layer


def run(ctx) -> dict:
    caps = sensitivity.capture_activations(ctx.api, ctx.params,
                                           ctx.calib_batches())
    out = {}
    for mode in (MODE_PER_TOKEN, MODE_KIVI):
        errs = sensitivity.layer_errors(caps, ctx.api.cfg, mode)
        pruned = prune_intra_layer(errs)
        groups = cluster_layers(pruned, eps=0.25)
        out[mode] = {
            "L": pruned.num_layers,
            "G": groups.num_groups,
            "groups": groups.groups,
            "space_full": float(9) ** pruned.num_layers,
            "space_pruned": pruned.space_size(),
            "space_grouped": groups.search_space_size(),
        }
    return out


def check_paper_claims(result: dict) -> dict[str, bool]:
    r = result[MODE_PER_TOKEN]
    return {
        "G <= L": r["G"] <= r["L"],
        "grouping covers all layers": sorted(
            l for g in r["groups"] for l in g) == list(range(r["L"])),
        "space monotone: grouped <= pruned <= full":
            r["space_grouped"] <= r["space_pruned"] <= r["space_full"],
    }
