"""Table 13 (systems extension): fault-tolerant serving under chaos.

KVTuner's serving claim is *nearly lossless* — this benchmark checks that
the property survives an unreliable substrate, not just a perfect one. Two
engines serve the identical shared-template Poisson request stream:

* **clean** (baseline): no faults — every request completes.
* **chaos**: the same stream through a seeded :class:`FaultInjector` —
  probabilistic allocator exhaustion, host-tier put/get failures, two
  mid-flight client cancellations, one NaN-poisoned slot and one corrupted
  packed pool block — with ``guard_nan`` quarantine and the engine-wide
  invariant auditor (``audit=True``) running at every host sync.

Claims enforced (the ISSUE 8 acceptance criteria):

* every submitted request reaches a terminal status (nothing hangs, the
  engine never raises);
* every *surviving* request's greedy output is token-identical to the
  clean run — faults end requests, they never corrupt co-scheduled ones;
* exactly the injected poison + corruption are quarantined;
* every injected fault class actually fired (the schedule is not vacuous);
* the auditor reports zero leaked or aliased blocks at drain.

Reported: terminal-status breakdown, fired-fault counts, quarantine count,
auditor summary, throughput of both runs.

Standalone: ``PYTHONPATH=src python -m benchmarks.table13_chaos [--tiny]``
(``--tiny`` drives a milliseconds-scale random model — the CI smoke mode).
"""
from __future__ import annotations

import numpy as np

from repro.core.precision import KVTunerSchedule, PrecisionPair
from repro.serving.engine import ContinuousEngine, Request, RequestStatus
from repro.serving.faults import FaultInjector


def build_workload(vocab: int, n_templates: int, per_template: int,
                   template_len: int, suffix_len: int, max_new: int,
                   seed: int = 0, arrival_rate: float = 2.0):
    from benchmarks.common import poisson_arrivals, shared_template_prompts

    rng = np.random.default_rng(seed)
    prompts = shared_template_prompts(vocab, n_templates, per_template,
                                      template_len, suffix_len, rng)
    arrivals = poisson_arrivals(len(prompts), arrival_rate, rng)
    return [Request(uid=i, prompt=p, max_new_tokens=max_new,
                    arrival_step=arrivals[i], priority=i % 4)
            for i, p in enumerate(prompts)]


def run(ctx, n_templates: int = 3, per_template: int = 4,
        template_len: int = 32, suffix_len: int = 8, max_new: int = 10,
        max_batch: int = 3, seed: int = 0, fault_seed: int = 1234,
        sched=None, prefill_chunk: int | None = None,
        use_pallas: bool = False) -> dict:
    cfg = ctx.api.cfg
    if sched is None:
        from repro.launch.steps import default_schedule
        sched = default_schedule(cfg, "kvtuner")
    r = cfg.kv_group_size
    if prefill_chunk is None:
        prefill_chunk = 2 * r
    max_seq = template_len + suffix_len + max_new + r
    pages_per_req = max_seq // r + 1

    def make_reqs():
        return build_workload(cfg.vocab_size, n_templates, per_template,
                              template_len, suffix_len, max_new, seed=seed)

    n = n_templates * per_template
    mid = [q.uid for q in make_reqs()][n // 3], \
        [q.uid for q in make_reqs()][2 * n // 3]

    def drive(faults, **kw):
        eng = ContinuousEngine(
            ctx.api, ctx.params, sched, max_batch=max_batch,
            max_seq=max_seq, prefix_cache=True, prefill_chunk=prefill_chunk,
            seed=seed, use_pallas=use_pallas, scheduler="priority",
            host_blocks=3 * max_batch * pages_per_req, faults=faults, **kw)
        for q in make_reqs():
            eng.submit(q)
        done = sorted(eng.run(), key=lambda q: q.uid)
        eng.alloc.assert_consistent()
        return done, eng

    clean_done, clean = drive(None)
    inj = FaultInjector(seed=fault_seed, p_alloc_fail=0.15,
                        p_host_put_fail=0.3, p_host_get_fail=0.3,
                        cancel_at=[(4, mid[0]), (11, mid[1])],
                        poison_at=[(6, (n // 2))], corrupt_at=[9])
    # the chaos run is TRACED: the observability claims below assert every
    # injected fault is visible from the telemetry layer alone
    chaos_done, chaos = drive(
        inj, guard_nan=True, audit=True, stall_ticks=40, max_waiting=2 * n,
        num_blocks=1 + (max_batch + 1) * pages_per_req, trace=True)
    audit_summary = chaos.audit()

    from repro.serving.trace import validate_trace
    trace_summary = validate_trace(chaos.tracer)
    reg = chaos.metrics
    fault_counters = {name: reg.counter(name).value
                      for name in reg.names() if name.startswith("faults.")}
    fault_events = [name for _, name, _ in chaos.tracer.engine_events
                    if name.startswith("fault.")]
    quarantine_events = sum(
        1 for rt in chaos.tracer.requests.values()
        for _, name, _ in rt.events if name == "quarantine")

    clean_out = {q.uid: list(q.output) for q in clean_done}
    survivors = [q for q in chaos_done if q.status == RequestStatus.DONE]
    return {
        "workload": {"n_requests": n, "n_templates": n_templates,
                     "template_len": template_len, "suffix_len": suffix_len,
                     "max_new": max_new, "seed": seed,
                     "fault_seed": fault_seed, "use_pallas": use_pallas},
        "clean": {"tokens_per_s": clean.stats.throughput,
                  "terminal_counts": clean.stats.terminal_counts},
        "chaos": {"tokens_per_s": chaos.stats.throughput,
                  "terminal_counts": chaos.stats.terminal_counts,
                  "quarantined": chaos.stats.quarantined,
                  "faults_fired": inj.summary(),
                  "corrupted_uids": sorted(inj.corrupted_uids),
                  "audit": audit_summary,
                  "trace": trace_summary,
                  "fault_counters": fault_counters,
                  "fault_events": sorted(fault_events),
                  "quarantine_events": quarantine_events,
                  "metrics": reg.snapshot()},
        "all_terminal": all(q.terminal for q in chaos_done)
                        and len(chaos_done) == n,
        "survivors": len(survivors),
        "survivors_identical": all(list(q.output) == clean_out[q.uid]
                                   for q in survivors),
        "clean_all_done": all(q.status == RequestStatus.DONE
                              for q in clean_done),
    }


def check_paper_claims(result: dict) -> dict[str, bool]:
    c = result["chaos"]
    fired = c["faults_fired"]
    return {
        "clean run completes every request":
            result["clean_all_done"],
        "every request terminal under chaos (no hangs, no crash)":
            result["all_terminal"],
        "surviving outputs token-identical to the unfaulted run":
            result["survivors"] > 0 and result["survivors_identical"],
        "allocator exhaustion fired": fired["alloc_faults"] > 0,
        "host-tier faults fired":
            fired["host_put_faults"] + fired["host_get_faults"] > 0,
        "mid-flight cancellations fired": fired["cancels_fired"] == 2,
        "NaN poison + block corruption fired":
            fired["poisons_fired"] == 1 and fired["corruptions_fired"] == 1,
        "quarantine isolated exactly the poisoned/corrupted slots":
            c["quarantined"] == 2,
        "auditor clean at drain (zero leaked/aliased blocks)":
            c["audit"]["live_slots"] == 0 and c["audit"]["swap_parked"] == 0,
        # observability: the faults are visible from telemetry alone
        "fault counters match the injector's fired counts":
            c["fault_counters"].get("faults.alloc", 0)
            == fired["alloc_faults"]
            and c["fault_counters"].get("faults.host_put", 0)
            == fired["host_put_faults"]
            and c["fault_counters"].get("faults.host_get", 0)
            == fired["host_get_faults"]
            and c["fault_counters"].get("faults.cancel", 0)
            == fired["cancels_fired"]
            and c["fault_counters"].get("faults.poison", 0)
            == fired["poisons_fired"]
            and c["fault_counters"].get("faults.corrupt", 0)
            == fired["corruptions_fired"],
        "every fired fault left a trace event":
            len(c["fault_events"]) == sum(fired.values()),
        "quarantines visible as trace events":
            c["quarantine_events"] == c["quarantined"],
        "chaos trace complete (every request a gap-free terminal tree)":
            c["trace"]["terminal"] == result["workload"]["n_requests"],
    }


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="random tiny model + small workload (CI smoke)")
    args = ap.parse_args()

    if args.tiny:
        from benchmarks.common import tiny_serving_ctx
        ctx = tiny_serving_ctx("t13-tiny")
        result = run(ctx, n_templates=2, per_template=4, template_len=24,
                     suffix_len=8, max_new=8, max_batch=3,
                     sched=KVTunerSchedule.uniform(2, PrecisionPair(8, 4)),
                     prefill_chunk=16)
    else:
        from benchmarks.common import get_bench_model
        ctx = get_bench_model(log=lambda *a: print(*a, flush=True))
        result = run(ctx)

    claims = check_paper_claims(result)
    print(json.dumps(result, indent=2, default=str))
    for claim, passed in claims.items():
        print(f"# [{'PASS' if passed else 'FAIL'}] {claim}", flush=True)
    if not all(claims.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
