"""Table 14 (systems extension): multi-device paged serving on a mesh.

The quantized paged pool (packed codes + scales + bf16 residual windows)
shards by KV head over the ``model`` mesh axis; page table, lengths,
weights and the block allocator replicate. Attention runs embarrassingly
parallel per KV-head shard — the only collective on the serving path is
the all-gather of the per-token attention output — so sharding changes
*where bytes live*, never *which tokens come out*.

This benchmark runs the table8 engine workload twice — single-device vs a
forced-8-device CPU mesh (``--xla_force_host_platform_device_count``) —
and gates the two acceptance properties:

* greedy outputs token-identical across the mesh boundary, with the
  fused pallas kernels both off and on;
* per-shard analytic KV stream bytes exactly 1/N of the global counters
  (each shard streams only its own heads; no KV all-gather anywhere).

The model uses ``num_kv_heads=8`` (one head per device) — the bench model
has 2 KV heads, which does not divide an 8-wide axis and would exercise
only the replicated fallback.

Standalone: ``PYTHONPATH=src python -m benchmarks.table14_sharded [--tiny]``
(the 8-device flag is set automatically before jax initializes). Via
``benchmarks.run`` — where the parent process already initialized jax with
one device — it transparently re-invokes itself in a subprocess.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.precision import KVTunerSchedule, PrecisionPair
from repro.models.registry import ModelApi, build_model
from repro.serving.engine import ContinuousEngine, Request

N_DEV = 8


@dataclasses.dataclass
class ShardedCtx:
    api: ModelApi
    params: dict


def sharded_serving_ctx(tiny: bool = False) -> ShardedCtx:
    """Random-weight model whose KV heads divide the 8-wide mesh axis
    (token identity and byte accounting do not depend on trained
    weights — same rationale as ``tiny_serving_ctx``)."""
    import jax

    if tiny:
        cfg = ModelConfig(name="t14-tiny", family="dense", num_layers=2,
                          d_model=64, num_heads=16, num_kv_heads=N_DEV,
                          d_ff=128, vocab_size=61, q_chunk=16,
                          kv_group_size=8)
    else:
        cfg = ModelConfig(name="t14-sharded", family="dense", num_layers=4,
                          d_model=128, num_heads=16, num_kv_heads=N_DEV,
                          d_ff=256, vocab_size=64, q_chunk=32,
                          kv_group_size=8)
    api = build_model(cfg)
    return ShardedCtx(api=api, params=api.init(jax.random.PRNGKey(0)))


def run(ctx, n_requests: int = 6, max_new: int = 8, max_batch: int = 2,
        seed: int = 0) -> dict:
    """Single-device vs 8-device mesh on the table8 Poisson workload."""
    import jax

    from benchmarks.common import poisson_arrivals
    from repro.launch.mesh import make_test_mesh

    cfg = ctx.api.cfg
    sched = KVTunerSchedule.uniform(len(cfg.attention_layers()),
                                    PrecisionPair(8, 4))
    rng = np.random.default_rng(seed)
    plens = rng.choice([32, 48, 64], size=n_requests)
    arrivals = poisson_arrivals(n_requests, 1.5, rng)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)) for n in plens]
    max_seq = int(plens.max()) + max_new

    def drive(**kw):
        eng = ContinuousEngine(ctx.api, ctx.params, sched,
                               max_batch=max_batch, max_seq=max_seq,
                               seed=seed, **kw)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new,
                               arrival_step=int(arrivals[i])))
        done = sorted(eng.run(), key=lambda r: r.uid)
        eng.alloc.assert_consistent()
        return [list(r.output) for r in done], eng

    base_out, base = drive()
    mesh = make_test_mesh(N_DEV)
    variants = {"xla": {}, "pallas": {"use_pallas": True}}
    sharded, identical = {}, {}
    for name, kw in variants.items():
        # the gated property is "sharding changes nothing": compare against
        # the single-device engine with the SAME kernel setting (kernel
        # on/off parity is its own suite — tests/test_qdecode_fused.py)
        ref_out = base_out if not kw else drive(**kw)[0]
        out, eng = drive(mesh=mesh, **kw)
        identical[name] = out == ref_out
        s = eng.stats
        sharded[name] = {
            "tokens_per_s": s.throughput,
            "decode_tokens_per_s": s.decode_tokens_per_s,
            "decode_steps": s.decode_steps,
            "decode_compilations": eng.decode_compilations,
            "n_shards": s.n_shards,
            "shard_pool_utilization": s.shard_pool_utilization,
            "shard_pool_high_watermark": s.shard_pool_high_watermark,
        }

    # analytic KV traffic: every counter is proportional to Hkv, so one
    # shard of the final request lengths streams EXACTLY total/N
    pool = base.state.pools[0]
    final_lens = [int(n) + max_new for n in plens]
    bytes_global = {
        "block_bytes": pool.block_bytes(),
        "decode_stream_bytes": pool.decode_stream_bytes(final_lens),
    }
    bytes_shard = {
        "block_bytes": pool.block_bytes(n_shards=N_DEV),
        "decode_stream_bytes": pool.decode_stream_bytes(final_lens,
                                                        n_shards=N_DEV),
    }

    return {
        "workload": {"n_requests": n_requests, "max_new": max_new,
                     "max_batch": max_batch, "seed": seed,
                     "prompt_lens": plens.tolist(),
                     "arrival_steps": list(arrivals)},
        "mesh": {"n_devices": len(jax.devices()), "axis": "model",
                 "kv_heads": cfg.num_kv_heads,
                 "heads_per_shard": cfg.num_kv_heads // N_DEV},
        "single": {"tokens_per_s": base.stats.throughput,
                   "decode_compilations": base.decode_compilations,
                   "n_shards": base.stats.n_shards},
        "sharded": sharded,
        "bytes": {"global": bytes_global, "per_shard": bytes_shard},
        "outputs_identical": identical,
        "metrics": eng.metrics.snapshot(),
    }


def check_paper_claims(result: dict) -> dict[str, bool]:
    sh, bg, bs = result["sharded"], result["bytes"]["global"], \
        result["bytes"]["per_shard"]
    return {
        "mesh outputs token-identical to single-device (xla)":
            result["outputs_identical"]["xla"],
        "mesh outputs token-identical to single-device (pallas)":
            result["outputs_identical"]["pallas"],
        "pool sharded across all 8 devices":
            all(v["n_shards"] == N_DEV for v in sh.values()),
        "per-shard KV bytes exactly 1/8 of global":
            all(bs[k] * N_DEV == bg[k] for k in bg),
        "decode step compiles once on the mesh":
            sh["xla"]["decode_compilations"] == 1,
    }


def run_subprocess(tiny: bool = False) -> dict:
    """Entry point for ``benchmarks.run``: the parent process has already
    initialized jax (usually with one CPU device), and
    ``--xla_force_host_platform_device_count`` cannot take effect after
    backend init — so re-invoke this module in a fresh interpreter and
    parse its ``--json`` output."""
    import jax

    if len(jax.devices()) >= N_DEV:
        ctx = sharded_serving_ctx(tiny=tiny)
        return run(ctx, **({"n_requests": 4, "max_new": 6} if tiny else {}))
    env = dict(os.environ)
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH")) if p)
    env.pop("XLA_FLAGS", None)       # child sets its own device count
    cmd = [sys.executable, "-m", "benchmarks.table14_sharded", "--json"]
    if tiny:
        cmd.append("--tiny")
    out = subprocess.run(cmd, env=env, cwd=root, capture_output=True,
                         text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"table14 subprocess failed:\n{out.stderr[-4000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def main() -> None:
    import argparse

    from repro.launch.mesh import force_host_device_count

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="small random model + short workload (CI smoke)")
    ap.add_argument("--json", action="store_true",
                    help="print the result dict as a single JSON line")
    args = ap.parse_args()

    # before any jax backend init — this is why model construction lives
    # inside main-time helpers, not at module import
    force_host_device_count(N_DEV)
    ctx = sharded_serving_ctx(tiny=args.tiny)
    result = run(ctx, **({"n_requests": 4, "max_new": 6} if args.tiny else {}))

    claims = check_paper_claims(result)
    if args.json:
        print(json.dumps(result, default=str))
    else:
        print(json.dumps(result, indent=2, default=str))
    for claim, passed in claims.items():
        print(f"# [{'PASS' if passed else 'FAIL'}] {claim}",
              file=sys.stderr if args.json else sys.stdout, flush=True)
    if not all(claims.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
