"""Offline calibration pipeline on the trained benchmark model: sensitivity
capture → intra-layer pruning → inter-layer clustering → NSGA-II search →
exported schedule JSON (what production serving loads, paper Fig. 1).

Run: PYTHONPATH=src python examples/calibrate_search.py [--mode kivi]
"""
import argparse
import os
import sys


sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import get_bench_model
from repro.core.precision import MODE_KIVI, MODE_PER_TOKEN
from repro.core.tuner import KVTuner

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "artifacts", "schedules")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default=MODE_PER_TOKEN,
                    choices=[MODE_PER_TOKEN, MODE_KIVI])
    ap.add_argument("--generations", type=int, default=6)
    args = ap.parse_args()

    ctx = get_bench_model(log=print)
    tuner = KVTuner(ctx.api, ctx.params, mode=args.mode)

    print("== sensitivity / pruning / clustering ==")
    errors, pruned, groups = tuner.analyze(ctx.calib_batches())
    names = [p.name for p in errors.pairs]
    print("layer-avg e_o per pair:")
    for i, n in enumerate(names):
        print(f"  {n:6s} {errors.e_o[:, i].mean():.4f}")
    for l in range(pruned.num_layers):
        print(f"  layer {l}: Pareto set "
              f"{[p.name for p in pruned.layer_candidates(l)]} "
              f"| e_o(KV4)={errors.e_o[l, names.index('KV4')]:.4f}")
    print(f"clustered groups: {groups.groups}")

    print("== NSGA-II search ==")
    report = tuner.search(ctx.calib_batches(),
                          eval_batches=ctx.eval_batches(n=1, batch=32),
                          generations=args.generations, pop_size=16)
    os.makedirs(OUT, exist_ok=True)
    for sched in report.frontier:
        path = os.path.join(OUT, f"{ctx.api.cfg.name}_{args.mode}_"
                                 f"C{sched.equivalent_bits:.2f}.json")
        sched.save(path)
        print(f"  {sched.name}: bits={sched.equivalent_bits:.2f} "
              f"loss={sched.objectives['loss']:.4f} -> {os.path.normpath(path)}")
    print(f"MOO evaluations: {report.moo.evaluations} "
          f"(search space after pruning+clustering: "
          f"{report.groups.search_space_size():.0f})")


if __name__ == "__main__":
    main()
