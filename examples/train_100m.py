"""End-to-end training driver: a ~100M-param llama-family model trained for a
few hundred steps with the full production substrate — fault-tolerant trainer,
async sharded checkpointing + resume, int8 error-feedback gradient
compression, stateless-resumable data pipeline.

By default runs a scaled config sized for this CPU container; pass --full for
the true ~100M model (slower). Kill and re-run: it resumes from the last
committed checkpoint.

Run: PYTHONPATH=src python examples/train_100m.py [--steps 300] [--full]
"""
import argparse
import os


from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticSource
from repro.data.synthetic import TaskConfig
from repro.models.registry import build_model
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.trainer import Trainer

CKPT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "artifacts", "train_100m")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="true ~100M params (CPU: slow)")
    ap.add_argument("--compress-grads", action="store_true", default=True)
    args = ap.parse_args()

    if args.full:  # ~100M params
        cfg = ModelConfig(name="lm-100m", family="dense", num_layers=12,
                          d_model=768, num_heads=12, num_kv_heads=4,
                          d_ff=2048, vocab_size=32000, q_chunk=128)
        batch, seq = 8, 256
    else:          # same family, CPU-friendly (~8M params)
        cfg = ModelConfig(name="lm-8m", family="dense", num_layers=6,
                          d_model=256, num_heads=8, num_kv_heads=2,
                          d_ff=512, vocab_size=2048, q_chunk=64)
        batch, seq = 16, 128

    api = build_model(cfg)
    n = cfg.param_count()
    print(f"model {cfg.name}: {n/1e6:.1f}M params")

    task = TaskConfig(vocab_size=cfg.vocab_size, chain_len=10, seq_len=seq)
    trainer = Trainer(
        api=api,
        optimizer=AdamW(lr=cosine_schedule(6e-4, 50, args.steps),
                        weight_decay=0.01, grad_clip=1.0),
        source=SyntheticSource(task=task, batch_size=batch, kind="mixed"),
        ckpt=CheckpointManager(CKPT_DIR, keep=2),
        ckpt_every=100,
        compress_grads=args.compress_grads,
        log_every=25,
    )
    state, history = trainer.run(args.steps)
    print(f"final loss: {history[-1]['loss']:.4f} "
          f"({history[-1]['steps_per_s']:.2f} steps/s)")
    print(f"checkpoints in {os.path.normpath(CKPT_DIR)} — "
          f"re-run to resume, delete to restart")


if __name__ == "__main__":
    main()
