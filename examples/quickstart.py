"""Quickstart: the full KVTuner loop in one minute on CPU.

1. build a small llama-family model and train it briefly on the calibration
   task; 2. analyze layer sensitivity; 3. search mixed-precision schedules;
4. serve with the best schedule and compare against uniform quantization.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.precision import MODE_PER_TOKEN, KVTunerSchedule, PrecisionPair
from repro.core.tuner import KVTuner
from repro.data import synthetic
from repro.data.pipeline import SyntheticSource
from repro.models.registry import build_model
from repro.serving.engine import generate
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.trainer import Trainer


def main():
    cfg = ModelConfig(name="quickstart", family="dense", num_layers=4,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                      vocab_size=64, q_chunk=64)
    api = build_model(cfg)
    task = synthetic.TaskConfig(vocab_size=64, chain_len=6, seq_len=48)

    print("== 1. train a small model on reasoning chains ==")
    trainer = Trainer(api=api, optimizer=AdamW(lr=cosine_schedule(1e-3, 30, 300)),
                      source=SyntheticSource(task=task, batch_size=32,
                                             kind="mixed", seed=0),
                      log_every=100)
    state, _ = trainer.run(300)
    params = state.params

    print("== 2-3. KVTuner offline pipeline (capture→prune→cluster→search) ==")
    rng = np.random.default_rng(42)
    calib = [{k: jnp.asarray(v) for k, v in
              synthetic.mixed_batch(task, 8, rng).items()} for _ in range(2)]
    tuner = KVTuner(api, params, mode=MODE_PER_TOKEN)
    report = tuner.search(calib, generations=4, pop_size=10, seed=0)
    full, pruned, grouped = report.space_reduction()
    print(f"search space: {full:.1e} -> {pruned:.1e} (pruning) "
          f"-> {grouped:.1e} (clustering)")
    print("Pareto frontier:")
    for sched in report.frontier:
        print(f"  {sched.name}: bits={sched.equivalent_bits:.2f} "
              f"loss={sched.objectives['loss']:.4f}")

    print("== 4. serve with the searched schedule vs uniform KV4 ==")
    best = report.best_under_bits(5.0) or report.frontier[-1]
    prompts = np.stack([synthetic.chain_batch(task, 1, rng)["tokens"][0][:24]
                        for _ in range(4)])
    for name, sched in [("BF16", None),
                        ("uniform KV4",
                         KVTunerSchedule.uniform(4, PrecisionPair(4, 4))),
                        (best.name, best)]:
        out, stats = generate(api, params, sched, prompts, max_new_tokens=8)
        print(f"  {name:16s} -> {stats.throughput:7.1f} tok/s (CPU), "
              f"first outputs {out[0][:6].tolist()}")


if __name__ == "__main__":
    main()
