"""Serving example: batched requests against a KVTuner mixed-precision KV
cache, comparing accuracy + throughput across schedules — the deployment path
(packed cache, static per-layer precision, zero online decision overhead).

Uses the shared trained benchmark model (trains it on first run).

Run: PYTHONPATH=src python examples/serve_mixed_precision.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import get_bench_model
from repro.core.precision import KVTunerSchedule, PrecisionPair
from repro.data import synthetic
from repro.launch.steps import default_schedule
from repro.serving.engine import ContinuousEngine, Request, ServeEngine


def main():
    ctx = get_bench_model(log=print)
    cfg = ctx.api.cfg
    n_attn = len(cfg.attention_layers())
    rng = np.random.default_rng(0)

    # build prompts that END right before a result token, so the first
    # generated token is checkable (the running value of the chain)
    batch = synthetic.chain_batch(ctx.task, 16, rng)
    toks, mask = batch["tokens"], batch["loss_mask"]
    prompts, answers = [], []
    for i in range(toks.shape[0]):
        pos = np.where(mask[i] > 0)[0]
        if len(pos) == 0:
            continue
        # cut at the deepest result token (falling back from the long-prompt
        # threshold when the task's chains are shorter than 40 tokens)
        late = pos[pos >= 40]
        cut = int(late[0]) if len(late) else int(pos[-1])
        prompts.append(toks[i][:cut])
        answers.append(int(toks[i][cut]))
    ragged = [np.asarray(p) for p in prompts]  # natural mixed lengths
    plen = min(len(p) for p in prompts)
    prompts = np.stack([p[-plen:] for p in prompts])

    schedules = {
        "BF16 (no quant)": None,
        "uniform KV8": KVTunerSchedule.uniform(n_attn, PrecisionPair(8, 8)),
        "uniform KV2": KVTunerSchedule.uniform(n_attn, PrecisionPair(2, 2)),
        "KVTuner mixed (~3.1-bit)": default_schedule(cfg, "kvtuner"),
    }
    print(f"\n{len(prompts)} requests, prompt len {plen}, "
          f"first generated token is the chain answer\n")
    for name, sched in schedules.items():
        eng = ServeEngine(ctx.api, ctx.params, sched,
                          max_batch=len(prompts))
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
        done = sorted(eng.run(), key=lambda r: r.uid)
        correct = sum(r.output[0] == a for r, a in zip(done, answers))
        bits = sched.equivalent_bits if sched else 16.0
        print(f"{name:26s} bits={bits:5.2f} "
              f"answer-acc={correct}/{len(done)} "
              f"throughput={eng.stats.throughput:7.1f} tok/s (CPU)")

    # continuous batching: the same requests as a ragged mixed-length stream
    # (no truncation to a common prompt length, one decode compilation).
    # prefix_cache implies chunked in-pool prefill — the same admission path
    # the overload demo below uses, so their outputs are comparable
    sched = default_schedule(cfg, "kvtuner")
    eng = ContinuousEngine(ctx.api, ctx.params, sched, max_batch=4,
                           max_seq=max(len(p) for p in ragged) + 4,
                           prefix_cache=True)
    for i, p in enumerate(ragged):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=4,
                           arrival_step=i))
    done = sorted(eng.run(), key=lambda r: r.uid)
    correct = sum(r.output[0] == a for r, a in zip(done, answers))
    print(f"\ncontinuous (paged pool)    bits={sched.equivalent_bits:5.2f} "
          f"answer-acc={correct}/{len(done)} "
          f"throughput={eng.stats.throughput:7.1f} tok/s (CPU) "
          f"decode-compiles={eng.decode_compilations}")

    # overload: the same stream through a pool deliberately too small for
    # the peak live context, with a host-RAM tier and the preemptive
    # priority scheduler — evicted prefixes spill to host instead of being
    # dropped, later arrivals preempt lower-priority victims (parked
    # bitwise, resumed token-identically), and every request still finishes
    r = cfg.kv_group_size
    max_seq = max(len(p) for p in ragged) + 4
    pages_per_req = max_seq // r + 1
    eng2 = ContinuousEngine(ctx.api, ctx.params, sched, max_batch=2,
                            max_seq=max_seq, prefix_cache=True,
                            num_blocks=1 + 2 * pages_per_req,  # ~2 live reqs
                            host_blocks=8 * pages_per_req,
                            scheduler="priority")
    for i, p in enumerate(ragged):
        eng2.submit(Request(uid=i, prompt=p, max_new_tokens=4,
                            arrival_step=i, priority=i))
    done2 = sorted(eng2.run(), key=lambda r_: r_.uid)
    s = eng2.stats
    assert [r_.output for r_ in done2] == [r_.output for r_ in done], \
        "tiered serving must be token-identical to the unconstrained pool"
    print(f"overloaded + host tier     outputs identical: True  "
          f"preemptions={s.preemptions} swap_out={s.swap_out_blocks} "
          f"swap_in={s.swap_in_blocks} host-prefix-hits={s.host_prefix_hits} "
          f"pool-peak={s.pool_high_watermark:.0%}")


if __name__ == "__main__":
    main()
