"""PartitionSpec assignment for params / optimizer state / decode state /
batches, by pytree path + shape (divisibility-safe via ShardingRules).

Policy (DESIGN.md §4):
* weights: Megatron TP on the model axis (col-parallel in-proj, row-parallel
  out-proj, vocab-parallel embeddings/head; experts on model when E divides);
* any tensor still larger than ``fsdp_threshold`` bytes per chip gains a
  second sharding axis over data (FSDP-style 2-D weight sharding) — this is
  what fits arctic-480b / grok-1-314b / deepseek-67b on 16 GB v5e chips;
* optimizer moments always take the extra data axis (ZeRO-1);
* activations between blocks shard batch over (pod, data) and sequence over
  model (Megatron sequence parallelism) — see DEFAULT_RULES;
* KV cache shards over batch × sequence (KV heads ≤ 16 for every assigned
  arch, so head-sharding is off the table — verified: JAX rejects uneven).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import ShardingRules

# name → per-dim logical axes (by rank). "_" = replicated dim.
_IN_PROJ = ("fsdp?", "model")      # [D, X] col-parallel
_OUT_PROJ = ("model", "fsdp?")     # [X, D] row-parallel
_PARAM_TABLE: dict[str, tuple[str, ...]] = {
    "embed": ("fsdp?", "model"),   # [V, D]: D-sharded gather-free lookup
    "lm_head": ("fsdp?", "model"),  # [D, V] vocab-parallel logits
    "wq": _IN_PROJ, "wk": _IN_PROJ, "wv": _IN_PROJ, "wo": _OUT_PROJ,
    "w_gate": _IN_PROJ, "w_up": _IN_PROJ, "w_down": _OUT_PROJ,
    "in_proj": _IN_PROJ, "out_proj": _OUT_PROJ, "x_proj": _OUT_PROJ,
    "dt_proj": ("_", "model"), "A_log": ("model", "_"), "D": ("model",),
    "conv_w": ("_", "model"), "conv_b": ("model",),
    "dt_bias": ("model",), "w_if": ("_", "_"), "if_bias": ("_",),
    "w_in": _IN_PROJ, "w_out": _OUT_PROJ, "r": ("_", "_", "_"),
    "router": ("_", "_"),
    "proj": ("_", "_"), "mask_emb": ("_",),
    "w1": ("_", "_"), "w2": ("_", "_"),  # vlm projector (small)
}
# stacked expert weights [E, D, F] / [E, F, D]
_MOE_IN = ("experts", "_", "expert_ff")
_MOE_OUT = ("experts", "expert_ff", "_")


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):       # GetAttrKey: dataclass / namedtuple fields
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return out


def _param_logical(path_names: list[str], shape: tuple[int, ...]) -> list[str]:
    name = path_names[-1] if path_names else ""
    in_moe = "moe" in path_names
    if in_moe and name in ("w_gate", "w_up"):
        base = list(_MOE_IN)
    elif in_moe and name == "w_down":
        base = list(_MOE_OUT)
    elif name in _PARAM_TABLE:
        base = list(_PARAM_TABLE[name])
    else:
        base = ["_"] * len(shape)
    # stacked-layer leading dim (scan plans add [L, ...])
    while len(base) < len(shape):
        base = ["_"] + base
    base = base[-len(shape):] if len(base) > len(shape) else base
    return base


@dataclasses.dataclass
class SpecBuilder:
    rules: ShardingRules
    fsdp_threshold: int = 128 * 1024 * 1024  # bytes per chip after TP

    def _resolve(self, logical: list[str], shape, *, force_fsdp: bool,
                 itemsize: int = 2) -> P:
        parts: list = []
        used: set = set()
        for dim, (name, size) in enumerate(zip(logical, shape)):
            ax = None
            if name not in ("_", "fsdp?"):
                ax = self.rules.axes(name, size)
                # a mesh axis shards at most one dim — earlier dims win
                if isinstance(ax, tuple):
                    ax = tuple(a for a in ax if a not in used) or None
                    if isinstance(ax, tuple):
                        if len(ax) == 1:
                            ax = ax[0]
                        if ax is not None:
                            total = 1
                            for a in (ax if isinstance(ax, tuple) else (ax,)):
                                total *= self.rules.mesh.shape[a]
                            if size % total:
                                ax = None
                elif ax in used:
                    ax = None
                if isinstance(ax, tuple):
                    used.update(ax)
                elif ax:
                    used.add(ax)
            parts.append(ax)
        # second axis: FSDP over data for big tensors / optimizer moments
        per_chip = float(np.prod(shape) * itemsize)
        for ax in used:
            per_chip /= self.rules.mesh.shape[ax]
        want_fsdp = force_fsdp or per_chip > self.fsdp_threshold
        if want_fsdp:
            fsdp_axes = [a for a in self.rules.table.get("fsdp", ())
                         if a in self.rules.mesh.axis_names and a not in used]
            # never FSDP the leading stacked-layers dim of scanned weights —
            # each scan step would gather its slice across the data axis
            start = 1 if len(shape) >= 3 else 0
            for dim, name in list(enumerate(logical))[start:]:
                if parts[dim] is None and fsdp_axes:
                    total = int(np.prod([self.rules.mesh.shape[a]
                                         for a in fsdp_axes]))
                    if shape[dim] % total == 0 and shape[dim] >= total:
                        parts[dim] = tuple(fsdp_axes) if len(fsdp_axes) > 1 \
                            else fsdp_axes[0]
                        break
        return P(*parts)

    # ------------------------------------------------------------ params
    def params(self, abstract_params, force_fsdp: bool = False):
        def assign(path, leaf):
            names = _path_names(path)
            itemsize = jax.dtypes.canonicalize_dtype(leaf.dtype).itemsize
            logical = _param_logical(names, leaf.shape)
            return self._resolve(logical, leaf.shape, force_fsdp=force_fsdp,
                                 itemsize=itemsize)
        return jax.tree_util.tree_map_with_path(assign, abstract_params)

    def train_state(self, abstract_state):
        """TrainState: params as usual; mu/nu/ef always FSDP (ZeRO-1)."""
        def assign(path, leaf):
            names = _path_names(path)
            itemsize = jax.dtypes.canonicalize_dtype(leaf.dtype).itemsize
            logical = _param_logical([n for n in names
                                      if n not in ("mu", "nu", "residual",
                                                   "params", "opt", "ef")],
                                     leaf.shape)
            force = any(n in ("mu", "nu", "residual") for n in names)
            if not leaf.shape:
                return P()
            return self._resolve(logical, leaf.shape, force_fsdp=force,
                                 itemsize=itemsize)
        return jax.tree_util.tree_map_with_path(assign, abstract_state)

    # ------------------------------------------------------------- decode
    def decode_state(self, abstract_state, long_context: bool = False):
        seq_rule = "kv_seq_long" if long_context else "kv_seq"

        def assign(path, leaf):
            names = _path_names(path)
            name = names[-1] if names else ""
            shape = leaf.shape
            r = self.rules
            if name in ("k_codes", "v_codes") and len(shape) == 4:
                return r.spec("batch", "none", seq_rule, "none", shape=shape)
            if name in ("k_scale", "k_zero", "v_scale", "v_zero"):
                if len(shape) == 5:  # grouped scales: dim2 follows seq groups
                    return r.spec("batch", "none", seq_rule, "none", "none",
                                  shape=shape)
                return P()
            if name in ("k_res", "v_res"):
                return r.spec("batch", "none", "none", "none", shape=shape)
            if name == "ssm":       # mamba [B, di, N]
                return r.spec("batch", "mamba_inner", "none", shape=shape)
            if name == "conv":      # [B, K-1, di]
                return r.spec("batch", "none", "mamba_inner", shape=shape)
            if name == "c" and len(shape) == 4:  # mLSTM [B,H,dk,dv]
                return r.spec("batch", "none", "none", "mamba_inner", shape=shape)
            if name in ("n",) and len(shape) == 3:
                return r.spec("batch", "none", "none", shape=shape)
            if len(shape) >= 1 and shape and shape[0] > 1:
                return r.spec("batch", *(["none"] * (len(shape) - 1)),
                              shape=shape)
            return P()

        return jax.tree_util.tree_map_with_path(assign, abstract_state)

    # -------------------------------------------------------------- batch
    def batch(self, abstract_batch):
        def assign(path, leaf):
            return self.rules.spec("batch", *(["none"] * (len(leaf.shape) - 1)),
                                   shape=leaf.shape)
        return jax.tree_util.tree_map_with_path(assign, abstract_batch)

    # ------------------------------------------------------------ helpers
    def named(self, spec_tree):
        mesh = self.rules.mesh
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))


def abstract_like(fn, *args, **kw):
    """jax.eval_shape convenience returning ShapeDtypeStruct pytrees."""
    return jax.eval_shape(fn, *args, **kw)
