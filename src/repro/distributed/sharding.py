"""Logical-axis sharding rules → PartitionSpecs (MaxText-style), plus an
ambient ``shard_hint`` used inside model code.

Model code annotates tensors with *logical* axis names ("batch", "kv_seq",
"experts", ...). A ``ShardingRules`` table maps logical names to mesh axes.
Rules are arch-aware: dims that don't divide the mesh axis fall back to
replication (JAX rejects uneven shards — verified empirically), which is how
e.g. arctic's 56 heads or grok's 8 experts are handled on a 16-wide model axis.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default logical → mesh-axis table. ``batch`` composes pod+data on the
# multi-pod mesh; `mesh_axes` resolves names missing from the mesh.
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "flat_tokens": ("pod", "data"),
    "model": ("model",),        # raw TP axis (weight in/out-proj dims)
    "fsdp": ("data",),          # 2nd weight-sharding axis for big matrices
    "heads": ("model",),
    "kv_heads": ("model",),
    "d_model": (),              # replicated by default (residual stream)
    "d_ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_ff": ("model",),
    "expert_cap": ("data",),
    "kv_seq": ("model",),       # KV cache sequence sharding (decode)
    "kv_seq_long": ("data", "model"),  # long_500k batch=1
    "seq": (),                  # activations seq usually unsharded
    "mamba_inner": ("model",),
    "layers": (),
    "none": (),
}


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    table: dict[str, tuple[str, ...]]

    def axes(self, logical: str, dim_size: int | None = None):
        # names absent from the mesh drop out (a table written for the
        # multi-pod mesh still works on a (data, model) mesh); a repeated
        # mesh axis within one entry collapses to its first occurrence
        # (P(("model","model")) would double-count the axis size)
        names = list(dict.fromkeys(
            a for a in self.table.get(logical, ()) if a in self.mesh.axis_names))
        if not names:
            return None
        if dim_size is not None and dim_size <= 0:
            return None  # degenerate dim: nothing to shard
        # greedy longest prefix that divides the dim (JAX rejects uneven shards)
        if dim_size is not None:
            kept = []
            total = 1
            for a in names:
                if dim_size % (total * self.mesh.shape[a]) == 0:
                    kept.append(a)
                    total *= self.mesh.shape[a]
                else:
                    break
            names = kept
        if not names:
            return None
        return tuple(names) if len(names) > 1 else names[0]

    def spec(self, *logical, shape: Sequence[int] | None = None) -> P:
        parts = []
        used: set = set()
        for i, name in enumerate(logical):
            dim = None if shape is None else shape[i]
            ax = self.axes(name, dim) if name else None
            # a mesh axis may shard at most one dim — earlier dims win
            if isinstance(ax, tuple):
                ax = tuple(a for a in ax if a not in used) or None
                if isinstance(ax, tuple) and len(ax) == 1:
                    ax = ax[0]
            elif ax in used:
                ax = None
            # re-check divisibility after dedup pruning BEFORE marking axes
            # used: a dim that falls back to replication here must not
            # block a later dim from taking those mesh axes
            if ax is not None and dim is not None:
                total = 1
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    total *= self.mesh.shape[a]
                if dim % total:
                    ax = None
            if isinstance(ax, tuple):
                used.update(ax)
            elif ax:
                used.add(ax)
            parts.append(ax)
        return P(*parts)

    def sharding(self, *logical, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical, shape=shape))


_ACTIVE: contextvars.ContextVar[ShardingRules | None] = \
    contextvars.ContextVar("sharding_rules", default=None)


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    tok = _ACTIVE.set(rules)
    try:
        yield rules
    finally:
        _ACTIVE.reset(tok)


def active_rules() -> ShardingRules | None:
    return _ACTIVE.get()


def shard_hint(x: jax.Array, *logical: str) -> jax.Array:
    """with_sharding_constraint if rules are active; no-op otherwise (tests,
    single-device smoke runs). Logical names that miss divisibility replicate."""
    rules = _ACTIVE.get()
    if rules is None or len(logical) != x.ndim:
        return x
    spec = rules.spec(*logical, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def make_rules(mesh: Mesh, overrides: Mapping[str, tuple[str, ...]] | None = None,
               ) -> ShardingRules:
    table = dict(DEFAULT_RULES)
    if overrides:
        table.update(overrides)
    return ShardingRules(mesh=mesh, table=table)
