"""DeepSeek-67B — llama-arch dense [arXiv:2401.02954; hf].
95L, d_model=8192, 64H (GQA kv=8, head_dim 128), d_ff=22016, vocab=102400."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b", family="dense", num_layers=95, d_model=8192,
        num_heads=64, num_kv_heads=8, d_ff=22016, vocab_size=102400,
        rope_theta=1e4)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", family="dense", num_layers=4, d_model=64,
        num_heads=8, num_kv_heads=2, d_ff=160, vocab_size=128, q_chunk=16)
