"""Llama-3.1-8B-Instruct — the paper's primary experimental model
[arXiv:2407.21783]. 32L, d_model=4096, 32H (GQA kv=8, head_dim 128),
d_ff=14336, vocab=128256. Included so the paper's own Tables 2-11 have a
full-size dry-run target; not part of the assigned 40-cell grid."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paper-llama3.1-8b", family="dense", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=128256,
        rope_theta=5e5)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama31-smoke", family="dense", num_layers=4, d_model=64,
        num_heads=8, num_kv_heads=2, d_ff=160, vocab_size=128, q_chunk=16)
