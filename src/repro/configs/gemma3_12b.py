"""Gemma3-12B — 5:1 local:global, 128k [hf:google/gemma-3-1b-pt family;
unverified]. 48L, d_model=3840, 16H (GQA kv=8, head_dim 256), d_ff=15360,
vocab=262144."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", family="dense", num_layers=48, d_model=3840,
        num_heads=16, num_kv_heads=8, head_dim=256, d_ff=15360,
        vocab_size=262144, local_global_ratio=5, local_window=1024,
        rope_theta=1e4, rope_theta_global=1e6, use_qk_norm=True,
        act="gelu", tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b-smoke", family="dense", num_layers=6, d_model=48,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=96, vocab_size=256,
        local_global_ratio=5, local_window=16, use_qk_norm=True, act="gelu",
        tie_embeddings=True, q_chunk=16)
