"""xLSTM-125M — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].
12L, d_model=768, 4H (head_dim 192), vocab=50304. d_ff=0: xlstm blocks carry
their own up/down projections. sLSTM at layers {5, 11} (1:5 ratio choice —
the paper's xLSTM[7:1] ratio rounded to this depth; documented deviation).

KVTuner is INAPPLICABLE (attention-free — no KV cache); the arch is
implemented without the technique per the assignment (DESIGN.md §5)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm", num_layers=12, d_model=768,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=50304,
        slstm_at=(5, 11), mlstm_proj_factor=2.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="ssm", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=128, slstm_at=(1,),
        q_chunk=16)
