"""Snowflake Arctic-480B — dense-MoE hybrid: 128 experts top-2 IN PARALLEL
with a dense residual MLP [hf:Snowflake/snowflake-arctic-base; hf].
35L, d_model=7168, 56H (GQA kv=8, head_dim 128), dense d_ff=4864 +
MoE d_ff=4864 per expert, vocab=32000.

Sharding note (DESIGN.md §4): 56 heads do not divide the 16-wide model axis —
attention activations replicate over heads; TP is carried by the 128/16
expert sharding + FSDP on expert ff dims."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe", num_layers=35, d_model=7168,
        num_heads=56, num_kv_heads=8, head_dim=128, d_ff=4864,
        vocab_size=32000, num_experts=128, experts_per_token=2,
        moe_d_ff=4864, moe_dense_residual=True, rope_theta=1e4)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="arctic-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=128, num_experts=8,
        experts_per_token=2, moe_d_ff=64, moe_dense_residual=True, q_chunk=16)
