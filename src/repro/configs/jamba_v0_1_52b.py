"""Jamba-v0.1 52B — Mamba + attention 1:7 interleave, MoE every 2 layers,
16 experts top-2 [arXiv:2403.19887; hf].
32L (4 periods x 8), d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=65536.
Attention at position 4 of each 8-layer period; MoE replaces the MLP on odd
layers. Only the 4 attention layers carry KV cache → the KVTuner search space
degenerates gracefully (DESIGN.md §5)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=65536,
        attn_period=8, attn_offset=4, num_experts=16, experts_per_token=2,
        moe_every=2, moe_d_ff=14336, mamba_d_state=16, mamba_d_conv=4,
        mamba_expand=2, rope_theta=1e4)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid", num_layers=8, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128, attn_period=4,
        attn_offset=2, num_experts=4, experts_per_token=2, moe_every=2,
        moe_d_ff=128, q_chunk=16)
