"""Gemma3-27B — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt family; unverified].
62L, d_model=5376, 32H (GQA kv=16, head_dim 128), d_ff=21504, vocab=262144.
Local layers: sliding window 1024, theta 10k; global layers: theta 1M."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense", num_layers=62, d_model=5376,
        num_heads=32, num_kv_heads=16, head_dim=128, d_ff=21504,
        vocab_size=262144, local_global_ratio=5, local_window=1024,
        rope_theta=1e4, rope_theta_global=1e6, use_qk_norm=True,
        act="gelu", tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense", num_layers=6, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        local_global_ratio=5, local_window=16, rope_theta=1e4,
        rope_theta_global=1e6, use_qk_norm=True, act="gelu",
        tie_embeddings=True, q_chunk=16)
