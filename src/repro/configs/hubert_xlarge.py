"""HuBERT-XLarge — encoder-only audio transformer [arXiv:2106.07447;
unverified]. 48L, d_model=1280, 16H (kv=16, head_dim 80), d_ff=5120,
vocab=504 (k-means target codebook).

Modality frontend is a STUB: input_specs provides precomputed frame
embeddings [B, T, 512] (the conv feature extractor output dim); the in-model
part is the projection + masked-prediction head. Encoder-only → no decode
shapes, no autoregressive KV cache (KVTuner inapplicable; DESIGN.md §5)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio", num_layers=48, d_model=1280,
        num_heads=16, num_kv_heads=16, d_ff=5120, vocab_size=504,
        is_encoder=True, frontend_dim=512, act="gelu", mask_prob=0.08)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke", family="audio", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=32,
        is_encoder=True, frontend_dim=24, act="gelu", q_chunk=16)
