"""Grok-1 314B — 8 experts top-2 MoE [hf:xai-org/grok-1; unverified].
64L, d_model=6144, 48H (GQA kv=8, head_dim 128), expert d_ff=32768,
vocab=131072.

Sharding note: 8 experts < 16-wide model axis → EP is infeasible on this
mesh; TP shards each expert's d_ff=32768 instead (DESIGN.md §4)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe", num_layers=64, d_model=6144,
        num_heads=48, num_kv_heads=8, head_dim=128, d_ff=32768,
        vocab_size=131072, num_experts=8, experts_per_token=2,
        moe_d_ff=32768, rope_theta=1e4)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128, num_experts=4,
        experts_per_token=2, moe_d_ff=128, q_chunk=16)
