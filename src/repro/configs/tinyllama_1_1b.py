"""TinyLlama-1.1B — llama2-arch small [arXiv:2401.02385; hf].
22L, d_model=2048, 32H (GQA kv=4, head_dim 64), d_ff=5632, vocab=32000."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b", family="dense", num_layers=22, d_model=2048,
        num_heads=32, num_kv_heads=4, d_ff=5632, vocab_size=32000,
        rope_theta=1e4)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-smoke", family="dense", num_layers=3, d_model=64,
        num_heads=8, num_kv_heads=2, d_ff=160, vocab_size=128, q_chunk=16)
