"""Assigned architecture registry: ``--arch <id>`` resolves here.

Each module defines ``config()`` returning the exact published configuration
(sources cited per-file) plus ``smoke_config()`` — a reduced same-family
variant for CPU tests.
"""
from __future__ import annotations

from repro.configs import (arctic_480b, deepseek_67b, gemma3_12b, gemma3_27b,
                           grok_1_314b, hubert_xlarge, jamba_v0_1_52b,
                           llava_next_mistral_7b, paper_llama31_8b,
                           tinyllama_1_1b, xlstm_125m)

ARCH_CONFIGS = {
    "llava-next-mistral-7b": llava_next_mistral_7b.config,
    "tinyllama-1.1b": tinyllama_1_1b.config,
    "gemma3-27b": gemma3_27b.config,
    "deepseek-67b": deepseek_67b.config,
    "gemma3-12b": gemma3_12b.config,
    "xlstm-125m": xlstm_125m.config,
    "arctic-480b": arctic_480b.config,
    "grok-1-314b": grok_1_314b.config,
    "jamba-v0.1-52b": jamba_v0_1_52b.config,
    "hubert-xlarge": hubert_xlarge.config,
    # the paper's own primary model (for completeness; not in the 40-cell grid)
    "paper-llama3.1-8b": paper_llama31_8b.config,
}

SMOKE_CONFIGS = {name: mod.smoke_config for name, mod in [
    ("llava-next-mistral-7b", llava_next_mistral_7b),
    ("tinyllama-1.1b", tinyllama_1_1b),
    ("gemma3-27b", gemma3_27b),
    ("deepseek-67b", deepseek_67b),
    ("gemma3-12b", gemma3_12b),
    ("xlstm-125m", xlstm_125m),
    ("arctic-480b", arctic_480b),
    ("grok-1-314b", grok_1_314b),
    ("jamba-v0.1-52b", jamba_v0_1_52b),
    ("hubert-xlarge", hubert_xlarge),
    ("paper-llama3.1-8b", paper_llama31_8b),
]}
