"""LLaVA-NeXT (Mistral-7B backbone) — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].
Backbone: 32L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=32000.
Modality frontend is a STUB: input_specs provides precomputed patch
embeddings (anyres: 5 tiles x 576 patches = 2880 image tokens, CLIP dim 1024);
the in-model part is the 2-layer MLP projector (the trainable mm adapter)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b", family="vlm", num_layers=32,
        d_model=4096, num_heads=32, num_kv_heads=8, d_ff=14336,
        vocab_size=32000, vision_dim=1024, image_tokens=2880, rope_theta=1e6)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-smoke", family="vlm", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128, vision_dim=32,
        image_tokens=8, q_chunk=16)
