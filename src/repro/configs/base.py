"""Model/run configuration schema shared by all 10 assigned architectures."""
from __future__ import annotations

import dataclasses

# Layer kinds used by heterogeneous stacks (gemma3, jamba, xlstm).
ATTN_LOCAL = "attn_local"
ATTN_GLOBAL = "attn_global"
MAMBA = "mamba"
MLSTM = "mlstm"
SLSTM = "slstm"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0            # expert hidden size (0 → d_ff)
    moe_dense_residual: bool = False  # arctic: parallel dense MLP + MoE
    moe_every: int = 1           # MoE replaces MLP every N layers (jamba: 2)
    capacity_factor: float = 1.25

    # --- gemma3 local:global attention ---
    local_global_ratio: int = 0  # N local layers per 1 global (0 → all global)
    local_window: int = 1024

    # --- jamba hybrid ---
    attn_period: int = 0         # 1 attention layer per N layers (jamba: 8)
    attn_offset: int = 4         # position of the attn layer within the period
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0       # 0 → ceil(d_model / 16)

    # --- xlstm ---
    slstm_at: tuple[int, ...] = ()
    mlstm_proj_factor: float = 2.0

    # --- encoder-only (hubert) ---
    is_encoder: bool = False
    frontend_dim: int = 0        # stub modality frontend embedding dim
    mask_prob: float = 0.08      # masked-prediction training

    # --- vlm (llava) ---
    vision_dim: int = 0          # stub patch-embedding dim
    image_tokens: int = 0        # anyres tiles × patches per tile

    # --- common transformer knobs ---
    rope_theta: float = 1e4
    rope_theta_global: float = 0.0  # gemma3 uses a different theta for globals
    norm_eps: float = 1e-6
    use_qk_norm: bool = False
    tie_embeddings: bool = False
    act: str = "silu"            # silu (swiglu) | gelu (geglu)
    dtype: str = "bfloat16"

    # --- KV quantization defaults (KVTuner schedule overrides per layer) ---
    kv_group_size: int = 32
    kv_residual_len: int = 32

    # --- training ---
    scan_layers: bool = True     # lax.scan over stacked layer params
    remat: bool = True
    q_chunk: int = 512           # query-chunked attention (flash-style in XLA)

    # --- perf-iteration knobs (§Perf; defaults = paper-faithful baseline) ---
    attn_probs_bf16: bool = False  # cast softmax probs to bf16 before P·V
    attn_boundary_hints: bool = False  # explicit SP↔TP reshard points
    sp_decode: bool = False        # shard_map seq-parallel flash decode
    moe_ep: bool = False           # shard_map expert-parallel MoE combine

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError(f"{self.name}: heads {self.num_heads} not divisible "
                             f"by kv heads {self.num_kv_heads}")
        if self.moe_d_ff == 0 and self.num_experts:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.mamba_dt_rank == 0:
            object.__setattr__(self, "mamba_dt_rank", -(-self.d_model // 16))

    # ----------------------------------------------------------- structure
    def layer_kinds(self) -> list[str]:
        """Per-layer kind for heterogeneous stacks; attention-bearing layers
        are the KVTuner search space."""
        kinds = []
        for i in range(self.num_layers):
            if self.family == "ssm":
                kinds.append(SLSTM if i in self.slstm_at else MLSTM)
            elif self.family == "hybrid" and self.attn_period:
                kinds.append(ATTN_GLOBAL if i % self.attn_period == self.attn_offset
                             else MAMBA)
            elif self.local_global_ratio:
                r = self.local_global_ratio + 1
                kinds.append(ATTN_GLOBAL if i % r == r - 1 else ATTN_LOCAL)
            else:
                kinds.append(ATTN_GLOBAL)
        return kinds

    def attention_layers(self) -> list[int]:
        return [i for i, k in enumerate(self.layer_kinds())
                if k in (ATTN_LOCAL, ATTN_GLOBAL)]

    def moe_layers(self) -> list[int]:
        if not self.num_experts:
            return []
        return [i for i in range(self.num_layers) if i % self.moe_every == self.moe_every - 1] \
            if self.moe_every > 1 else list(range(self.num_layers))

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def is_homogeneous(self) -> bool:
        kinds = set(self.layer_kinds())
        moe_mixed = bool(self.num_experts) and self.moe_every > 1
        return len(kinds) == 1 and not moe_mixed

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for roofline N."""
        d, f, hd = self.d_model, self.d_ff, self.head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        for kind in self.layer_kinds():
            if kind in (ATTN_GLOBAL, ATTN_LOCAL):
                total += d * hd * (nq + 2 * nkv) + nq * hd * d  # qkvo
            elif kind == MAMBA:
                di = self.mamba_expand * d
                total += d * 2 * di + di * self.mamba_d_conv + \
                    di * (self.mamba_dt_rank + 2 * self.mamba_d_state) + \
                    self.mamba_dt_rank * di + di * d
            elif kind == MLSTM:
                di = int(self.mlstm_proj_factor * d)
                total += 2 * d * di + 3 * di * di // max(self.num_heads, 1) + di * d
            elif kind == SLSTM:
                total += 4 * d * d + 4 * d * (d // max(self.num_heads, 1))
            total += 2 * d  # norms
        # MLP / MoE
        mlp = 3 * d * f if self.act == "silu" else 2 * d * f
        for i in range(self.num_layers):
            if self.family in ("ssm",):
                total += 2 * d * int(2.6 * d) if i in self.slstm_at else 0
                continue
            if self.num_experts and i in self.moe_layers():
                total += self.num_experts * 3 * d * self.moe_d_ff + d * self.num_experts
                if self.moe_dense_residual:
                    total += mlp
            elif self.family == "hybrid" and self.layer_kinds()[i] == MAMBA:
                total += mlp
            elif self.family != "ssm":
                total += mlp
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only) → MODEL_FLOPS=6·N_active·D."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        inactive = 0
        n_moe = len(self.moe_layers())
        inactive = n_moe * (self.num_experts - self.experts_per_token) * 3 * d * self.moe_d_ff
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) column of the assigned grid."""

    name: str          # train_4k | prefill_32k | decode_32k | long_500k
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeCell("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeCell("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeCell("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeCell("long_500k", "decode", 524288, 1)
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def supported_shapes(cfg: ModelConfig) -> list[ShapeCell]:
    """Shape-cell applicability rules (see DESIGN.md §5)."""
    shapes = [TRAIN_4K, PREFILL_32K]
    if not cfg.is_encoder:
        shapes.append(DECODE_32K)
        subquadratic = cfg.family in ("ssm", "hybrid") or cfg.local_global_ratio > 0
        if subquadratic:
            shapes.append(LONG_500K)
    return shapes
