"""Host-RAM tier for the paged quantized KV pool.

KVTuner's 4-8x cache compression is exactly what makes host<->device block
migration affordable: a swapped block moves *packed* codes + scales, not
bf16 KV, so offloading rides the same mixed-precision schedule the decode
kernels consume. This module holds the host side of the tier hierarchy

    device pool  ->  host block store  ->  recompute from prompt

* :func:`extract_blocks` / :func:`scatter_blocks` move packed blocks between
  the per-layer device pools and host numpy in ONE batched ``device_get`` /
  ``device_put`` per call (all layers, all blocks together), bitwise exact —
  a swapped-out block swapped back in dequantizes to identical values.
* :class:`HostBlockStore` is the refcounted host-side container: evicted
  radix-tree prefixes spill here instead of being dropped, and preempted
  requests park their exclusively-owned blocks here until resume. Handles
  are reference-counted exactly like device blocks in ``BlockAllocator``
  (the tree and a parked request may both point at host bytes), and freeing
  an unheld handle raises instead of corrupting the store.

All movement happens host-side between jitted steps — device code never
sees the host tier, so the single-compile decode step is untouched.
"""
from __future__ import annotations

import jax
import numpy as np

#: per-layer payload of one packed block:
#: (k_codes, k_scale, k_zero, v_codes, v_scale, v_zero); scale/zero are
#: ``None`` for unquantized (bits >= 16) segments, whose pool arrays are
#: shared ``(1,)`` dummies that never move.
LayerBlock = tuple


class HostStoreError(RuntimeError):
    """A host-tier transfer failed (injected or real): the requested bytes
    could not be read back. Callers fall back — drop the unreachable prefix
    chain, or demote the parked request to recompute-from-prompt."""


def _live_pools(pools) -> list:
    return [p for p in pools if p is not None]


def extract_blocks(pools, bids) -> list[list[LayerBlock]]:
    """Copy packed blocks ``bids`` of every layer pool to host numpy with ONE
    batched ``device_get``. Returns one payload per block id: a list over
    attention layers of :data:`LayerBlock` tuples (layer order = the order of
    non-``None`` entries in ``pools``)."""
    import jax.numpy as jnp

    idx = jnp.asarray(list(bids), jnp.int32)
    batched = []
    for p in _live_pools(pools):
        sides = []
        for codes, scale, zero, seg in (
                (p.k_codes, p.k_scale, p.k_zero, p.codec.k),
                (p.v_codes, p.v_scale, p.v_zero, p.codec.v)):
            if seg.quantized:
                sides.append((codes[idx], scale[idx], zero[idx]))
            else:
                sides.append((codes[idx], None, None))
        batched.append(tuple(sides[0]) + tuple(sides[1]))
    host = jax.device_get(batched)   # ONE transfer batch for all layers
    n = len(bids)
    return [[tuple(None if a is None else np.asarray(a[i]) for a in layer)
             for layer in host] for i in range(n)]


def _scatter_blocks_jit(live, stacked, idx):
    """Jitted body of :func:`scatter_blocks`: ``live`` (the non-``None``
    pools) is DONATED, so the updates land in place instead of holding
    old+new copies of every pool array — the pool is sized to fill HBM, so
    a transient double residency per swap event would OOM exactly the
    deployments the host tier exists for. Retraces once per distinct
    swapped-block count (swap-event admission cost, like any prefill)."""
    import dataclasses

    out = []
    for p, (kc, ks, kz, vc, vs, vz) in zip(live, stacked):
        rep = dict(k_codes=p.k_codes.at[idx].set(kc),
                   v_codes=p.v_codes.at[idx].set(vc))
        if ks is not None:
            rep.update(k_scale=p.k_scale.at[idx].set(ks),
                       k_zero=p.k_zero.at[idx].set(kz))
        if vs is not None:
            rep.update(v_scale=p.v_scale.at[idx].set(vs),
                       v_zero=p.v_zero.at[idx].set(vz))
        out.append(dataclasses.replace(p, **rep))
    return out


_scatter_blocks_call = jax.jit(_scatter_blocks_jit, donate_argnums=(0,))


def scatter_blocks(pools, payloads: list[list[LayerBlock]], dst_bids):
    """Write host payloads into device blocks ``dst_bids`` (one batched
    transfer of the stacked arrays, then one donating jitted scatter over
    all layers). Returns the new pools list; bitwise the inverse of
    :func:`extract_blocks`. The input pools' buffers are consumed (donated)
    — callers must drop their old references, as the engine does when it
    rebinds ``state.pools``."""
    import jax.numpy as jnp

    if not payloads:
        return list(pools)
    if len(payloads) != len(dst_bids):
        raise ValueError(f"{len(payloads)} payloads for {len(dst_bids)} "
                         "destination blocks")
    idx = jnp.asarray(list(dst_bids), jnp.int32)
    live = _live_pools(pools)
    stacked = [tuple(None if payloads[0][li][f] is None
                     else np.stack([pl[li][f] for pl in payloads])
                     for f in range(6)) for li in range(len(live))]
    new_live = iter(_scatter_blocks_call(live, stacked, idx))
    return [None if p is None else next(new_live) for p in pools]


def extract_residual(pools, slot: int) -> list[tuple]:
    """Copy one slot's per-layer (k_res, v_res) rows to host (one batched
    ``device_get``) — the partial-group window a preempted request must carry
    to its new slot."""
    rows = [(p.k_res[slot], p.v_res[slot]) for p in _live_pools(pools)]
    return [tuple(np.asarray(a) for a in rw) for rw in jax.device_get(rows)]


def _scatter_residual_jit(live, rows, slot):
    import dataclasses

    return [dataclasses.replace(p, k_res=p.k_res.at[slot].set(kr),
                                v_res=p.v_res.at[slot].set(vr))
            for p, (kr, vr) in zip(live, rows)]


_scatter_residual_call = jax.jit(_scatter_residual_jit, donate_argnums=(0,))


def scatter_residual(pools, rows: list[tuple], slot: int):
    """Restore per-layer residual rows at ``slot``; inverse of
    :func:`extract_residual`. Donating (see :func:`scatter_blocks`)."""
    import jax.numpy as jnp

    new_live = iter(_scatter_residual_call(
        _live_pools(pools), rows, jnp.asarray(slot, jnp.int32)))
    return [None if p is None else next(new_live) for p in pools]


class HostBlockStore:
    """Refcounted host-RAM store of packed quantized KV blocks.

    A *handle* names one logical block's bytes across every layer (mirroring
    how one device block id spans all layer pools). ``capacity`` bounds the
    number of resident blocks — the knob that sizes the host tier the way
    ``num_blocks`` sizes the device pool. Handles are reference-counted:
    the radix tree holds one reference on spilled prefix blocks, a parked
    request holds one on its swapped-out blocks, and the payload is freed
    when the last reference drops.
    """

    def __init__(self, capacity: int):
        if capacity < 0:
            raise ValueError(f"host store capacity must be >= 0, "
                             f"got {capacity}")
        self.capacity = capacity
        self._store: dict[int, list[LayerBlock]] = {}
        self._refs: dict[int, int] = {}
        self._next = 0
        #: fault-injection hook: ``hook(op, n) -> bool`` with ``op`` in
        #: {"put", "get"}; True fails the call — ``put_blocks`` returns
        #: ``None`` (the capacity-full signal every caller already handles)
        #: and ``take_to_device`` raises :class:`HostStoreError`, both
        #: BEFORE any bytes move or refcounts change
        self.fault_hook = None

    def __len__(self) -> int:
        return len(self._store)

    @property
    def free_slots(self) -> int:
        return self.capacity - len(self._store)

    def stored_bytes(self) -> int:
        total = 0
        for payload in self._store.values():
            for layer in payload:
                total += sum(a.nbytes for a in layer if a is not None)
        return total

    # ---------------------------------------------------------------- swap
    def put_blocks(self, pools, bids) -> list[int] | None:
        """Swap packed device blocks ``bids`` out to the host tier (one
        batched transfer). Returns one handle per block at refcount 1, or
        ``None`` without copying anything when capacity cannot hold them —
        the caller then falls back to dropping (prefix spill) or recompute
        (preemption)."""
        bids = list(bids)
        if len(bids) > self.free_slots:
            return None
        if not bids:
            return []
        if self.fault_hook is not None and self.fault_hook("put", len(bids)):
            return None
        payloads = extract_blocks(pools, bids)
        handles = []
        for pl in payloads:
            h = self._next
            self._next += 1
            self._store[h] = pl
            self._refs[h] = 1
            handles.append(h)
        return handles

    def take_to_device(self, pools, handles, dst_bids) -> list:
        """Swap host blocks back into device blocks ``dst_bids`` (one batched
        transfer); returns the new pools. Handles stay resident (and
        referenced) — the caller releases them once the swap-in is final."""
        handles = list(handles)
        if handles and self.fault_hook is not None \
                and self.fault_hook("get", len(handles)):
            raise HostStoreError(
                f"injected host-tier read failure ({len(handles)} blocks)")
        payloads = [self._payload(h) for h in handles]
        return scatter_blocks(pools, payloads, dst_bids)

    # ------------------------------------------------------------ refcounts
    def refcount(self, handle: int) -> int:
        return self._refs.get(handle, 0)

    def handle_refcounts(self) -> dict[int, int]:
        """Snapshot of every live handle's refcount (audit hook)."""
        return dict(self._refs)

    def ref(self, handles) -> None:
        for h in handles:
            self._payload(h)
            self._refs[h] += 1

    def release(self, handles) -> None:
        for h in handles:
            self._payload(h)
            self._refs[h] -= 1
            if self._refs[h] == 0:
                del self._store[h]
                del self._refs[h]

    def _payload(self, handle: int) -> list[LayerBlock]:
        pl = self._store.get(handle)
        if pl is None:
            raise ValueError(f"bad or freed host block handle {handle}")
        return pl
