"""Reusable quantize/pack/dequant codec for KV cache segments.

Extracted from ``LayerKVCache`` so every storage layout — the dense per-request
segment cache and the shared paged block pool — speaks the same packed format:
uint8 codes packed along head_dim + per-group f32 (scale, zero), with the
grouped-scale convention of ``repro.core.quant``.

A ``SegmentCodec`` describes ONE side (K or V) with a resolved mode
(per-token or per-channel; the 'kivi' pair mode is resolved by ``KVCodec``).
Precision is static — bits/mode are python values, so every codec method
lowers with zero dynamic control flow (the KVTuner property).

Shape convention: segments are ``[*lead, S, D]`` with arbitrary leading axes
(``[B, Hkv]`` for the dense cache, ``[N_blocks, Hkv]`` for the paged pool).
Grouped scale/zero shapes:

* per-channel (groups of ``R`` tokens): ``[*lead, S/R, 1, D]``
* per-token (groups of ``min(R, D)`` channels): ``[*lead, S, D/g, 1]``
* bits >= 16 (no quantization): scale/zero collapse to a ``(1,)`` dummy.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.precision import (MODE_KIVI, MODE_PER_CHANNEL, MODE_PER_TOKEN,
                                  PrecisionPair)


def kv_modes(mode: str) -> tuple[str, str]:
    """Resolve a pair mode to (k_mode, v_mode); 'kivi' = per-channel keys,
    per-token values (paper §4.2)."""
    if mode == MODE_KIVI:
        return MODE_PER_CHANNEL, MODE_PER_TOKEN
    return mode, mode


@dataclasses.dataclass(frozen=True)
class SegmentCodec:
    """Static codec for one packed K or V segment."""

    bits: int
    mode: str          # resolved per-segment mode (never 'kivi')
    group_size: int
    head_dim: int

    @property
    def quantized(self) -> bool:
        return self.bits < 16

    @property
    def code_dim(self) -> int:
        """Packed last-axis width of the codes tensor."""
        d = self.head_dim
        return d if self.bits >= 16 else d * self.bits // 8

    def scale_shape(self, lead: tuple, n_tokens: int) -> tuple:
        if self.bits >= 16:
            return (1,)
        d, r = self.head_dim, self.group_size
        if self.mode == MODE_PER_CHANNEL:  # groups along S
            return (*lead, n_tokens // r, 1, d)
        return (*lead, n_tokens, d // min(r, d), 1)

    def init_segment(self, lead: tuple, n_tokens: int, dtype):
        """Zero-initialized (codes, scale, zero) for a [*lead, n_tokens, D]
        segment; raw dtype storage when bits >= 16."""
        if self.bits >= 16:
            codes = jnp.zeros((*lead, n_tokens, self.head_dim), dtype)
            # two distinct dummies: aliased buffers break jit donation
            return (codes, jnp.zeros((1,), jnp.float32),
                    jnp.zeros((1,), jnp.float32))
        codes = jnp.zeros((*lead, n_tokens, self.code_dim), jnp.uint8)
        sshape = self.scale_shape(lead, n_tokens)
        return codes, jnp.ones(sshape, jnp.float32), jnp.zeros(sshape, jnp.float32)

    def encode(self, x: jax.Array):
        """x [*lead, S, D] → (codes, scale, zero). Passthrough when bits>=16
        (dummy scale/zero so the pytree structure is layout-stable)."""
        if self.bits >= 16:
            dummy = jnp.zeros((1,), jnp.float32)
            return x, dummy, dummy
        qt = quant.quantize(x, self.bits, self.mode, self.group_size)
        return qt.codes, qt.scale, qt.zero

    def decode(self, codes: jax.Array, scale: jax.Array, zero: jax.Array,
               dtype=jnp.bfloat16) -> jax.Array:
        """codes [*lead, S, cd] → X̂ [*lead, S, D] (any number of lead axes)."""
        if self.bits >= 16:
            return codes.astype(dtype)
        *lead, s, _ = codes.shape
        d, r = self.head_dim, self.group_size
        raw = quant.unpack_codes(codes, self.bits).astype(jnp.float32)
        if self.mode == MODE_PER_CHANNEL:
            rg = raw.reshape(*lead, s // r, r, d)
            out = rg * scale + zero
        else:
            g = min(r, d)
            rg = raw.reshape(*lead, s, d // g, g)
            out = rg * scale + zero
        return out.reshape(*lead, s, d).astype(dtype)

    def segment_bytes(self, lead: tuple, n_tokens: int, dtype) -> int:
        """Packed bytes of a [*lead, n_tokens, D] segment incl. scale/zero."""
        import numpy as np

        n_lead = int(np.prod(lead)) if lead else 1
        if self.bits >= 16:
            return n_lead * n_tokens * self.head_dim * jnp.dtype(dtype).itemsize
        codes = n_lead * n_tokens * self.code_dim
        scales = 2 * 4 * int(np.prod(self.scale_shape(lead, n_tokens)))
        return codes + scales


@dataclasses.dataclass(frozen=True)
class KVCodec:
    """The (K, V) codec pair for one attention layer."""

    k: SegmentCodec
    v: SegmentCodec
    mode: str          # original pair mode (may be 'kivi')
    group_size: int

    @classmethod
    def make(cls, pair: PrecisionPair, mode: str, group_size: int,
             head_dim: int) -> "KVCodec":
        k_mode, v_mode = kv_modes(mode)
        return cls(
            k=SegmentCodec(pair.k_bits, k_mode, group_size, head_dim),
            v=SegmentCodec(pair.v_bits, v_mode, group_size, head_dim),
            mode=mode, group_size=group_size)

    @property
    def head_dim(self) -> int:
        return self.k.head_dim
