"""Radix-tree prefix index over the paged quantized KV pool.

Serving workloads repeat prompt prefixes constantly (system prompts, few-shot
templates); because pool blocks are immutable packed quant groups of exactly
``R`` tokens, a finished request's prompt blocks can be re-used verbatim by
any later request whose prompt starts with the same tokens — no requantization
and no prefill compute for the shared part.

The index is a radix tree over **group chains**: each node is one full
R-token group, keyed by its token ids, holding the physical block id that
stores that group's quantized KV (for every layer — block ``i`` of each
layer's pool belongs to the same request, so one id suffices). A path from
the root spells out a prompt prefix in R-token steps.

Sharing is copy-on-write at block granularity: cached blocks are only ever
*read* (prefill writes start past the shared prefix, and decode flushes
target a request's own freshly allocated blocks), so "copying" degenerates
to forking the chain — a request whose prompt diverges at group ``g``
allocates fresh blocks from ``g`` on and inserts them as sibling nodes.

Lifetime is reference-counted through :class:`~repro.cache.paged.
BlockAllocator`: the tree holds one reference on every indexed block, each
live request holds one more on the blocks it pinned. When the allocator runs
dry, :meth:`PrefixCache.evict_lru` reclaims the least-recently-used *leaf*
whose block no live request references — trimming cold prefixes suffix-first
so a chain is never broken in the middle.

With a :class:`~repro.cache.offload.HostBlockStore` attached, eviction
**spills instead of dropping**: the victim's packed block moves to host RAM
and the node stays in the tree as a *host-resident* entry (``block = -1``,
``host`` = store handle). A later longest-prefix match that reaches a
host-resident chain still counts as a hit — the engine swaps the bytes back
into freshly allocated device blocks and pins them like any shared prefix.
Only when the host tier itself is full (and no colder host entry can be
dropped to make room) does eviction fall back to dropping.
"""
from __future__ import annotations

import dataclasses

from repro.cache.paged import BlockAllocator


@dataclasses.dataclass(eq=False)
class PrefixNode:
    """One cached R-token group: ``key`` = its token ids, ``block`` = the
    physical pool block holding its quantized KV (``-1`` when the bytes live
    in the host tier under handle ``host``)."""

    key: tuple[int, ...]
    block: int
    parent: "PrefixNode | None"
    children: dict[tuple[int, ...], "PrefixNode"] = \
        dataclasses.field(default_factory=dict)
    last_used: int = 0
    host: int | None = None

    @property
    def on_device(self) -> bool:
        return self.block >= 0


class PrefixCache:
    """Host-side longest-prefix index; all bookkeeping happens between
    jitted steps (device code only ever reads page tables).

    ``host_store`` (optional) enables the spill tier: see module docstring.
    """

    def __init__(self, allocator: BlockAllocator, group_size: int,
                 host_store=None):
        self.alloc = allocator
        self.group_size = group_size
        self.host = host_store
        self.root = PrefixNode(key=(), block=-1, parent=None)
        self._clock = 0
        self._nodes = 0
        # cumulative tier-transition counters (engines report deltas)
        self.spilled_blocks = 0      # device -> host
        self.dropped_blocks = 0      # device -> gone
        self.host_dropped_blocks = 0  # host -> gone

    def __len__(self) -> int:
        """Number of cached groups (device- plus host-resident)."""
        return self._nodes

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _groups(self, tokens) -> list[tuple[int, ...]]:
        r = self.group_size
        return [tuple(int(t) for t in tokens[g * r:(g + 1) * r])
                for g in range(len(tokens) // r)]

    # -------------------------------------------------------------- lookup
    def match(self, tokens) -> list[int]:
        """Longest *device-resident* cached chain of full groups prefixing
        ``tokens``; returns the physical block ids (group ``g`` of the
        prompt → ``blocks[g]``). Stops at the first host-resident node —
        use :meth:`match_nodes` for tier-aware admission.

        A pure lookup: LRU stamps refresh only on :meth:`insert` (a
        successful admission), so a speculative match — truncated by the
        engine's chunk alignment, or followed by a failed allocation — does
        not promote never-used suffix nodes over genuinely warm chains.
        Between a match and its admission the engine pins the blocks, so
        unstamped matched nodes cannot be evicted underneath it."""
        blocks = []
        for n in self.match_nodes(tokens):
            if not n.on_device:
                break
            blocks.append(n.block)
        return blocks

    def match_nodes(self, tokens) -> list["PrefixNode"]:
        """Longest cached chain of full groups prefixing ``tokens`` across
        BOTH tiers — node ``g`` may be device-resident (``on_device``) or
        host-resident (``host`` handle). The engine swaps host entries back
        into fresh device blocks before pinning the chain. Like
        :meth:`match`, a pure lookup (no LRU stamping): a chain of
        device-resident nodes is always a prefix of the result (eviction
        spills suffix-first and swap-in restores root-first)."""
        node, chain = self.root, []
        for key in self._groups(tokens):
            child = node.children.get(key)
            if child is None:
                break
            chain.append(child)
            node = child
        return chain

    # -------------------------------------------------------------- insert
    def insert(self, tokens, blocks: list[int]) -> int:
        """Index a prefilled prompt's full-group chain: ``blocks[g]`` holds
        group ``g``. Newly adopted blocks gain one tree reference (so they
        outlive the request); already-cached groups just refresh their LRU
        stamp. A host-resident node whose group the request holds a device
        block for is *promoted* back to the device tier (its host copy is
        freed — the fresh block is bitwise identical by the chunk-aligned
        sharing invariant). Returns the number of groups newly adopted."""
        t = self._tick()
        node, adopted = self.root, 0
        for g, key in enumerate(self._groups(tokens)):
            child = node.children.get(key)
            if child is None:
                child = PrefixNode(key=key, block=blocks[g], parent=node,
                                   last_used=t)
                node.children[key] = child
                self.alloc.ref([blocks[g]])
                self._nodes += 1
                adopted += 1
            else:
                if not child.on_device:
                    # promote: tree adopts the request's fresh device block
                    self.alloc.ref([blocks[g]])
                    self.host.release([child.host])
                    child.block, child.host = blocks[g], None
                child.last_used = t
            node = child
        return adopted

    # ------------------------------------------------------------ eviction
    def _scan(self):
        """One post-order pass over the tree: ``(device_cands, host_cands)``
        — nodes whose whole subtree is unpinned (no live request holds any
        block in it; host entries count a store reference beyond the tree's
        own as a pin), each list in LRU order, deeper first on ties so a
        chain always trims suffix-before-parent. Host candidates addition-
        ally require a subtree free of device nodes (dropping one must not
        orphan a device-resident descendant). Iterative (cached chains can
        be thousands of groups deep)."""
        dev, hst = [], []
        ok: dict[int, tuple[bool, bool]] = {}  # id -> (unpinned, host_only)
        stack = [(c, 1, False) for c in self.root.children.values()]
        while stack:
            node, depth, visited = stack.pop()
            if not visited:
                stack.append((node, depth, True))
                stack.extend((c, depth + 1, False)
                             for c in node.children.values())
                continue
            kids = [ok[id(c)] for c in node.children.values()]
            sub_ok = all(k[0] for k in kids)
            if node.on_device:
                unpinned = sub_ok and self.alloc.refcount(node.block) == 1
                host_only = False
            else:
                unpinned = sub_ok and self.host.refcount(node.host) == 1
                host_only = all(k[1] for k in kids)
            ok[id(node)] = (unpinned, unpinned and host_only)
            if unpinned:
                entry = (node.last_used, -depth, id(node), node)
                if node.on_device:
                    dev.append(entry)
                elif host_only:
                    hst.append(entry)
        dev.sort()
        hst.sort()
        return [c[-1] for c in dev], [c[-1] for c in hst]

    def _drop(self, node) -> None:
        """Unlink ``node`` and free its whole (detached) subtree. Callers
        always drop unpinned device descendants first (candidate lists put
        children before parents and are consumed prefix-first), so anything
        still attached below can only be host-resident — spilled suffixes
        ride along with their dropped ancestor instead of leaking."""
        del node.parent.children[node.key]
        stack = [node]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            self._nodes -= 1
            if n.on_device:
                if n is not node:
                    raise AssertionError(
                        "evicted a node with device-resident descendants")
                self.alloc.release([n.block])
                self.dropped_blocks += 1
            else:
                self.host.release([n.host])
                self.host_dropped_blocks += 1

    def evict(self, need: int, partial: bool = False, pools=None) -> int:
        """Free up to ``need`` device blocks, least-recently-used first, in
        ONE tree scan. When fewer than ``need`` blocks are evictable the
        call refuses (returns 0) unless ``partial`` — a doomed allocation
        attempt must not destroy cached templates it cannot help anyway.

        With a host store attached and ``pools`` given, victims **spill**:
        their packed bytes move to the host tier in one batched transfer and
        the nodes stay matchable (host-resident). Colder host entries are
        dropped to make room; victims the host tier cannot hold after that
        are dropped outright. Hotter victims get the host slots (drops take
        the LRU end), so the tier order always runs cold -> colder."""
        if need <= 0:
            return 0
        dev, hst = self._scan()
        if len(dev) < need and not partial:
            return 0
        take = dev[:need]
        n_spill = 0
        if self.host is not None and pools is not None and take:
            room = self.host.free_slots
            if room < len(take):
                # drop cold host entries to make room for hotter spills
                for node in hst:
                    if room >= len(take):
                        break
                    self._drop(node)
                    room += 1
            n_spill = min(len(take), room)
        dropped, spilled = take[:len(take) - n_spill], \
            take[len(take) - n_spill:]
        if spilled:
            handles = self.host.put_blocks(pools, [n.block for n in spilled])
            if handles is None:   # raced capacity (shouldn't happen)
                dropped, spilled, handles = take, [], []
            for node, h in zip(spilled, handles):
                self.alloc.release([node.block])
                node.block, node.host = -1, h
                self.spilled_blocks += 1
        for node in dropped:
            self._drop(node)
        return len(take)

    def drop_host_lru(self, n: int) -> int:
        """Drop up to ``n`` cold host-tier entries (LRU, suffix-first) to
        make room in the store — used before parking a preempted request's
        blocks. Returns entries dropped."""
        if n <= 0:
            return 0
        _, hst = self._scan()
        for node in hst[:n]:
            self._drop(node)
        return min(n, len(hst))

    def iter_nodes(self):
        """Iterate every cached node across both tiers, parents before
        children (audit hook — the engine-wide invariant auditor walks the
        tree to reconstruct expected block/handle refcounts)."""
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    def drop_chain(self, node: PrefixNode) -> None:
        """Unlink ``node`` and free its whole subtree immediately — the
        failure path for a host-resident chain whose bytes became
        unreachable (a faulted store read): the next longest-prefix match
        then stops at the device-resident part instead of retrying the dead
        handles forever. Host nodes never have device-resident descendants
        (insert promotes ancestors first), so the cascade is tier-safe."""
        self._drop(node)

    def evict_lru(self, pools=None) -> int:
        """Reclaim the least-recently-used evictable leaf's device block;
        1 if freed, else 0. Pass ``pools`` to spill it into an attached
        host store instead of dropping (see :meth:`evict`)."""
        return self.evict(1, pools=pools)

    def clear(self) -> int:
        """Drop every evictable cached prefix (both tiers, nothing spills);
        returns device blocks freed."""
        freed = 0
        while True:
            dev, hst = self._scan()
            if not dev and not hst:
                return freed
            if dev:
                # children precede parents; host suffixes cascade along
                for node in dev:
                    self._drop(node)
                freed += len(dev)
            else:
                self._drop(hst[0])
