"""Radix-tree prefix index over the paged quantized KV pool.

Serving workloads repeat prompt prefixes constantly (system prompts, few-shot
templates); because pool blocks are immutable packed quant groups of exactly
``R`` tokens, a finished request's prompt blocks can be re-used verbatim by
any later request whose prompt starts with the same tokens — no requantization
and no prefill compute for the shared part.

The index is a radix tree over **group chains**: each node is one full
R-token group, keyed by its token ids, holding the physical block id that
stores that group's quantized KV (for every layer — block ``i`` of each
layer's pool belongs to the same request, so one id suffices). A path from
the root spells out a prompt prefix in R-token steps.

Sharing is copy-on-write at block granularity: cached blocks are only ever
*read* (prefill writes start past the shared prefix, and decode flushes
target a request's own freshly allocated blocks), so "copying" degenerates
to forking the chain — a request whose prompt diverges at group ``g``
allocates fresh blocks from ``g`` on and inserts them as sibling nodes.

Lifetime is reference-counted through :class:`~repro.cache.paged.
BlockAllocator`: the tree holds one reference on every indexed block, each
live request holds one more on the blocks it pinned. When the allocator runs
dry, :meth:`PrefixCache.evict_lru` drops the least-recently-used *leaf*
whose block no live request references — trimming cold prefixes suffix-first
so a chain is never broken in the middle.
"""
from __future__ import annotations

import dataclasses

from repro.cache.paged import BlockAllocator


@dataclasses.dataclass(eq=False)
class PrefixNode:
    """One cached R-token group: ``key`` = its token ids, ``block`` = the
    physical pool block holding its quantized KV."""

    key: tuple[int, ...]
    block: int
    parent: "PrefixNode | None"
    children: dict[tuple[int, ...], "PrefixNode"] = \
        dataclasses.field(default_factory=dict)
    last_used: int = 0


class PrefixCache:
    """Host-side longest-prefix index; all bookkeeping happens between
    jitted steps (device code only ever reads page tables)."""

    def __init__(self, allocator: BlockAllocator, group_size: int):
        self.alloc = allocator
        self.group_size = group_size
        self.root = PrefixNode(key=(), block=-1, parent=None)
        self._clock = 0
        self._nodes = 0

    def __len__(self) -> int:
        """Number of cached groups (= pool blocks the tree references)."""
        return self._nodes

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _groups(self, tokens) -> list[tuple[int, ...]]:
        r = self.group_size
        return [tuple(int(t) for t in tokens[g * r:(g + 1) * r])
                for g in range(len(tokens) // r)]

    # -------------------------------------------------------------- lookup
    def match(self, tokens) -> list[int]:
        """Longest cached chain of full groups prefixing ``tokens``; returns
        the physical block ids (group ``g`` of the prompt → ``blocks[g]``).

        A pure lookup: LRU stamps refresh only on :meth:`insert` (a
        successful admission), so a speculative match — truncated by the
        engine's chunk alignment, or followed by a failed allocation — does
        not promote never-used suffix nodes over genuinely warm chains.
        Between a match and its admission the engine pins the blocks, so
        unstamped matched nodes cannot be evicted underneath it."""
        node, blocks = self.root, []
        for key in self._groups(tokens):
            child = node.children.get(key)
            if child is None:
                break
            blocks.append(child.block)
            node = child
        return blocks

    # -------------------------------------------------------------- insert
    def insert(self, tokens, blocks: list[int]) -> int:
        """Index a prefilled prompt's full-group chain: ``blocks[g]`` holds
        group ``g``. Newly adopted blocks gain one tree reference (so they
        outlive the request); already-cached groups just refresh their LRU
        stamp. Returns the number of groups newly adopted."""
        t = self._tick()
        node, adopted = self.root, 0
        for g, key in enumerate(self._groups(tokens)):
            child = node.children.get(key)
            if child is None:
                child = PrefixNode(key=key, block=blocks[g], parent=node,
                                   last_used=t)
                node.children[key] = child
                self.alloc.ref([blocks[g]])
                self._nodes += 1
                adopted += 1
            else:
                child.last_used = t
            node = child
        return adopted

    # ------------------------------------------------------------ eviction
    def _evictable(self):
        """One post-order pass: nodes whose whole subtree is unpinned (no
        live request holds any block in it), in LRU order — deeper first on
        ties so a chain always trims suffix-before-parent. Iterative (cached
        chains can be thousands of groups deep)."""
        cands = []
        ok: dict[int, bool] = {}
        stack = [(c, 1, False) for c in self.root.children.values()]
        while stack:
            node, depth, visited = stack.pop()
            if not visited:
                stack.append((node, depth, True))
                stack.extend((c, depth + 1, False)
                             for c in node.children.values())
                continue
            sub_ok = all(ok[id(c)] for c in node.children.values())
            e = sub_ok and self.alloc.refcount(node.block) == 1
            ok[id(node)] = e
            if e:
                cands.append((node.last_used, -depth, id(node), node))
        cands.sort()
        return [c[-1] for c in cands]

    def evict(self, need: int, partial: bool = False) -> int:
        """Free up to ``need`` blocks, least-recently-used first, in ONE tree
        scan. When fewer than ``need`` blocks are evictable the call refuses
        (returns 0) unless ``partial`` — a doomed allocation attempt must not
        destroy cached templates it cannot help anyway."""
        if need <= 0:
            return 0
        cands = self._evictable()
        if len(cands) < need and not partial:
            return 0
        freed = 0
        for node in cands:
            if freed >= need:
                break
            del node.parent.children[node.key]
            self._nodes -= 1
            self.alloc.release([node.block])
            freed += 1
        return freed

    def evict_lru(self) -> int:
        """Drop the least-recently-used evictable leaf; 1 if freed, else 0."""
        return self.evict(1)

    def clear(self) -> int:
        """Drop every evictable cached prefix; returns blocks freed."""
        return self.evict(self._nodes, partial=True)
