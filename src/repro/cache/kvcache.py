"""Quantized KV cache with KIVI-style layout (paper §2, §C).

Per attention layer the cache is:

* a **packed quantized main segment** of capacity ``S_cap`` tokens (uint8 codes
  packed along head_dim + per-group f32 scale/zero). Tokens enter the main
  segment only in full groups of ``R = residual_len`` (= quant group size along
  the token axis, so each flushed block is exactly one per-channel group);
* a **bf16 residual window** of the most recent ``< R`` tokens (KIVI keeps
  recent tokens full-precision; paper uses R = 32);
* a scalar ``length`` (total tokens).

Precision is **static per layer** — the KVTuner property that keeps the decode
graph free of dynamic control flow. ``k_bits/v_bits == 16`` stores that side
unquantized (raw dtype) with the same append mechanics.

Shapes: K/V are ``[B, Hkv, S, D]``. The main segment is sized
``S_cap = ceil(seq/R)*R + extra_groups*R`` so decode can append beyond the
prefill length with a static shape.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.cache.codec import KVCodec, SegmentCodec, kv_modes
from repro.core.precision import (MODE_PER_CHANNEL, MODE_PER_TOKEN,
                                  PrecisionPair)
from repro.core import quant

# Back-compat alias: the mode-pair resolution now lives in the shared codec.
_kv_modes = kv_modes


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LayerKVCache:
    """One attention layer's quantized cache. A registered pytree; static
    fields (bits/mode/sizes) are aux data so jit treats them as compile-time."""

    k_codes: jax.Array
    k_scale: jax.Array
    k_zero: jax.Array
    v_codes: jax.Array
    v_scale: jax.Array
    v_zero: jax.Array
    k_res: jax.Array  # [B, Hkv, R, D] working dtype
    v_res: jax.Array
    length: jax.Array  # i32 scalar: total tokens in cache

    k_bits: int = dataclasses.field(metadata=dict(static=True))
    v_bits: int = dataclasses.field(metadata=dict(static=True))
    mode: str = dataclasses.field(metadata=dict(static=True))
    group_size: int = dataclasses.field(metadata=dict(static=True))
    s_cap: int = dataclasses.field(metadata=dict(static=True))
    window: int = dataclasses.field(metadata=dict(static=True))  # 0 = unbounded

    # ------------------------------------------------------------- create
    @classmethod
    def init(cls, batch: int, kv_heads: int, head_dim: int, capacity: int,
             pair: PrecisionPair, mode: str = MODE_PER_TOKEN, group_size: int = 32,
             dtype=jnp.bfloat16, window: int = 0) -> "LayerKVCache":
        r = group_size
        if window:
            capacity = min(capacity, window)
        s_cap = -(-capacity // r) * r
        if s_cap >= 16 * r:
            # round the group count to a multiple of 16 so scale/zero tensors
            # (whose dim is n_groups) stay shardable on a 16-wide mesh axis
            s_cap = -(-s_cap // (16 * r)) * (16 * r)
        b, h, d = batch, kv_heads, head_dim
        codec = KVCodec.make(pair, mode, r, d)
        kc, ks, kz = codec.k.init_segment((b, h), s_cap, dtype)
        vc, vs, vz = codec.v.init_segment((b, h), s_cap, dtype)
        return cls(
            k_codes=kc, k_scale=ks, k_zero=kz, v_codes=vc, v_scale=vs, v_zero=vz,
            k_res=jnp.zeros((b, h, r, d), dtype), v_res=jnp.zeros((b, h, r, d), dtype),
            length=jnp.zeros((), jnp.int32), k_bits=pair.k_bits, v_bits=pair.v_bits,
            mode=mode, group_size=r, s_cap=s_cap, window=window)

    # ------------------------------------------------------------ helpers
    @property
    def residual_len(self) -> int:
        return self.k_res.shape[2]

    @property
    def head_dim(self) -> int:
        return self.k_res.shape[3]

    @property
    def codec(self) -> KVCodec:
        """The layer's static (K, V) codec — shared with the paged pool."""
        return KVCodec.make(PrecisionPair(self.k_bits, self.v_bits), self.mode,
                            self.group_size, self.head_dim)

    def _quant_block(self, block: jax.Array, bits: int, m: str):
        """Quantize one [B,H,R,D] token block → (codes, scale, zero) with the
        same grouped-scale convention used at init."""
        qt = quant.quantize(block, bits, m, self.group_size)
        return qt.codes, qt.scale, qt.zero

    # ------------------------------------------------------------- prefill
    def fill(self, k: jax.Array, v: jax.Array) -> "LayerKVCache":
        """Bulk-insert S tokens (prefill). Any non-group-aligned remainder goes
        to the residual window. Windowed (local-attention) caches keep only the
        trailing tokens, placed at their ring slots (absolute group index mod
        n_groups) so ``token_positions`` stays consistent during decode."""
        b, h, s, d = k.shape
        r = self.group_size
        roll_groups = 0
        s_orig = s
        if self.window and s > self.s_cap:
            start = s - self.s_cap  # group-aligned when s, s_cap are multiples of r
            start = start // r * r
            k, v = k[:, :, start:], v[:, :, start:]
            s = k.shape[2]
            roll_groups = (start // r) % (self.s_cap // r)
        n_full = s // r * r
        out = self
        if n_full:
            out = out._fill_main(k[:, :, :n_full], v[:, :, :n_full],
                                 roll_groups=roll_groups)
        rem = s - n_full
        if rem:
            k_res = out.k_res.at[:, :, :rem].set(k[:, :, n_full:])
            v_res = out.v_res.at[:, :, :rem].set(v[:, :, n_full:])
            out = dataclasses.replace(out, k_res=k_res, v_res=v_res)
        return dataclasses.replace(out, length=jnp.asarray(s_orig, jnp.int32))

    def _fill_main(self, k, v, roll_groups: int = 0) -> "LayerKVCache":
        s = k.shape[2]
        k_mode, v_mode = _kv_modes(self.mode)
        r = self.group_size

        def place(buf, block, per_group: bool):
            """Write `block` into slots, ring-rolled by roll_groups groups."""
            if not roll_groups:
                n = block.shape[2]
                return buf.at[:, :, :n].set(block)
            shift = roll_groups * (1 if per_group else r)
            n_slots = buf.shape[2]
            rolled = jnp.roll(
                jnp.concatenate(
                    [block, buf[:, :, block.shape[2]:]], axis=2)[:, :, :n_slots],
                shift, axis=2)
            return rolled

        def seg(codes, scale, zero, x, bits, m):
            if bits >= 16:
                return place(codes, x, per_group=False), scale, zero
            c, sc, z = self._quant_block(x, bits, m)
            codes = place(codes, c, per_group=False)
            if m == MODE_PER_CHANNEL:
                scale = place(scale, sc, per_group=True)
                zero = place(zero, z, per_group=True)
            else:
                scale = place(scale, sc, per_group=False)
                zero = place(zero, z, per_group=False)
            return codes, scale, zero

        kc, ks, kz = seg(self.k_codes, self.k_scale, self.k_zero, k, self.k_bits, k_mode)
        vc, vs, vz = seg(self.v_codes, self.v_scale, self.v_zero, v, self.v_bits, v_mode)
        return dataclasses.replace(self, k_codes=kc, k_scale=ks, k_zero=kz,
                                   v_codes=vc, v_scale=vs, v_zero=vz)

    # -------------------------------------------------------------- append
    def append(self, k_new: jax.Array, v_new: jax.Array) -> "LayerKVCache":
        """Append one token [B,H,1,D]; flush the residual window to the main
        segment when it fills (static-shape lax.cond)."""
        r = self.group_size
        slot = jnp.mod(self.length, r)
        k_res = jax.lax.dynamic_update_slice_in_dim(self.k_res, k_new, slot, axis=2)
        v_res = jax.lax.dynamic_update_slice_in_dim(self.v_res, v_new, slot, axis=2)
        new_len = self.length + 1
        cache = dataclasses.replace(self, k_res=k_res, v_res=v_res, length=new_len)

        def flush(c: "LayerKVCache") -> "LayerKVCache":
            g = jnp.mod((new_len // r) - 1, c.s_cap // r)  # ring over groups if windowed
            if not c.window:
                g = (new_len // r) - 1
            return c._flush_group(g)

        return jax.lax.cond(jnp.mod(new_len, r) == 0, flush, lambda c: c, cache)

    def _flush_group(self, g: jax.Array) -> "LayerKVCache":
        r = self.group_size
        k_mode, v_mode = _kv_modes(self.mode)

        def seg(codes, scale, zero, res, bits, m):
            if bits >= 16:
                return jax.lax.dynamic_update_slice_in_dim(codes, res, g * r, axis=2), scale, zero
            c, sc, z = self._quant_block(res, bits, m)
            codes = jax.lax.dynamic_update_slice_in_dim(codes, c, g * r, axis=2)
            if m == MODE_PER_CHANNEL:
                scale = jax.lax.dynamic_update_slice_in_dim(scale, sc, g, axis=2)
                zero = jax.lax.dynamic_update_slice_in_dim(zero, z, g, axis=2)
            else:
                scale = jax.lax.dynamic_update_slice_in_dim(scale, sc, g * r, axis=2)
                zero = jax.lax.dynamic_update_slice_in_dim(zero, z, g * r, axis=2)
            return codes, scale, zero

        kc, ks, kz = seg(self.k_codes, self.k_scale, self.k_zero, self.k_res,
                         self.k_bits, k_mode)
        vc, vs, vz = seg(self.v_codes, self.v_scale, self.v_zero, self.v_res,
                         self.v_bits, v_mode)
        return dataclasses.replace(self, k_codes=kc, k_scale=ks, k_zero=kz,
                                   v_codes=vc, v_scale=vs, v_zero=vz)

    # ------------------------------------------------------------- dequant
    def _deq(self, codes, scale, zero, bits, m, dtype):
        return SegmentCodec(bits, m, self.group_size, self.head_dim).decode(
            codes, scale, zero, dtype)

    def dequant(self, dtype=jnp.bfloat16):
        """Full materialized (K̂, V̂, valid) of shape [B,H,S_cap+R,D]; `valid`
        is a [S_cap+R] bool mask of live positions (main + residual).

        This is the XLA reference path; the Pallas kernel consumes the packed
        segments directly (repro.kernels.qdecode).
        """
        k_mode, v_mode = _kv_modes(self.mode)
        k_main = self._deq(self.k_codes, self.k_scale, self.k_zero, self.k_bits,
                           k_mode, dtype)
        v_main = self._deq(self.v_codes, self.v_scale, self.v_zero, self.v_bits,
                           v_mode, dtype)
        k = jnp.concatenate([k_main, self.k_res.astype(dtype)], axis=2)
        v = jnp.concatenate([v_main, self.v_res.astype(dtype)], axis=2)
        n_main = jnp.minimum(self.length // self.group_size * self.group_size,
                             self.s_cap)
        n_res = self.length - (self.length // self.group_size * self.group_size)
        idx = jnp.arange(self.s_cap + self.residual_len)
        valid = jnp.where(idx < self.s_cap, idx < n_main, (idx - self.s_cap) < n_res)
        return k, v, valid

    def token_positions(self) -> jax.Array:
        """Absolute position ids for every cache slot (for RoPE-consistent
        masks); windowed caches wrap groups in a ring."""
        r, s_cap = self.group_size, self.s_cap
        n_groups = s_cap // r
        total_groups = self.length // r
        idx = jnp.arange(s_cap)
        if self.window:
            g = idx // r
            # group g currently holds the group with index: latest occupant
            cycle = jnp.maximum((total_groups - 1 - g) // n_groups, 0)
            occupant = g + cycle * n_groups
            main_pos = occupant * r + idx % r
        else:
            main_pos = idx
        res_pos = total_groups * r + jnp.arange(self.residual_len)
        return jnp.concatenate([main_pos, res_pos])

    # --------------------------------------------------------------- sizes
    def packed_bytes(self) -> int:
        import numpy as np
        total = 0
        for arr in (self.k_codes, self.k_scale, self.k_zero, self.v_codes,
                    self.v_scale, self.v_zero, self.k_res, self.v_res):
            total += int(np.prod(arr.shape)) * arr.dtype.itemsize
        return total


def init_model_cache(cfg, schedule, batch: int, capacity: int, extra_groups: int = 4):
    """Per-attention-layer cache list following a KVTunerSchedule.

    Non-attention layers (mamba/xlstm) get ``None`` here; their recurrent
    state lives in the model-specific state pytree.
    """
    from repro.configs.base import ATTN_LOCAL

    caches = []
    kinds = cfg.layer_kinds()
    attn_ids = cfg.attention_layers()
    r = cfg.kv_residual_len
    cap = -(-capacity // r) * r + extra_groups * r
    for i, kind in enumerate(kinds):
        if i not in attn_ids:
            caches.append(None)
            continue
        pair = schedule[attn_ids.index(i)] if schedule is not None else \
            PrecisionPair(16, 16)
        window = cfg.local_window if kind == ATTN_LOCAL else 0
        caches.append(LayerKVCache.init(
            batch, cfg.num_kv_heads, cfg.head_dim, cap, pair,
            mode=schedule.mode if schedule is not None else MODE_PER_TOKEN,
            group_size=cfg.kv_group_size, dtype=jnp.dtype(cfg.dtype), window=window))
    return caches
