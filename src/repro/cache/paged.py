"""Shared paged pool of quantized KV blocks (vLLM-style, TPU-static shapes).

Instead of one private ``ceil(S/R)·R`` segment per request, every attention
layer owns ONE global pool of ``num_blocks`` packed blocks; a block holds
exactly ``R = group_size`` tokens, so each block is one quantization group
(one per-channel scale row, or R per-token scale rows). Requests map logical
group ``g`` to a physical block through a per-slot **page table** shared by
all layers; blocks are allocated at admission and recycled when a request
finishes — continuous batching without reshaping or re-jitting anything.

Layout per layer (per-layer static ``(k_bits, v_bits)`` preserved, so mixed
precision still lowers with zero dynamic control flow):

* ``k_codes [N, Hkv, R, D·kb/8]`` uint8 (raw dtype when bits >= 16)
* ``k_scale/k_zero``: per-channel ``[N, Hkv, 1, 1, D]``,
  per-token ``[N, Hkv, R, D/g, 1]``, dummy ``(1,)`` when unquantized
* same for V, plus per-slot bf16 residual windows
  ``k_res/v_res [max_slots, Hkv, R, D]`` and nothing else — lengths and the
  page table live in the decode state, shared across layers.

**Block 0 is reserved as a scratch block**: conditional flushes scatter
non-flushing slots' (quantized-but-dead) residuals there, so the decode step
has no per-slot control flow. Page-table entries of unallocated groups also
point at block 0; both are masked out by the per-slot length, so its contents
are never observed.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.cache.codec import KVCodec
from repro.cache.kvcache import LayerKVCache
from repro.core.precision import MODE_PER_CHANNEL, MODE_PER_TOKEN, PrecisionPair

#: physical block id reserved as the scatter target for masked-out writes.
SCRATCH_BLOCK = 0


def _encode_scatter(codes_p, scale_p, zero_p, bids, blk, seg):
    """Quantize ``blk [n, Hkv, R, D]`` token groups with ``seg`` and scatter
    them to physical blocks ``bids [n]`` — the single place that knows the
    packed block layout for every pool write path."""
    bc, bs, bz = seg.encode(blk)
    codes_p = codes_p.at[bids].set(bc.astype(codes_p.dtype))
    if seg.quantized:
        scale_p = scale_p.at[bids].set(bs)
        zero_p = zero_p.at[bids].set(bz)
    return codes_p, scale_p, zero_p


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PagedKVPool:
    """One attention layer's share of the paged pool. A registered pytree;
    bits/mode/sizes are static aux data (compile-time per layer)."""

    k_codes: jax.Array
    k_scale: jax.Array
    k_zero: jax.Array
    v_codes: jax.Array
    v_scale: jax.Array
    v_zero: jax.Array
    k_res: jax.Array   # [max_slots, Hkv, R, D] working dtype
    v_res: jax.Array

    k_bits: int = dataclasses.field(metadata=dict(static=True))
    v_bits: int = dataclasses.field(metadata=dict(static=True))
    mode: str = dataclasses.field(metadata=dict(static=True))
    group_size: int = dataclasses.field(metadata=dict(static=True))

    # ------------------------------------------------------------- create
    @classmethod
    def init(cls, num_blocks: int, max_slots: int, kv_heads: int,
             head_dim: int, pair: PrecisionPair, mode: str = MODE_PER_TOKEN,
             group_size: int = 32, dtype=jnp.bfloat16) -> "PagedKVPool":
        codec = KVCodec.make(pair, mode, group_size, head_dim)
        r = group_size
        kc, ks, kz = codec.k.init_segment((num_blocks, kv_heads), r, dtype)
        vc, vs, vz = codec.v.init_segment((num_blocks, kv_heads), r, dtype)
        # separate residual buffers: the serving state is jit-donated, and
        # donating one buffer twice (aliased k_res/v_res) is an XLA error
        k_res = jnp.zeros((max_slots, kv_heads, r, head_dim), dtype)
        v_res = jnp.zeros((max_slots, kv_heads, r, head_dim), dtype)
        return cls(k_codes=kc, k_scale=ks, k_zero=kz, v_codes=vc, v_scale=vs,
                   v_zero=vz, k_res=k_res, v_res=v_res,
                   k_bits=pair.k_bits, v_bits=pair.v_bits, mode=mode,
                   group_size=r)

    # ------------------------------------------------------------ helpers
    @property
    def num_blocks(self) -> int:
        return self.k_codes.shape[0]

    @property
    def max_slots(self) -> int:
        return self.k_res.shape[0]

    @property
    def head_dim(self) -> int:
        return self.k_res.shape[3]

    @property
    def codec(self) -> KVCodec:
        return KVCodec.make(PrecisionPair(self.k_bits, self.v_bits), self.mode,
                            self.group_size, self.head_dim)

    # ------------------------------------------------------------- prefill
    def adopt_prefill(self, cache: LayerKVCache, slot: jax.Array,
                      pages: jax.Array) -> "PagedKVPool":
        """Copy a freshly prefilled dense **batch-1** ``LayerKVCache`` into
        this pool: the first ``len(pages)`` full groups go to physical blocks
        ``pages``; the cache's residual window goes to the slot's residual.

        Group-block equality is by construction: the dense prefill quantizes
        per R-token group with the same codec, so adopted blocks are bitwise
        what the wave engine's cache holds.
        """
        if (cache.k_bits, cache.v_bits, cache.mode, cache.group_size) != \
                (self.k_bits, self.v_bits, self.mode, self.group_size):
            raise ValueError("cache codec does not match pool codec")
        n_groups = int(pages.shape[0])   # static
        r = self.group_size
        hkv = self.k_res.shape[1]
        c = self.codec

        def side(codes_p, scale_p, zero_p, codes_c, scale_c, zero_c, seg):
            if n_groups:
                blk = codes_c[0, :, :n_groups * r] \
                    .reshape(hkv, n_groups, r, -1).transpose(1, 0, 2, 3)
                codes_p = codes_p.at[pages].set(blk.astype(codes_p.dtype))
                if seg.quantized:
                    if seg.mode == MODE_PER_CHANNEL:
                        sb = scale_c[0, :, :n_groups].transpose(1, 0, 2, 3)[:, :, None]
                        zb = zero_c[0, :, :n_groups].transpose(1, 0, 2, 3)[:, :, None]
                    else:
                        gg = scale_c.shape[-2]
                        sb = scale_c[0, :, :n_groups * r] \
                            .reshape(hkv, n_groups, r, gg, 1).transpose(1, 0, 2, 3, 4)
                        zb = zero_c[0, :, :n_groups * r] \
                            .reshape(hkv, n_groups, r, gg, 1).transpose(1, 0, 2, 3, 4)
                    scale_p = scale_p.at[pages].set(sb)
                    zero_p = zero_p.at[pages].set(zb)
            return codes_p, scale_p, zero_p

        kc, ks, kz = side(self.k_codes, self.k_scale, self.k_zero,
                          cache.k_codes, cache.k_scale, cache.k_zero, c.k)
        vc, vs, vz = side(self.v_codes, self.v_scale, self.v_zero,
                          cache.v_codes, cache.v_scale, cache.v_zero, c.v)
        k_res = self.k_res.at[slot].set(cache.k_res[0].astype(self.k_res.dtype))
        v_res = self.v_res.at[slot].set(cache.v_res[0].astype(self.v_res.dtype))
        return dataclasses.replace(self, k_codes=kc, k_scale=ks, k_zero=kz,
                                   v_codes=vc, v_scale=vs, v_zero=vz,
                                   k_res=k_res, v_res=v_res)

    def write_prefill_groups(self, k: jax.Array, v: jax.Array,
                             bids: jax.Array) -> "PagedKVPool":
        """Quantize full groups of post-rope chunk KV straight into pool
        blocks — the chunked in-pool prefill write (no dense ``LayerKVCache``
        intermediate).

        ``k/v [1, Hkv, n·R, D]`` (group-aligned chunk slice);
        ``bids [n]`` i32 physical block ids (may be traced). Group boundaries
        are the quantization boundaries, so the written blocks are bitwise
        what a dense prefill + :meth:`adopt_prefill` would have produced.
        """
        r = self.group_size
        hkv = self.k_res.shape[1]
        n = k.shape[2] // r   # static
        c = self.codec

        def groups(x):
            return x[0].reshape(hkv, n, r, -1).transpose(1, 0, 2, 3)

        kc, ks, kz = _encode_scatter(self.k_codes, self.k_scale, self.k_zero,
                                     bids, groups(k), c.k)
        vc, vs, vz = _encode_scatter(self.v_codes, self.v_scale, self.v_zero,
                                     bids, groups(v), c.v)
        return dataclasses.replace(self, k_codes=kc, k_scale=ks, k_zero=kz,
                                   v_codes=vc, v_scale=vs, v_zero=vz)

    def write_wave(self, k: jax.Array, v: jax.Array, page_table: jax.Array,
                   ctx_lens: jax.Array, chunk_lens: jax.Array) \
            -> "PagedKVPool":
        """Masked batched write of ONE prefill chunk wave for every slot —
        the batched-admission twin of :meth:`write_prefill_groups` +
        :meth:`write_residual`, with **traced** per-slot lengths and no
        per-slot control flow:

        * each of the chunk's ``C // R`` groups scatters to the page-table
          block at logical group ``ctx_lens // R + i`` when ``i`` is below
          the slot's own full-group count, else to :data:`SCRATCH_BLOCK`
          (dead lanes — slots mid-decode or out of chunks — scatter
          everything to scratch and are untouched);
        * the trailing partial group (``chunk_lens % R`` tokens, last wave
          of a request only) lands in the slot's residual window via a
          masked positional write.

        ``k/v [max_slots, Hkv, C, D]`` post-rope chunk KV (C a multiple of
        R — waves are padded to the engine's chunk size); ``page_table
        [max_slots, P]``; ``ctx_lens/chunk_lens [max_slots]`` i32 (ctx a
        multiple of R). Written blocks are bitwise what the serial
        unbatched prefill produces for the same inputs (group boundaries
        are the quantization boundaries either way).
        """
        r = self.group_size
        s, hkv, c_len, _ = k.shape
        if c_len % r:
            raise ValueError(f"wave chunk width {c_len} not a multiple of "
                             f"the quant group size {r}")
        if s != self.max_slots:
            raise ValueError(f"wave batch {s} != max_slots {self.max_slots}")
        n_g = c_len // r
        ctx_lens = ctx_lens.astype(jnp.int32)
        chunk_lens = chunk_lens.astype(jnp.int32)
        full = jnp.minimum(chunk_lens // r, n_g)          # [S]
        gi = jnp.broadcast_to(jnp.arange(n_g)[None, :], (s, n_g))
        logical = jnp.clip(ctx_lens[:, None] // r + gi, 0,
                           page_table.shape[1] - 1)
        real = jnp.take_along_axis(page_table.astype(jnp.int32), logical,
                                   axis=1)
        bids = jnp.where(gi < full[:, None], real,
                         SCRATCH_BLOCK).reshape(-1)       # [S·n_g]
        c = self.codec

        def groups(x):
            return x.reshape(s, hkv, n_g, r, -1).transpose(0, 2, 1, 3, 4) \
                .reshape(s * n_g, hkv, r, -1)

        kc, ks, kz = _encode_scatter(self.k_codes, self.k_scale, self.k_zero,
                                     bids, groups(k), c.k)
        vc, vs, vz = _encode_scatter(self.v_codes, self.v_scale, self.v_zero,
                                     bids, groups(v), c.v)

        # trailing partial group → residual window positions [0, rem)
        rem = chunk_lens - full * r                        # [S], 0..R-1
        pos = jnp.broadcast_to(jnp.arange(r)[None, :], (s, r))
        src = jnp.clip(full[:, None] * r + pos, 0, c_len - 1)
        k_tail = jnp.take_along_axis(k, src[:, None, :, None], axis=2)
        v_tail = jnp.take_along_axis(v, src[:, None, :, None], axis=2)
        wmask = (pos < rem[:, None])[:, None, :, None]
        k_res = jnp.where(wmask, k_tail.astype(self.k_res.dtype), self.k_res)
        v_res = jnp.where(wmask, v_tail.astype(self.v_res.dtype), self.v_res)
        return dataclasses.replace(self, k_codes=kc, k_scale=ks, k_zero=kz,
                                   v_codes=vc, v_scale=vs, v_zero=vz,
                                   k_res=k_res, v_res=v_res)

    def write_residual(self, slot: jax.Array, k_tail: jax.Array,
                       v_tail: jax.Array) -> "PagedKVPool":
        """Seed a slot's residual window with the prompt's trailing partial
        group. ``k_tail/v_tail [1, Hkv, rem, D]``, ``rem < R`` static."""
        rem = k_tail.shape[2]
        k_res = self.k_res.at[slot, :, :rem].set(
            k_tail[0].astype(self.k_res.dtype))
        v_res = self.v_res.at[slot, :, :rem].set(
            v_tail[0].astype(self.v_res.dtype))
        return dataclasses.replace(self, k_res=k_res, v_res=v_res)

    # -------------------------------------------------------------- append
    def append(self, k_new: jax.Array, v_new: jax.Array, lengths: jax.Array,
               alive: jax.Array, page_table: jax.Array) -> "PagedKVPool":
        """Append one token per live slot; flush full residual groups into
        their page-table block. Fully batched, no per-slot control flow:

        * the residual write is a masked one-hot update at ``lengths % R``;
        * every slot's residual is (re)quantized each step, but only slots
          whose new length crosses a group boundary scatter to their real
          block — everyone else scatters to :data:`SCRATCH_BLOCK`.

        ``k_new/v_new [max_slots, Hkv, 1, D]``; ``lengths [max_slots]`` i32
        pre-append; ``alive [max_slots]`` bool; ``page_table [max_slots, P]``.
        """
        r = self.group_size
        slot_in_group = jnp.mod(lengths, r)
        write = (jnp.arange(r)[None, :] == slot_in_group[:, None]) \
            & alive[:, None]
        wmask = write[:, None, :, None]
        k_res = jnp.where(wmask, k_new.astype(self.k_res.dtype), self.k_res)
        v_res = jnp.where(wmask, v_new.astype(self.v_res.dtype), self.v_res)

        new_len = lengths + alive.astype(jnp.int32)
        flush = alive & (jnp.mod(new_len, r) == 0)
        g = jnp.maximum(new_len // r - 1, 0)
        bids = jnp.where(
            flush,
            jnp.take_along_axis(page_table, g[:, None], axis=1)[:, 0],
            SCRATCH_BLOCK)

        c = self.codec
        kc, ks, kz = _encode_scatter(self.k_codes, self.k_scale, self.k_zero,
                                     bids, k_res, c.k)
        vc, vs, vz = _encode_scatter(self.v_codes, self.v_scale, self.v_zero,
                                     bids, v_res, c.v)
        return dataclasses.replace(self, k_codes=kc, k_scale=ks, k_zero=kz,
                                   v_codes=vc, v_scale=vs, v_zero=vz,
                                   k_res=k_res, v_res=v_res)

    def append_tokens(self, k_new: jax.Array, v_new: jax.Array,
                      lengths: jax.Array, counts: jax.Array,
                      page_table: jax.Array) -> "PagedKVPool":
        """Append up to K tokens per slot in one call — the commit half of
        speculative decode. Slot ``s`` appends the first ``counts[s]`` of its
        K candidate tokens; the rest never touch the pool (their writes are
        masked out), so a partial accept IS the rollback of the rejected
        tail.

        Because ``K <= R``, the whole commit crosses at most ONE group
        boundary, so it vectorizes to exactly the cost of a single
        :meth:`append` — one masked multi-token window write plus one
        encode/scatter per side — instead of K unrolled steps. Token j
        lands at window position ``(L + j) % R`` (each position written at
        most once); slots whose window fills flush the **flush-moment**
        window state (old partial group + the tokens that completed it) to
        logical group ``L // R``, everyone else scatters to
        :data:`SCRATCH_BLOCK`. Live pool blocks and residual windows end
        bitwise identical to ``counts[s]`` sequential single-token appends;
        only the scratch block (garbage by contract) differs.

        ``k_new/v_new [max_slots, Hkv, K, D]`` post-rope candidate KV;
        ``lengths [max_slots]`` i32 pre-append; ``counts [max_slots]`` i32
        in ``[0, K]`` (0 = dead slot); ``page_table [max_slots, P]``.
        """
        r = self.group_size
        kk = k_new.shape[2]
        if kk > r:
            raise ValueError(
                f"append_tokens: K ({kk}) must be <= group_size ({r}) "
                f"so at most one group can flush")
        lengths = lengths.astype(jnp.int32)
        counts = counts.astype(jnp.int32)
        base = jnp.mod(lengths, r)                       # [S]
        j = jnp.arange(kk)                               # [K]
        pos = jnp.mod(base[:, None] + j[None, :], r)     # [S, K]
        live = j[None, :] < counts[:, None]              # [S, K]
        # the step that completes the current group, if any slot reaches it
        j_f = r - 1 - base                               # [S]
        flush = j_f < counts

        def scatter(win, toks, mask):
            """Masked multi-token one-hot write into the residual window."""
            onehot = (pos[:, :, None] == jnp.arange(r)[None, None, :]) \
                & mask[:, :, None]                       # [S, K, r]
            oh = onehot[:, None, :, :, None]             # [S, 1, K, r, 1]
            t = toks.astype(win.dtype)[:, :, :, None, :]   # [S, H, K, 1, D]
            upd = jnp.sum(jnp.where(oh, t, jnp.zeros((), win.dtype)), axis=2)
            written = jnp.any(onehot, axis=1)[:, None, :, None]
            return jnp.where(written, upd, win)

        # window state AT the flush moment: only tokens up to j_f written
        # (post-boundary tokens had not been appended yet)
        pre = live & (j[None, :] <= j_f[:, None])
        k_fl = scatter(self.k_res, k_new, pre)
        v_fl = scatter(self.v_res, v_new, pre)
        # final window state: every accepted token written at its position
        k_res = scatter(self.k_res, k_new, live)
        v_res = scatter(self.v_res, v_new, live)

        g = lengths // r       # the logical group the flush completes
        bids = jnp.where(
            flush,
            jnp.take_along_axis(page_table, g[:, None], axis=1)[:, 0],
            SCRATCH_BLOCK)
        c = self.codec
        kc, ks, kz = _encode_scatter(self.k_codes, self.k_scale, self.k_zero,
                                     bids, k_fl, c.k)
        vc, vs, vz = _encode_scatter(self.v_codes, self.v_scale, self.v_zero,
                                     bids, v_fl, c.v)
        return dataclasses.replace(self, k_codes=kc, k_scale=ks, k_zero=kz,
                                   v_codes=vc, v_scale=vs, v_zero=vz,
                                   k_res=k_res, v_res=v_res)

    # ------------------------------------------------- speculative rollback
    def snapshot_spec(self, lengths: jax.Array,
                      page_table: jax.Array) -> dict:
        """Capture everything a ``<= R``-token speculative append can
        disturb, so :meth:`rollback_spec` can make rejected tokens vanish
        **bitwise**. Take it BEFORE :meth:`append_tokens`.

        Appending ``Ka <= R`` tokens from length ``L`` crosses at most ONE
        group boundary, and the only block it can flush is the one backing
        logical group ``L // R`` — so the snapshot is the residual windows
        plus that single block (codes + scales) per slot. Quantized blocks
        cannot recover the bf16 values they were encoded from, which is why
        rollback needs a pre-append copy at all ("unflush/re-own").
        """
        lengths = lengths.astype(jnp.int32)
        g0 = jnp.clip(lengths // self.group_size, 0,
                      page_table.shape[1] - 1)
        bids = jnp.take_along_axis(page_table.astype(jnp.int32),
                                   g0[:, None], axis=1)[:, 0]

        def grab(arr):
            return arr[bids] if arr.ndim > 1 else arr

        return {"bids": bids, "k_res": self.k_res, "v_res": self.v_res,
                "k_codes": grab(self.k_codes), "k_scale": grab(self.k_scale),
                "k_zero": grab(self.k_zero), "v_codes": grab(self.v_codes),
                "v_scale": grab(self.v_scale), "v_zero": grab(self.v_zero)}

    def rollback_tail(self, snap: dict, lengths: jax.Array, keep: jax.Array,
                      appended: jax.Array) -> "PagedKVPool":
        """Bitwise-revert the REJECTED TAIL of a multi-token append: after
        slot ``s`` appended ``appended[s] <= R`` tokens (single-token
        :meth:`append` sub-steps or one :meth:`append_tokens`) from length
        ``lengths[s]``, keep the first ``keep[s]`` and make the rest vanish
        — live blocks and residual windows end bitwise identical to having
        appended only the kept prefix (the tested invariant).

        Token ``j`` of the append landed at window position
        ``(L + j) % R``, so position ``p`` is restored from the snapshot iff
        its token index ``(p - L%R) % R`` falls in ``[keep, appended)`` —
        this truncates the speculative window tail AND, when the rolled-back
        region wrapped past a flush, re-exposes the old partial group the
        wrap overwrote. The group flush fires at token index
        ``j_f = R-1 - L%R``; iff ``j_f`` is itself rejected the snapshot
        block scatters back to its physical id ("unflush"), while a flush in
        the KEPT prefix encoded exactly the serial flush-moment bytes and
        must stand. Slots with nothing to unflush scatter their stale
        snapshot copy to :data:`SCRATCH_BLOCK` (garbage by contract) — no
        per-slot control flow.

        ``lengths [max_slots]`` i32 PRE-append lengths (the ones the
        snapshot was taken at); ``keep/appended [max_slots]`` i32 with
        ``0 <= keep <= appended <= R``.
        """
        r = self.group_size
        lengths = lengths.astype(jnp.int32)
        keep = keep.astype(jnp.int32)
        appended = appended.astype(jnp.int32)
        base = jnp.mod(lengths, r)                        # [S]
        p = jnp.arange(r)[None, :]                        # window positions
        jmap = jnp.mod(p - base[:, None], r)              # token that wrote p
        restore = (jmap >= keep[:, None]) & (jmap < appended[:, None])
        rm = restore[:, None, :, None]                    # [S, 1, r, 1]
        k_res = jnp.where(rm, snap["k_res"], self.k_res)
        v_res = jnp.where(rm, snap["v_res"], self.v_res)

        j_f = r - 1 - base                 # sub-step that flushed, if reached
        unflush = (j_f >= keep) & (j_f < appended)
        bids = jnp.where(unflush, snap["bids"], SCRATCH_BLOCK)

        def put(arr, saved):
            if arr.ndim <= 1:
                return arr
            return arr.at[bids].set(saved)

        return dataclasses.replace(
            self,
            k_codes=put(self.k_codes, snap["k_codes"]),
            k_scale=put(self.k_scale, snap["k_scale"]),
            k_zero=put(self.k_zero, snap["k_zero"]),
            v_codes=put(self.v_codes, snap["v_codes"]),
            v_scale=put(self.v_scale, snap["v_scale"]),
            v_zero=put(self.v_zero, snap["v_zero"]),
            k_res=k_res, v_res=v_res)

    def rollback_spec(self, snap: dict, undo: jax.Array) -> "PagedKVPool":
        """Undo a speculative :meth:`append_tokens` WHOLESALE for the slots
        in ``undo`` — post-rollback state is bitwise identical to never
        having appended. The ``keep = 0, appended = R`` corner of
        :meth:`rollback_tail`: every window position reverts and the
        snapshot block scatters back unconditionally (a no-op rewrite of
        identical bytes when no flush happened).

        ``undo [max_slots]`` bool. Only valid for appends of at most
        ``group_size`` tokens since the snapshot (one flush max — see
        :meth:`snapshot_spec`).
        """
        undo = undo.astype(bool)
        zero = jnp.zeros(undo.shape, jnp.int32)
        return self.rollback_tail(
            snap, zero, zero, jnp.where(undo, self.group_size, 0))

    # ------------------------------------------------------------- dequant
    def gather_dequant(self, page_table: jax.Array, dtype=jnp.bfloat16):
        """Materialize per-slot (K̂, V̂) ``[max_slots, Hkv, P·R, D]`` by
        gathering pool blocks through the page table (XLA reference path;
        the Pallas kernel streams blocks via the same table instead)."""
        c = self.codec

        def side(codes, scale, zero, seg):
            blocks = codes[page_table]                  # [B, P, Hkv, R, cd]
            if seg.quantized:
                s, z = scale[page_table], zero[page_table]
            else:
                s, z = scale, zero
            x = seg.decode(blocks, s, z, dtype)         # [B, P, Hkv, R, D]
            b, p, h, r, d = x.shape
            return x.transpose(0, 2, 1, 3, 4).reshape(b, h, p * r, d)

        k = side(self.k_codes, self.k_scale, self.k_zero, c.k)
        v = side(self.v_codes, self.v_scale, self.v_zero, c.v)
        return k, v

    # --------------------------------------------------------------- sizes
    def _per_shard(self, n_bytes: int, n_shards: int) -> int:
        """Divide a pool-byte total across ``n_shards`` KV-head shards.
        Every byte counter below is proportional to Hkv (codes, scales,
        residual windows and fp tiles all carry the head dim), so a
        head-sharded mesh engine streams EXACTLY total/N bytes per device —
        the "no KV all-gather on the decode path" invariant in a number."""
        if n_shards == 1:
            return n_bytes
        hkv = self.k_res.shape[1]
        if n_shards < 1 or hkv % n_shards:
            raise ValueError(
                f"n_shards ({n_shards}) must divide the pool's KV head "
                f"count ({hkv})")
        return n_bytes // n_shards

    def block_bytes(self, n_shards: int = 1) -> int:
        """Packed bytes of ONE block (codes + scales, K and V); with
        ``n_shards`` > 1, the bytes of one shard's slice of that block."""
        import numpy as np

        total = 0
        for arr in (self.k_codes, self.k_scale, self.k_zero, self.v_codes,
                    self.v_scale, self.v_zero):
            n = int(np.prod(arr.shape)) * arr.dtype.itemsize
            total += n // self.num_blocks if arr.ndim > 1 else 0
        return self._per_shard(total, n_shards)

    def pool_bytes(self) -> int:
        import numpy as np

        total = 0
        for arr in (self.k_codes, self.k_scale, self.k_zero, self.v_codes,
                    self.v_scale, self.v_zero, self.k_res, self.v_res):
            total += int(np.prod(arr.shape)) * arr.dtype.itemsize
        return total

    def decode_stream_bytes(self, lengths, n_shards: int = 1) -> int:
        """Analytic HBM bytes ONE length-aware fused decode launch streams
        for per-slot token counts ``lengths`` (host ints/array): live packed
        blocks (out-of-range grid steps alias an already-resident block and
        DMA nothing, but a fully dead slot still fetches one aliased block
        on its first grid step) plus every slot's residual window. The work-
        proportionality metric reported by ``benchmarks/kernels_micro``.
        ``n_shards`` > 1 gives the PER-DEVICE bytes of a KV-head-sharded
        mesh launch (each shard streams only its own heads' slice)."""
        import numpy as np

        lens = np.asarray(lengths)
        r = self.group_size
        # lengths floor to full groups (the kernel never streams a partial
        # group — the tail lives in the residual window)
        fetched = int(np.sum(np.maximum(lens // r, 1)))
        res_bytes = int(np.prod(self.k_res.shape[1:])) * \
            self.k_res.dtype.itemsize
        return self._per_shard(
            fetched * self.block_bytes() + 2 * len(lens) * res_bytes,
            n_shards)

    def verify_stream_bytes(self, lengths, n_tokens: int,
                            q_tiles: int = 1, n_shards: int = 1) -> int:
        """Analytic HBM bytes ONE fused decode-verify launch streams for
        per-slot committed token counts ``lengths`` and ``n_tokens``
        (= speculate_k + 1) query/window tokens per slot: live packed
        context blocks (same aliasing rules as :meth:`decode_stream_bytes`)
        plus every slot's residual window plus its full-precision
        ``n_tokens``-token candidate K/V tile. The amortization story in
        one number: verifying k+1 tokens re-streams the pool ONCE, where
        k+1 single-token decodes stream it k+1 times — the benchmark
        reports this ratio alongside wall-clock
        (``benchmarks/kernels_micro.run_verify``)."""
        import numpy as np

        lens = np.asarray(lengths)
        r = self.group_size
        fetched = int(np.sum(np.maximum(lens // r, 1)))
        hkv = self.k_res.shape[1]
        res_bytes = int(np.prod(self.k_res.shape[1:])) * \
            self.k_res.dtype.itemsize
        win = hkv * n_tokens * self.head_dim * self.k_res.dtype.itemsize
        return self._per_shard(
            q_tiles * (fetched * self.block_bytes()
                       + 2 * len(lens) * (res_bytes + win)), n_shards)

    def prefill_stream_bytes(self, ctx_lens, chunk: int,
                             q_tiles: int = 1, n_shards: int = 1) -> int:
        """Analytic HBM bytes ONE fused prefill wave streams for per-slot
        context token counts ``ctx_lens`` (host ints/array) and a
        ``chunk``-token wave: live packed context blocks (out-of-range grid
        steps alias an already-resident block and DMA nothing, but a
        zero-context slot still fetches one aliased block on its first
        step) plus every slot's full-precision chunk K/V tile. The mirror
        of :meth:`decode_stream_bytes` for the prefill path, reported by
        ``benchmarks/kernels_micro.run_prefill``.

        ``q_tiles``: the kernel's q-tile count (``C·G / block_q`` — see
        ``repro.kernels.qprefill.pick_block_q``). The context/chunk index
        maps do not depend on the q-tile grid axis, so every q tile
        re-streams the full context and chunk tile; pass the tile count
        whenever the flattened query axis exceeds ``block_q`` (it is 1 for
        ``C·G <= block_q``, the common serving geometry)."""
        import numpy as np

        lens = np.asarray(ctx_lens)
        r = self.group_size
        fetched = int(np.sum(np.maximum(lens // r, 1)))
        hkv = self.k_res.shape[1]
        tile = hkv * chunk * self.head_dim * self.k_res.dtype.itemsize
        return self._per_shard(
            q_tiles * (fetched * self.block_bytes()
                       + 2 * len(lens) * tile), n_shards)


def init_model_pools(cfg, schedule, max_slots: int, num_blocks: int) -> list:
    """Per-attention-layer paged pools following a KVTunerSchedule (mirrors
    ``init_model_cache``). Non-attention layers get ``None``.

    Windowed (local-attention) layers are not paged yet — their ring caches
    are bounded by the window and gain nothing from paging; configs using
    them must serve through the wave engine.
    """
    from repro.configs.base import ATTN_LOCAL

    kinds = cfg.layer_kinds()
    attn_ids = cfg.attention_layers()
    pools: list = []
    for i, kind in enumerate(kinds):
        if i not in attn_ids:
            pools.append(None)
            continue
        if kind == ATTN_LOCAL:
            raise NotImplementedError(
                "paged KV pool does not support windowed local-attention "
                "layers; use the wave engine for this config")
        pair = schedule[attn_ids.index(i)] if schedule is not None else \
            PrecisionPair(16, 16)
        pools.append(PagedKVPool.init(
            num_blocks, max_slots, cfg.num_kv_heads, cfg.head_dim, pair,
            mode=schedule.mode if schedule is not None else MODE_PER_TOKEN,
            group_size=cfg.kv_group_size, dtype=jnp.dtype(cfg.dtype)))
    return pools


class BlockAllocator:
    """Host-side refcounting free-list allocator over physical block ids
    ``1..N-1`` (block 0 is the scratch block). Purely python — allocation
    happens between jitted steps, never inside them.

    Blocks are reference-counted so the prefix cache can share one physical
    block between a cached prefix and any number of live requests (COW
    semantics: shared blocks are only ever read; a request forks by
    allocating fresh blocks past its divergence point). ``alloc`` hands out
    blocks at refcount 1; ``ref`` pins shared blocks; ``release`` decrements
    and returns a block to the free list only when the last reference drops.
    Releasing an unallocated block raises instead of silently corrupting the
    free list (double-free hardening)."""

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, 0, -1))
        self._refs = [0] * num_blocks
        self.high_watermark = 0
        #: fault-injection hook: ``hook(n) -> bool``; True makes this
        #: ``alloc`` report exhaustion (``None``) without taking blocks —
        #: the same signal a genuinely dry pool sends, so every caller's
        #: backpressure path (eviction, preemption, stall) is exercised
        self.fault_hook = None

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def allocated_blocks(self) -> int:
        return self.num_blocks - 1 - len(self._free)

    @property
    def utilization(self) -> float:
        """Allocated fraction of the usable pool (scratch block excluded)."""
        return self.allocated_blocks / max(self.num_blocks - 1, 1)

    def refcount(self, block: int) -> int:
        return self._refs[block]

    def assert_consistent(self) -> None:
        """Leak/corruption check: every usable block is either on the free
        list at refcount 0 or off it at refcount > 0, with no duplicates.
        Cheap enough to run after every serve loop in tests."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("free list holds duplicate block ids")
        if not free.isdisjoint({0}) or any(not 0 < b < self.num_blocks
                                           for b in free):
            raise AssertionError("free list holds out-of-range block ids")
        for b in range(1, self.num_blocks):
            if b in free and self._refs[b] != 0:
                raise AssertionError(
                    f"block {b} is free but has refcount {self._refs[b]}")
            if b not in free and self._refs[b] <= 0:
                raise AssertionError(
                    f"block {b} leaked: refcount {self._refs[b]}, "
                    "not on the free list")

    def alloc(self, n: int) -> list[int] | None:
        """n block ids at refcount 1, or None if the pool can't satisfy."""
        if n > len(self._free):
            return None
        if n == 0:
            return []
        if self.fault_hook is not None and self.fault_hook(n):
            return None
        taken = self._free[-n:][::-1]
        del self._free[len(self._free) - n:]
        for b in taken:
            self._refs[b] = 1
        self.high_watermark = max(self.high_watermark, self.allocated_blocks)
        return taken

    def ref(self, blocks) -> None:
        """Add one reference to each (already-allocated) block."""
        for b in blocks:
            self._check(b)
            if self._refs[b] == 0:
                raise ValueError(f"ref of unallocated block {b}")
            self._refs[b] += 1

    def release(self, blocks) -> None:
        """Drop one reference per block; free those that reach zero."""
        for b in blocks:
            self._check(b)
            if self._refs[b] == 0:
                raise ValueError(f"double free of block {b}")
            self._refs[b] -= 1
            if self._refs[b] == 0:
                self._free.append(b)

    def _check(self, b: int) -> None:
        if not 0 < b < self.num_blocks:
            raise ValueError(f"bad block id {b}")
