"""Fault-tolerant sharded checkpointing with reshard-on-load.

Layout per step:  <dir>/step_<N>/
    manifest.json           tree structure, shapes, dtypes, step metadata
    <leaf-key>.zst|.bin     raw buffers, one file per leaf (zstd-compressed
                            when the optional ``zstandard`` module is present,
                            plain bytes otherwise; restore handles either)
    COMMITTED               written last — partial checkpoints are never loaded

Design points for the 1000-node posture:
* **Atomic commit marker**: a preempted save leaves no COMMITTED file; restore
  picks the latest committed step, so crashes mid-save are harmless.
* **Async save**: `save(..., blocking=False)` snapshots to host memory
  (device_get) then writes on a background thread — training continues.
* **Reshard-on-load**: restore takes target shardings; arrays are device_put
  to the *current* mesh regardless of the mesh at save time (elastic
  up/down-scaling across restarts). In true multi-host deployment each process
  writes its addressable shards; the single-process container writes full
  arrays (the manifest format is identical).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

try:  # optional dependency: compression only, format stays readable without it
    import zstandard as zstd
except ImportError:
    zstd = None

_SEP = "/"


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree, blocking: bool = True,
             extra: dict | None = None) -> None:
        self.wait()  # one in-flight async save at a time
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        treedef = jax.tree_util.tree_structure(tree)

        def _write():
            path = os.path.join(self.dir, f"step_{step:08d}")
            tmp = path + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "extra": extra or {},
                        "treedef": str(treedef), "leaves": {}}
            cctx = zstd.ZstdCompressor(level=3) if zstd is not None else None
            ext = ".zst" if cctx is not None else ".bin"
            for key, arr in host.items():
                fname = key.replace(_SEP, "__") + ext
                manifest["leaves"][key] = {
                    "shape": list(arr.shape), "dtype": str(arr.dtype),
                    "file": fname}
                buf = arr.tobytes()
                with open(os.path.join(tmp, fname), "wb") as f:
                    f.write(cctx.compress(buf) if cctx is not None else buf)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                f.write("ok")
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "COMMITTED")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """Restore into the structure of ``target_tree`` (an abstract or
        concrete pytree). ``shardings``: matching pytree of NamedSharding for
        reshard-on-load; None → host arrays placed by default device order."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        if not os.path.exists(os.path.join(path, "COMMITTED")):
            raise FileNotFoundError(f"no committed checkpoint at step {step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        dctx = zstd.ZstdDecompressor() if zstd is not None else None
        flat_target = _flatten(target_tree)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        out_flat = {}
        for key, leaf in flat_target.items():
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            with open(os.path.join(path, meta["file"]), "rb") as f:
                buf = f.read()
            if meta["file"].endswith(".zst"):
                if dctx is None:
                    raise RuntimeError(
                        "checkpoint was written zstd-compressed but the "
                        "'zstandard' module is not installed")
                buf = dctx.decompress(buf)
            arr = np.frombuffer(buf, dtype=np.dtype(meta["dtype"])) \
                .reshape(meta["shape"]).copy()
            sh = flat_shard.get(key)
            out_flat[key] = jax.device_put(arr, sh) if sh is not None \
                else jax.numpy.asarray(arr)
        # rebuild via the target's treedef
        leaves_paths = jax.tree_util.tree_flatten_with_path(target_tree)[0]
        treedef = jax.tree_util.tree_structure(target_tree)
        ordered = [out_flat[_SEP.join(_path_str(p) for p in path_)]
                   for path_, _ in leaves_paths]
        return jax.tree_util.tree_unflatten(treedef, ordered), manifest["extra"]

    def restore_latest(self, target_tree, shardings=None):
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, target_tree, shardings)
        return step, tree, extra
