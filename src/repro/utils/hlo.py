"""Post-optimization HLO analyzer for roofline terms.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies **once** (verified
empirically — a 10-iteration scan reports 1/10th of the FLOPs), which breaks
any scanned-layers model. This module re-derives totals from
``compiled.as_text()``:

* parses every computation, building a per-computation symbol table
  (op name → shape/dtype) so operand sizes resolve;
* walks the call graph from ENTRY with multipliers: while bodies multiply by
  the **trip count** recovered from the loop condition's compare-against-
  constant; fusions contribute FLOPs but not HBM bytes (their internals live
  in registers/VMEM); conditionals contribute their most expensive branch;
* accumulates: dot FLOPs (2·|out|·contraction), per-collective-kind bytes
  (operand bytes, per the roofline spec), and an HBM-traffic proxy
  (operand+output bytes of schedulable top-level ops).

All sizes are per-device — the HLO is already SPMD-partitioned.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str

    @property
    def out_bytes(self) -> float:
        return _shape_bytes(self.type_str)


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    symbols: dict[str, str]  # op name → type string


@dataclasses.dataclass
class CostReport:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    op_counts: dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    while_trip_counts: dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def merged(self, other: "CostReport", mult: float = 1.0,
               bytes_too: bool = True) -> None:
        self.flops += mult * other.flops
        if bytes_too:
            self.hbm_bytes += mult * other.hbm_bytes
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] += mult * v
        for k, v in other.op_counts.items():
            self.op_counts[k] += int(mult * v)


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        mc = _COMP_RE.match(line)
        if mc and ("->" in line):
            current = Computation(name=mc.group(1), ops=[], symbols={})
            comps[mc.group(1)] = current
            if line.lstrip().startswith("ENTRY"):
                entry_name = mc.group(1)
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        md = _DEF_RE.match(line)
        if md:
            name, type_str, opcode = md.groups()
            current.symbols[name] = type_str
            current.ops.append(Op(name=name, type_str=type_str, opcode=opcode,
                                  line=line.strip()))
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _dot_flops(op: Op, symbols: dict[str, str]) -> float:
    out_elems = 1
    for d in _shape_dims(op.type_str):
        out_elems *= d
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", op.line)
    paren = op.line.split(f"{op.opcode}(", 1)[1]
    operands = _OPERAND_RE.findall(paren.split(")", 1)[0])
    contraction = 1
    if m and operands:
        lhs_type = symbols.get(operands[0], "")
        lhs_dims = _shape_dims(lhs_type)
        if m.group(1):
            for idx in m.group(1).split(","):
                i = int(idx)
                if i < len(lhs_dims):
                    contraction *= lhs_dims[i]
    return 2.0 * out_elems * contraction


def _operand_bytes(op: Op, symbols: dict[str, str]) -> float:
    paren = op.line.split(f"{op.opcode}(", 1)
    if len(paren) < 2:
        return 0.0
    names = _OPERAND_RE.findall(paren[1].split("),", 1)[0])
    return sum(_shape_bytes(symbols.get(n, "")) for n in names)


def _trip_count(cond: Computation) -> int:
    """Loop condition: ROOT compare(..., constant) with direction=LT/LE.
    jax scans lower to 0-based counters stepping by 1; the compare constant is
    the trip count. Fallback: the largest integer constant in the condition."""
    consts = {}
    for op in cond.ops:
        m = _CONST_RE.search(op.line)
        if m and op.opcode == "constant":
            consts[op.name] = int(m.group(1))
    for op in cond.ops:
        if op.opcode == "compare":
            names = _OPERAND_RE.findall(op.line.split("compare(", 1)[1])
            for n in names:
                if n in consts:
                    bump = 1 if "direction=LE" in op.line else 0
                    return max(consts[n] + bump, 1)
    return max(consts.values(), default=1)


def _called_comps(op: Op) -> list[str]:
    out = []
    for attr in ("calls", "body", "to_apply"):
        m = re.search(rf"{attr}=%?([\w.\-]+)", op.line)
        if m:
            out.append(m.group(1))
    m = re.search(r"branch_computations={([^}]*)}", op.line)
    if m:
        out.extend(_OPERAND_RE.findall(m.group(1)))
    for attr in ("true_computation", "false_computation"):
        m = re.search(rf"{attr}=%?([\w.\-]+)", op.line)
        if m:
            out.append(m.group(1))
    return out


def analyze(text: str) -> CostReport:
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        return CostReport()
    memo: dict[tuple[str, bool], CostReport] = {}

    # HBM traffic model: every schedulable op's OUTPUT is written once and
    # read ~once downstream → traffic ≈ 2 × Σ output bytes. This avoids the
    # classic over-count where a fused dynamic-slice inside a scan body lists
    # the full stacked [L, ...] array as an operand every iteration. Ops that
    # produce no real buffer (tuples, parameters, bitcasts) count zero;
    # dynamic-update-slice aliases its big operand and only writes the update
    # region, so it counts the update operand instead of its output.
    # Only these opcodes count as HBM round-trips. The CPU backend leaves
    # elementwise chains unfused; the TPU compiler fuses them into producer
    # fusions, so exp/add/select/... are treated as fused (0 bytes) and the
    # proxy reflects the TPU memory behaviour the roofline targets.
    _BYTES_OPS = {"dot", "convolution", "fusion", "copy", "reduce",
                  "reduce-window", "sort", "scatter", "gather",
                  "dynamic-slice", "concatenate", "pad", "reverse",
                  "transpose", "custom-call", "cholesky", "triangular-solve",
                  "rng", "rng-bit-generator", "select-and-scatter"}

    def comp_cost(name: str, bytes_on: bool) -> CostReport:
        key = (name, bytes_on)
        if key in memo:
            return memo[key]
        memo[key] = CostReport()  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[key]
        rep = CostReport()
        for op in comp.ops:
            rep.op_counts[op.opcode] += 1
            if op.opcode in ("dot", "convolution"):
                rep.flops += _dot_flops(op, comp.symbols)
                if bytes_on:
                    rep.hbm_bytes += 2 * op.out_bytes
            elif any(op.opcode.startswith(c) for c in COLLECTIVES):
                kind = next(c for c in COLLECTIVES if op.opcode.startswith(c))
                ob = _operand_bytes(op, comp.symbols)
                rep.collective_bytes[kind] += ob
                if bytes_on:
                    rep.hbm_bytes += 2 * op.out_bytes
            elif op.opcode == "while":
                cond = re.search(r"condition=%?([\w.\-]+)", op.line)
                body = re.search(r"body=%?([\w.\-]+)", op.line)
                mt = _TRIP_RE.search(op.line)  # XLA's own annotation wins
                if mt:
                    trips = int(mt.group(1))
                else:
                    trips = _trip_count(comps[cond.group(1)]) if cond and \
                        cond.group(1) in comps else 1
                rep.while_trip_counts[op.name] = trips
                if body:
                    rep.merged(comp_cost(body.group(1), bytes_on), mult=trips)
            elif op.opcode == "conditional":
                branches = [comp_cost(c, bytes_on) for c in _called_comps(op)]
                if branches:
                    best = max(branches, key=lambda r: (r.flops, r.hbm_bytes))
                    rep.merged(best, mult=1.0)
            elif op.opcode in ("fusion",):
                for c in _called_comps(op):
                    rep.merged(comp_cost(c, False), mult=1.0, bytes_too=False)
                if bytes_on:
                    rep.hbm_bytes += 2 * op.out_bytes
            elif op.opcode in ("call", "custom-call", "async-start"):
                for c in _called_comps(op):
                    rep.merged(comp_cost(c, bytes_on), mult=1.0)
                if bytes_on:
                    rep.hbm_bytes += 2 * op.out_bytes
            elif op.opcode == "dynamic-update-slice":
                if bytes_on:
                    paren = op.line.split("dynamic-update-slice(", 1)
                    names = _OPERAND_RE.findall(paren[1]) if len(paren) > 1 else []
                    upd = _shape_bytes(comp.symbols.get(names[1], "")) \
                        if len(names) > 1 else op.out_bytes
                    rep.hbm_bytes += 2 * upd
            elif bytes_on and op.opcode in _BYTES_OPS:
                rep.hbm_bytes += 2 * op.out_bytes
        memo[key] = rep
        return rep

    return comp_cost("__entry__", True)


# ---------------------------------------------------------------- roofline
@dataclasses.dataclass(frozen=True)
class Hardware:
    """TPU v5e per-chip constants (the assignment's target)."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s
    hbm_bw: float = 819e9           # B/s
    ici_bw: float = 50e9            # B/s per link (spec formula: × chips)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    collective_bytes: float
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic (perfect-overlap) bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-resource bound that is useful model
        compute: MODEL_FLOPS-time / achieved step time."""
        if not self.model_flops:
            return 0.0
        ideal = self.model_flops / self.flops * self.compute_s \
            if self.flops else 0.0
        return ideal / max(self.step_time_s, 1e-30)


def roofline_terms(report: CostReport, hw: Hardware = Hardware(),
                   model_flops_per_device: float = 0.0) -> Roofline:
    """Terms are per-chip: the report's numbers come from SPMD-partitioned
    (per-device) HLO, so 'chips ×' in the spec formulas is already applied."""
    return Roofline(
        compute_s=report.flops / hw.peak_flops,
        memory_s=report.hbm_bytes / hw.hbm_bw,
        collective_s=report.total_collective_bytes / hw.ici_bw,
        flops=report.flops,
        hbm_bytes=report.hbm_bytes,
        collective_bytes=report.total_collective_bytes,
        model_flops=model_flops_per_device,
    )
