"""Production serving launcher: sharded prefill/decode programs for an
assigned architecture with a KVTuner schedule on the production mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b \
        --shape decode_32k --schedule kvtuner [--multi-pod]
"""
import os

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse

from repro.configs import ARCH_CONFIGS
from repro.configs.base import SHAPES_BY_NAME
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, default_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCH_CONFIGS))
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--schedule", default="kvtuner",
                    choices=["kvtuner", "kv8", "kv4", "kv16"])
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = ARCH_CONFIGS[args.arch]()
    cell = SHAPES_BY_NAME[args.shape]
    assert cell.kind in ("decode", "prefill")
    sched = default_schedule(cfg, args.schedule)
    if sched is not None:
        print(f"schedule: {sched.name} ({sched.equivalent_bits:.2f}-bit, "
              f"mode={sched.mode})")
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    with mesh:
        built = build_cell(cfg, cell, mesh, schedule_profile=args.schedule)
        compiled = built.lower().compile()
        ma = compiled.memory_analysis()
        print(f"compiled {built.name} on {mesh.size} chips")
        print(f"  per-device: args={ma.argument_size_in_bytes/2**30:.3f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.3f}GiB")
    print("serve program ready (attach repro.serving.engine on TPU hosts)")


if __name__ == "__main__":
    main()
