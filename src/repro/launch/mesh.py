"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
touches no jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = (data, model) — 256 chips (one v5e pod).
    Multi-pod: (2, 16, 16) = (pod, data, model) — 512 chips; the pod axis
    composes with data for batch/FSDP sharding (DCN-crossing collectives are
    all-reduce only)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Degenerate 1×1 mesh for CPU smoke tests of the sharded code path."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
