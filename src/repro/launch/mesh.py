"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
touches no jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import os

import jax

_HOST_COUNT_FLAG = "--xla_force_host_platform_device_count"


def force_host_device_count(n: int = 8) -> None:
    """Emulate ``n`` CPU devices by extending ``XLA_FLAGS`` — the knob that
    lets multi-device code paths (sharded paged pool, mesh engine) run on a
    laptop or CI runner. Must be called BEFORE the jax backend initializes;
    this module's no-device-state-at-import contract exists exactly so
    callers (tests/conftest.py, benchmark ``__main__``s) can sequence it.
    No-op when the flag is already set (e.g. CI exports it globally), so the
    environment always wins over the in-process default."""
    flags = os.environ.get("XLA_FLAGS", "")
    if _HOST_COUNT_FLAG in flags:
        return
    os.environ["XLA_FLAGS"] = f"{flags} {_HOST_COUNT_FLAG}={n}".strip()


def make_test_mesh(n_devices: int = 8, axes: tuple = ("data", "model")):
    """Small mesh for CPU multi-device tests: all ``n_devices`` land on the
    LAST axis (``model`` by default — the axis the paged pool shards KV
    heads over), leading axes are size 1. Pair with
    :func:`force_host_device_count` (or the tests/ conftest, or
    ``XLA_FLAGS`` in CI) so the devices exist."""
    if len(axes) < 1:
        raise ValueError("make_test_mesh needs at least one axis name")
    avail = len(jax.devices())
    if avail < n_devices:
        raise RuntimeError(
            f"make_test_mesh({n_devices}) but only {avail} devices are "
            "visible — call launch.mesh.force_host_device_count() before "
            "jax initializes (or export XLA_FLAGS="
            f"{_HOST_COUNT_FLAG}={n_devices})")
    shape = (1,) * (len(axes) - 1) + (n_devices,)
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):  # jax < 0.5: no AxisType knob
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = (data, model) — 256 chips (one v5e pod).
    Multi-pod: (2, 16, 16) = (pod, data, model) — 512 chips; the pod axis
    composes with data for batch/FSDP sharding (DCN-crossing collectives are
    all-reduce only)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Degenerate 1×1 mesh for CPU smoke tests of the sharded code path."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
