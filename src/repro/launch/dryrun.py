import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell on placeholder devices; record memory/cost/roofline terms.

The two lines above MUST precede any other import (jax locks the device count
on first init). Results are written incrementally to
``experiments/dryrun/<arch>__<shape>__<mesh>.json`` so reruns resume.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import time
import traceback


from repro.configs import ARCH_CONFIGS
from repro.configs.base import SHAPES_BY_NAME, supported_shapes
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, model_flops_for
from repro.utils import hlo

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run_cell(arch: str, shape: str, mesh_kind: str,
             schedule_profile: str = "kvtuner", out_dir: str = OUT_DIR,
             force: bool = False, variant: str = "baseline") -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape}__{mesh_kind}__{schedule_profile}"
    if variant != "baseline":
        tag += f"__{variant}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = ARCH_CONFIGS[arch]()
    cell = SHAPES_BY_NAME[shape]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "devices": n_dev, "schedule": schedule_profile,
           "variant": variant, "ok": False}
    t0 = time.time()
    try:
        with mesh:
            built = build_cell(cfg, cell, mesh,
                               schedule_profile=schedule_profile,
                               variant=variant)
            lowered = built.lower()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        report = hlo.analyze(compiled.as_text())
        mf = model_flops_for(cfg, cell, n_dev)
        rl = hlo.roofline_terms(report, model_flops_per_device=mf)

        rec.update(
            ok=True, lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory=dict(
                argument_bytes=getattr(ma, "argument_size_in_bytes", None),
                output_bytes=getattr(ma, "output_size_in_bytes", None),
                temp_bytes=getattr(ma, "temp_size_in_bytes", None),
                alias_bytes=getattr(ma, "alias_size_in_bytes", None),
            ),
            cost_analysis={k: ca.get(k) for k in
                           ("flops", "bytes accessed") if k in ca},
            hlo=dict(
                flops=rl.flops, hbm_bytes=rl.hbm_bytes,
                collective_bytes=dict(report.collective_bytes),
                op_counts={k: v for k, v in sorted(report.op_counts.items())
                           if any(c in k for c in hlo.COLLECTIVES)
                           or k in ("dot", "while", "fusion")},
                while_trips=report.while_trip_counts,
            ),
            roofline=dict(
                compute_s=rl.compute_s, memory_s=rl.memory_s,
                collective_s=rl.collective_s, dominant=rl.dominant,
                model_flops_per_dev=mf,
                useful_flops_ratio=rl.useful_flops_ratio,
                roofline_fraction=rl.roofline_fraction,
                step_time_s=rl.step_time_s,
            ),
        )
    except Exception as e:  # record failures — they are dry-run bugs to fix
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, default=float)
    return rec


def iter_all_cells():
    for arch, cfg_fn in ARCH_CONFIGS.items():
        cfg = cfg_fn()
        for cell in supported_shapes(cfg):
            yield arch, cell.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--schedule", default="kvtuner")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = list(iter_all_cells()) if args.all else [(args.arch, args.shape)]
    meshes = ["single", "multi"] if args.all else [args.mesh]
    for arch, shape in cells:
        for mesh_kind in meshes:
            rec = run_cell(arch, shape, mesh_kind, args.schedule,
                           force=args.force, variant=args.variant)
            status = "OK " if rec.get("ok") else "FAIL"
            rl = rec.get("roofline", {})
            print(f"[{status}] {arch:24s} {shape:12s} {mesh_kind:6s} "
                  f"compile={rec.get('compile_s', '-')}s "
                  f"dominant={rl.get('dominant', '-')} "
                  f"{rec.get('error', '')}", flush=True)


if __name__ == "__main__":
    main()
