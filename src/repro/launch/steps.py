"""Cell builders: (arch × shape × mesh) → lowered step programs.

One builder per shape kind:
  train_*   → train_step(TrainState, batch)        (fwd + bwd + AdamW)
  prefill_* → prefill_step(params, batch)          (fwd + cache quantization)
  decode_* / long_* → serve_step(params, state, token)  (one token, full cache)

Shardings come from repro.distributed.specs; the KVTuner schedule for
inference cells is the paper-faithful mixed profile (sensitive first/last
layers high, bulk K4V2 — the structure KVTuner's search recovers, §6.5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeCell
from repro.core.precision import (MODE_KIVI, KVTunerSchedule,
                                  PrecisionPair)
from repro.distributed.sharding import ShardingRules, make_rules, use_rules
from repro.distributed.specs import SpecBuilder
from repro.models.registry import ModelApi, build_model
from repro.models import transformer as tfm
from repro.training.optimizer import AdamW
from repro.training.trainer import TrainState, make_train_step


def default_schedule(cfg: ModelConfig, profile: str = "kvtuner",
                     mode: str = MODE_KIVI) -> KVTunerSchedule | None:
    """Representative schedules for full-size archs (no calibration data at
    this scale — the *searched* schedules exist for the trained small model).

    kvtuner: first/last attention layers K8V4, bulk K4V2 (≈3.1-bit) — the
             sensitivity structure the paper reports (§6.5, Table 11).
    kv8/kv4/kv16: uniform baselines for §Perf comparisons.
    """
    n = len(cfg.attention_layers())
    if n == 0:
        return None
    if profile == "kvtuner":
        pairs = [PrecisionPair(4, 2)] * n
        for i in (0, n - 1):
            pairs[i] = PrecisionPair(8, 4)
        return KVTunerSchedule(pairs, mode=mode, model_name=cfg.name)
    bits = {"kv8": 8, "kv4": 4, "kv16": 16}[profile]
    return KVTunerSchedule.uniform(n, PrecisionPair(bits, bits), mode=mode,
                                   model_name=cfg.name)


def rules_for(cfg: ModelConfig, mesh, train: bool) -> ShardingRules:
    overrides = {}
    if train:
        overrides["seq"] = ("model",)       # Megatron sequence parallelism
    if cfg.family == "ssm":
        # 125M-class model: no TP — both axes do data parallelism
        overrides["batch"] = ("pod", "data", "model")
        overrides["seq"] = ()
    return make_rules(mesh, overrides)


@dataclasses.dataclass
class BuiltCell:
    name: str
    fn: object            # jitted, unlowered
    abstract_args: tuple  # ShapeDtypeStructs
    api: ModelApi

    def lower(self):
        return self.fn.lower(*self.abstract_args)


def build_cell(cfg: ModelConfig, cell: ShapeCell, mesh,
               schedule_profile: str = "kvtuner",
               fsdp_threshold: int = 128 * 1024 * 1024,
               donate: bool = True, variant: str = "baseline") -> BuiltCell:
    if variant == "opt":
        # §Perf optimized configuration (EXPERIMENTS.md): bf16 P·V, pinned
        # SP↔TP reshard boundaries, seq-parallel flash decode combine
        cfg = dataclasses.replace(cfg, attn_probs_bf16=True,
                                  attn_boundary_hints=True, sp_decode=True,
                                  moe_ep=True)
    api = build_model(cfg)
    rules = rules_for(cfg, mesh, train=(cell.kind == "train"))
    builder = SpecBuilder(rules, fsdp_threshold=fsdp_threshold)
    rng = jax.random.PRNGKey(0)

    if cell.kind == "train":
        opt = AdamW(lr=1e-4)
        abstract_params = jax.eval_shape(api.init, rng)
        abstract_state = jax.eval_shape(
            lambda p: TrainState(params=p, opt=opt.init(p), ef=None),
            abstract_params)
        abstract_batch = api.input_specs(cell)
        state_sh = builder.named(builder.train_state(abstract_state))
        batch_sh = builder.named(builder.batch(abstract_batch))
        grad_sh = None
        if variant == "opt":
            # ZeRO gradient layout: reduce-scatter instead of all-reduce
            grad_sh = builder.named(builder.params(abstract_params,
                                                   force_fsdp=True))
        raw_step = make_train_step(api, opt, grad_shardings=grad_sh)

        def step(state, batch):
            with use_rules(rules):
                return raw_step(state, batch)

        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,) if donate else ())
        return BuiltCell(name=f"{cfg.name}:{cell.name}", fn=fn,
                         abstract_args=(abstract_state, abstract_batch),
                         api=api)

    schedule = default_schedule(cfg, schedule_profile)

    if cell.kind == "prefill":
        abstract_params = jax.eval_shape(api.init, rng)
        abstract_batch = api.input_specs(cell)
        params_sh = builder.named(builder.params(abstract_params))
        batch_sh = builder.named(builder.batch(abstract_batch))

        def pre(params, batch):
            with use_rules(rules):
                if api.cfg.is_encoder:
                    logits, _ = api.forward(params, batch)
                    return logits[:, -1]
                return api.prefill(params, batch, schedule,
                                   capacity=cell.seq_len)

        abstract_out = jax.eval_shape(pre, abstract_params, abstract_batch)
        if api.cfg.is_encoder:
            out_sh = None
        else:
            state_sh = builder.named(builder.decode_state(
                abstract_out[1], long_context=cell.seq_len > 100_000))
            out_sh = (None, state_sh)
        fn = jax.jit(pre, in_shardings=(params_sh, batch_sh),
                     out_shardings=out_sh)
        return BuiltCell(name=f"{cfg.name}:{cell.name}", fn=fn,
                         abstract_args=(abstract_params, abstract_batch),
                         api=api)

    # decode / long-context decode
    abstract_params = jax.eval_shape(api.init, rng)
    params_sh = builder.named(builder.params(abstract_params))
    long = cell.seq_len > 100_000

    def mk_state():
        return tfm.init_decode_state(cfg, schedule, cell.global_batch,
                                     cell.seq_len, extra_groups=4,
                                     filled_to=cell.seq_len)

    abstract_state = jax.eval_shape(mk_state)
    state_sh = builder.named(builder.decode_state(abstract_state,
                                                  long_context=long))
    token = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
    token_sh = NamedSharding(mesh, builder.rules.spec(
        "batch", "none", shape=(cell.global_batch, 1)))

    def serve_step(params, state, tok):
        with use_rules(rules):
            return api.decode_step(params, state, tok)

    fn = jax.jit(serve_step, in_shardings=(params_sh, state_sh, token_sh),
                 out_shardings=(None, state_sh),
                 donate_argnums=(1,) if donate else ())
    return BuiltCell(name=f"{cfg.name}:{cell.name}", fn=fn,
                     abstract_args=(abstract_params, abstract_state, token),
                     api=api)


def model_flops_for(cfg: ModelConfig, cell: ShapeCell, n_devices: int) -> float:
    """Analytic MODEL_FLOPS per device: 6·N_active·tokens (train) or
    2·N_active·tokens (inference); decode processes one token per sequence."""
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        factor = 6.0
    elif cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        factor = 2.0
    else:
        tokens = cell.global_batch
        factor = 2.0
    return factor * n_active * tokens / n_devices
