"""Roofline report: aggregates experiments/dryrun/*.json into the
EXPERIMENTS.md §Roofline table and picks the hillclimb candidates.

    PYTHONPATH=src python -m repro.launch.roofline [--mesh single] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_ADVICE = {
    "compute": "raise MXU utilization: larger fused matmul tiles / fewer "
               "remat recomputes (useful-FLOPs ratio is the lever)",
    "memory": "cut HBM traffic: bf16 (not f32) attention intermediates, "
              "fused flash kernel so scores never round-trip, lower KV bits",
    "collective": "cut resharding: align layer in/out shardings (SP boundary), "
                  "overlap collectives with compute, shrink KV all-gathers "
                  "via seq-parallel softmax combine",
}


def load(mesh: str, schedule: str = "kvtuner") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(OUT_DIR, f"*__{mesh}__{schedule}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 0.1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}µs"


def table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "6ND/HLO | roofline-frac | per-dev args+temp |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED: "
                         f"{r.get('error', '?')[:60]} | | | | | | |")
            continue
        rl = r["roofline"]
        mem = r.get("memory") or {}
        args = (mem.get("argument_bytes") or 0) / 2 ** 30
        temp = (mem.get("temp_bytes") or 0) / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rl['compute_s'])} | "
            f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
            f"**{rl['dominant']}** | {rl['useful_flops_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.3f} | {args:.1f}+{temp:.1f} GiB |")
    return "\n".join(lines)


def advice(recs: list[dict]) -> str:
    out = []
    for r in recs:
        if not r.get("ok"):
            continue
        rl = r["roofline"]
        out.append(f"- **{r['arch']} × {r['shape']}**: {rl['dominant']}-bound "
                   f"→ {_ADVICE[rl['dominant']]}")
    return "\n".join(out)


def pick_hillclimb(recs: list[dict]) -> list[dict]:
    ok = [r for r in recs if r.get("ok")]
    if not ok:
        return []
    worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"] /
               max(r["roofline"]["step_time_s"], 1e-30))
    decodes = [r for r in ok if "decode" in r["shape"] or "long" in r["shape"]]
    rep = max(decodes or ok, key=lambda r: r["roofline"]["memory_s"])
    picks, seen = [], set()
    for tag, r in (("worst-roofline-fraction", worst),
                   ("most-collective-bound", coll),
                   ("paper-representative-decode", rep)):
        key = (r["arch"], r["shape"])
        if key not in seen:
            seen.add(key)
            picks.append({"why": tag, **{k: r[k] for k in
                                         ("arch", "shape", "roofline")}})
    return picks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--schedule", default="kvtuner")
    args = ap.parse_args()
    recs = load(args.mesh, args.schedule)
    n_ok = sum(1 for r in recs if r.get("ok"))
    print(f"## Roofline — {args.mesh} mesh ({n_ok}/{len(recs)} cells OK)\n")
    print(table(recs))
    print("\n### Dominant-term advice\n")
    print(advice(recs))
    print("\n### Hillclimb candidates\n")
    for p in pick_hillclimb(recs):
        print(f"- {p['why']}: {p['arch']} × {p['shape']} "
              f"(dominant={p['roofline']['dominant']}, "
              f"frac={p['roofline']['roofline_fraction']:.3f})")


if __name__ == "__main__":
    main()
