"""Production training launcher: build the sharded train step for an assigned
architecture on the production mesh and run it (on TPU) or dry-run it (here).

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --shape train_4k [--multi-pod] [--dry-run]

On a real cluster this process runs per-host under `jax.distributed`
initialization; the container executes the same code against placeholder
devices (--dry-run lowers + compiles without allocating).
"""
import os

if __name__ == "__main__" and "--real" not in os.sys.argv:
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse


from repro.configs import ARCH_CONFIGS
from repro.configs.base import SHAPES_BY_NAME
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCH_CONFIGS))
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dry-run", action="store_true", default=True)
    ap.add_argument("--real", action="store_true",
                    help="run on actual devices (TPU cluster)")
    args = ap.parse_args()

    cfg = ARCH_CONFIGS[args.arch]()
    cell = SHAPES_BY_NAME[args.shape]
    assert cell.kind == "train", "use launch.serve for inference shapes"
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    print(f"mesh: {dict(mesh.shape)} ({mesh.size} chips)")

    with mesh:
        built = build_cell(cfg, cell, mesh)
        lowered = built.lower()
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        print(f"compiled {built.name}")
        print(f"  per-device memory: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB")
        if args.real:
            # On TPU: initialize real state via jit-sharded init, then loop
            # with the fault-tolerant Trainer (repro.training.trainer).
            raise SystemExit("real-device training requires a TPU cluster; "
                             "this container is CPU-only")
    print("dry-run OK")


if __name__ == "__main__":
    main()
