"""Mamba (S6) block for the jamba hybrid — selective scan in chunked-remat
form (TPU adaptation: sequential CUDA scan → chunked lax.scan; the inner-dim
axis shards over ``model`` since channels are independent in the recurrence).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_hint
from repro.models import common


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MambaState:
    """Decode-time recurrent state: constant memory wrt sequence length."""

    ssm: jax.Array   # [B, d_inner, N] f32
    conv: jax.Array  # [B, d_conv-1, d_inner] last inputs for the causal conv


def init_mamba(rng, cfg) -> dict:
    dt = common.dtype_of(cfg)
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    r = cfg.mamba_dt_rank
    ks = common.split_keys(rng, 6)
    # S4D-real initialization for A; dt bias so softplus(dt) spans [1e-3, 0.1].
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_init = jnp.exp(jax.random.uniform(ks[0], (di,)) *
                      (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))
    return {
        "in_proj": common.dense_init(ks[1], d, 2 * di, dt),
        "conv_w": 0.1 * jax.random.normal(ks[2], (cfg.mamba_d_conv, di), jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": common.dense_init(ks[3], di, r + 2 * n, dt),
        "dt_proj": common.dense_init(ks[4], r, di, jnp.float32, scale=r ** -0.5),
        "dt_bias": dt_bias,
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": common.dense_init(ks[5], di, d, dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: jax.Array | None = None):
    """Depthwise causal conv over S via explicit shifted taps (kernel ≤ 4).

    x [B,S,di]; prev [B, K-1, di] decode context. Returns (y, new_prev)."""
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    ext = jnp.concatenate([prev, x], axis=1)  # [B, S+K-1, di]
    y = sum(ext[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_prev = ext[:, -(k - 1):]
    return y + b.astype(y.dtype), new_prev


def _ssm_params(params, xc, cfg):
    """xc [B,S,di] → (dt [B,S,di], B [B,S,N], C [B,S,N]) in f32."""
    n, r = cfg.mamba_d_state, cfg.mamba_dt_rank
    proj = (xc @ params["x_proj"]).astype(jnp.float32)
    dt_r, bc = proj[..., :r], proj[..., r:]
    bmat, cmat = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(dt_r @ params["dt_proj"] + params["dt_bias"])
    return dt, bmat, cmat


def _scan_chunked(dt, xc, bmat, cmat, a, init_state, chunk: int, remat: bool):
    """h_t = exp(dt_t A) h_{t-1} + (dt_t x_t) B_t ; y_t = Σ_n C_t[n] h_t[:, n].

    dt/xc: [B,S,di]; bmat/cmat: [B,S,N]; a: [di,N]. The [B,·,di,N] discretized
    operands are formed **inside** each rematerialized chunk — materializing
    them over the full sequence is O(B·S·di·N) and was the dominant memory
    term at train_4k (caught by the dry-run memory analysis)."""
    b, s, di = dt.shape
    c = min(chunk, s)
    while s % c:
        c -= 1
    nc = s // c

    def inner(h, xs):
        dtt, xct, bt, ct = xs  # [B,di], [B,di], [B,N], [B,N]
        a_bar = jnp.exp(dtt[..., None] * a)
        bx = (dtt * xct)[..., None] * bt[:, None, :]
        h = a_bar * h + bx
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    def outer(h, xs):
        return jax.lax.scan(inner, h, xs)

    if remat and nc > 1:
        outer = jax.checkpoint(outer)

    def to_chunks(x):
        return x.reshape(b, nc, c, *x.shape[2:]).transpose(
            1, 2, 0, *range(3, x.ndim + 1))

    xs = tuple(map(to_chunks, (dt, xc, bmat, cmat)))
    h, ys = jax.lax.scan(outer, init_state, xs)  # ys [nc, c, B, di]
    y = ys.transpose(2, 0, 1, 3).reshape(b, s, di)
    return y, h


def apply_mamba(params, cfg, x, state: MambaState | None = None,
                chunk: int = 128):
    """x [B,S,D] → (y [B,S,D], new_state). Full-sequence (train/prefill) when
    state covers it; decode passes S=1 with a carried state."""
    b, s, d = x.shape
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    xz = x @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = shard_hint(x_in, "batch", "seq", "mamba_inner")

    prev = state.conv if state is not None else None
    xc, new_conv = _causal_conv(x_in, params["conv_w"], params["conv_b"], prev)
    xc = jax.nn.silu(xc)

    dt, bmat, cmat = _ssm_params(params, xc, cfg)
    a = -jnp.exp(params["A_log"])                       # [di, N]

    h0 = state.ssm if state is not None else jnp.zeros((b, di, n), jnp.float32)
    y, h = _scan_chunked(dt, xc.astype(jnp.float32), bmat, cmat, a, h0, chunk,
                         cfg.remat)
    y = y + params["D"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    out = y @ params["out_proj"]
    return out, MambaState(ssm=h, conv=new_conv)


def init_mamba_state(cfg, batch: int) -> MambaState:
    di = cfg.mamba_expand * cfg.d_model
    return MambaState(
        ssm=jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.mamba_d_conv - 1, di), common.dtype_of(cfg)))
