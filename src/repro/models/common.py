"""Shared pure-JAX building blocks: norms, RoPE, MLPs, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------- init
def dense_init(rng, in_dim: int, out_dim: int, dtype, scale: float = 0.02):
    return (scale * jax.random.truncated_normal(
        rng, -2.0, 2.0, (in_dim, out_dim), jnp.float32)).astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype, scale: float = 0.02):
    return (scale * jax.random.truncated_normal(
        rng, -2.0, 2.0, (vocab, dim), jnp.float32)).astype(dtype)


def split_keys(rng, n: int):
    return list(jax.random.split(rng, n))


# --------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]  # add head axis
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- mlp
def init_mlp(rng, d_model: int, d_ff: int, act: str, dtype):
    ks = split_keys(rng, 3)
    if act == "silu":  # swiglu: gate, up, down
        return {"w_gate": dense_init(ks[0], d_model, d_ff, dtype),
                "w_up": dense_init(ks[1], d_model, d_ff, dtype),
                "w_down": dense_init(ks[2], d_ff, d_model, dtype)}
    return {"w_up": dense_init(ks[0], d_model, d_ff, dtype),
            "w_down": dense_init(ks[1], d_ff, d_model, dtype)}


def apply_mlp(params: dict, x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]


def mlp_kind(params: dict) -> str:
    return "silu" if "w_gate" in params else "gelu"


# -------------------------------------------------------------------- losses
def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: jax.Array | None = None) -> jax.Array:
    """Mean token NLL in f32; labels [..., S] int32; mask 1=count."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
