"""Public model API: build a ModelApi from a ModelConfig, and input_specs
(ShapeDtypeStruct stand-ins) for every (arch × shape) dry-run cell."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.core.precision import KVTunerSchedule
from repro.models import transformer as tfm


@dataclasses.dataclass
class ModelApi:
    cfg: ModelConfig

    def init(self, rng):
        return tfm.init_params(self.cfg, rng)

    def forward(self, params, batch, **kw):
        return tfm.forward(params, self.cfg, batch, **kw)

    def train_loss(self, params, batch, rng=None):
        return tfm.train_loss(params, self.cfg, batch, rng)

    def prefill(self, params, batch, schedule=None, **kw):
        return tfm.prefill(params, self.cfg, batch, schedule, **kw)

    def decode_step(self, params, state, token, **kw):
        return tfm.decode_step(params, self.cfg, state, token, **kw)

    def init_decode_state(self, schedule, batch, capacity, **kw):
        return tfm.init_decode_state(self.cfg, schedule, batch, capacity, **kw)

    # ------------------------------------------------- paged / continuous
    def init_paged_state(self, schedule, max_slots, num_blocks, max_pages):
        return tfm.init_paged_state(self.cfg, schedule, max_slots, num_blocks,
                                    max_pages)

    def paged_adopt(self, state, caches, slot, pages, prompt_len):
        return tfm.paged_adopt(self.cfg, state, caches, slot, pages,
                               prompt_len)

    def prefill_paged(self, params, state, tokens, slot, start, *, chunk,
                      use_pallas=False):
        return tfm.prefill_paged(params, self.cfg, state, tokens, slot,
                                 start, chunk=chunk, use_pallas=use_pallas)

    def prefill_paged_wave(self, params, state, tokens, ctx_lens, chunk_lens,
                           *, use_pallas=False):
        return tfm.prefill_paged_wave(params, self.cfg, state, tokens,
                                      ctx_lens, chunk_lens,
                                      use_pallas=use_pallas)

    def paged_decode_step(self, params, state, token, alive, **kw):
        return tfm.paged_decode_step(params, self.cfg, state, token, alive,
                                     **kw)

    def paged_decode_loop(self, params, state, token, alive, remaining,
                          eos_ids, rng, **kw):
        return tfm.paged_decode_loop(params, self.cfg, state, token, alive,
                                     remaining, eos_ids, rng, **kw)

    def paged_spec_step(self, params, state, tokens, drafts, n_draft, alive,
                        remaining, eos_ids, **kw):
        return tfm.paged_spec_step(params, self.cfg, state, tokens, drafts,
                                   n_draft, alive, remaining, eos_ids, **kw)

    # ------------------------------------------------------------ dry-run
    def input_specs(self, cell: ShapeCell) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this cell.
        No device allocation — feeds jit(...).lower() directly."""
        cfg = self.cfg
        b, s = cell.global_batch, cell.seq_len
        i32 = jnp.int32

        def sds(shape, dtype=i32):
            return jax.ShapeDtypeStruct(shape, dtype)

        if cfg.is_encoder:
            batch = {"frames": sds((b, s, cfg.frontend_dim), jnp.bfloat16)}
            if cell.kind == "train":
                batch["mask"] = sds((b, s), jnp.bool_)
                batch["targets"] = sds((b, s), i32)
            return batch
        if cfg.family == "vlm":
            s_img = min(cfg.image_tokens, s // 2)
            if cell.kind == "decode":
                return {"token": sds((b, 1), i32)}
            return {"tokens": sds((b, s - s_img), i32),
                    "patch_embeds": sds((b, s_img, cfg.vision_dim), jnp.bfloat16)}
        if cell.kind == "decode":
            return {"token": sds((b, 1), i32)}
        return {"tokens": sds((b, s), i32)}

    def decode_state_specs(self, cell: ShapeCell,
                           schedule: KVTunerSchedule | None = None):
        """ShapeDtypeStructs for the decode-state pytree at this cell (cache
        holding `seq_len` tokens). Uses eval_shape → no allocation."""
        fn = partial(tfm.init_decode_state, self.cfg, schedule,
                     cell.global_batch, cell.seq_len, 4, cell.seq_len)
        return jax.eval_shape(fn)


def build_model(cfg: ModelConfig) -> ModelApi:
    return ModelApi(cfg=cfg)
