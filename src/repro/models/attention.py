"""GQA attention: chunked full-sequence path (train/prefill) and quantized-KV
decode path, with fake-quant hooks for the KVTuner sensitivity/search loop.

The full-sequence path chunks queries (flash-style, XLA scan) so [S, S] score
matrices are never materialized — required for the 32k prefill cells. The
decode path consumes a ``LayerKVCache`` (packed mixed-precision segments).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.cache.kvcache import LayerKVCache
from repro.core import quant
from repro.core.precision import MODE_PER_TOKEN
from repro.models import common

NEG_INF = -2.0 ** 30  # large-negative in f32; avoids NaN from (-inf) - (-inf)


def init_attention(rng, cfg) -> dict:
    dt = common.dtype_of(cfg)
    ks = common.split_keys(rng, 4)
    d, hd = cfg.d_model, cfg.head_dim
    p = {
        "wq": common.dense_init(ks[0], d, cfg.num_heads * hd, dt),
        "wk": common.dense_init(ks[1], d, cfg.num_kv_heads * hd, dt),
        "wv": common.dense_init(ks[2], d, cfg.num_kv_heads * hd, dt),
        "wo": common.dense_init(ks[3], cfg.num_heads * hd, d, dt),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


def qkv(params, cfg, x, positions, theta):
    """x [B,S,D] → q [B,S,H,hd], k/v [B,S,Hkv,hd] with RoPE applied.

    ``cfg.attn_boundary_hints`` pins the SP↔TP reshard to exactly one
    all-gather(seq)+head-shard transition per layer (Megatron-SP boundary)
    instead of letting GSPMD pick per-op reshards (§Perf)."""
    from repro.distributed.sharding import shard_hint

    b, s, _ = x.shape
    hd = cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = (x @ params["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = (x @ params["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    if getattr(cfg, "attn_boundary_hints", False):
        q = shard_hint(q, "batch", "none", "heads", "none")
        k = shard_hint(k, "batch", "none", "kv_heads", "none")
        v = shard_hint(v, "batch", "none", "kv_heads", "none")
    if cfg.use_qk_norm:
        q = common.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, params["k_norm"], cfg.norm_eps)
    if theta:
        q = common.apply_rope(q, positions, theta)
        k = common.apply_rope(k, positions, theta)
    return q, k, v


def _scores(q, k, cfg):
    """q [B,Sq,H,hd] × k [B,Sk,Hkv,hd] → [B,H,Sq,Sk] (GQA via reshape).

    Head counts derive from the operand shapes, not cfg: inside a
    head-sharded shard_map body q/k carry the LOCAL head slice while cfg
    still describes the global model (q_per_kv is shard-invariant)."""
    b, sq, h, hd = q.shape
    g = h // k.shape[2]
    qg = q.reshape(b, sq, k.shape[2], g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32)
    return s.reshape(b, h, sq, k.shape[1]) / jnp.sqrt(hd).astype(jnp.float32)


def _weighted_v(probs, v, cfg):
    """probs [B,H,Sq,Sk] × v [B,Sk,Hkv,hd] → [B,Sq,H,hd].

    With ``cfg.attn_probs_bf16`` the probabilities are cast to the value dtype
    before the P·V matmul (f32 accumulation via preferred_element_type) — the
    §Perf change that keeps the chunk-scan carries/cotangents in bf16 instead
    of f32, halving attention HBM traffic and reshard collective bytes.
    """
    b, h, sq, sk = probs.shape
    g = h // v.shape[2]       # shape-derived: shard-safe (see _scores)
    pg = probs.reshape(b, v.shape[2], g, sq, sk)
    if getattr(cfg, "attn_probs_bf16", False):
        pg = pg.astype(v.dtype)
        o = jnp.einsum("bkgqs,bskh->bqkgh", pg, v,
                       preferred_element_type=jnp.float32).astype(v.dtype)
    else:
        o = jnp.einsum("bkgqs,bskh->bqkgh", pg, v.astype(jnp.float32))
    return o.reshape(b, sq, h, v.shape[-1])


def _mask_bias(q_pos, k_pos, kind: str, window: int) -> jax.Array:
    """[Sq, Sk] additive f32 bias. kind: causal | local | bidir."""
    if kind == "bidir":
        return jnp.zeros((q_pos.shape[0], k_pos.shape[0]), jnp.float32)
    allowed = k_pos[None, :] <= q_pos[:, None]
    if kind == "local" and window:
        allowed &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(allowed, 0.0, NEG_INF)


def full_attention(q, k, v, cfg, kind: str = "causal", window: int = 0,
                   q_positions=None, k_positions=None):
    """Chunked softmax(QKᵀ)V over the full sequence.

    Queries are processed in chunks of ``cfg.q_chunk`` via lax.scan with remat,
    keeping peak score memory at [B, H, chunk, Sk].
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    if q_positions is None:
        q_positions = jnp.arange(sq)
    if k_positions is None:
        k_positions = jnp.arange(sk)
    chunk = min(cfg.q_chunk, sq)
    if sq % chunk:
        chunk = sq  # fallback: odd sizes run unchunked

    def one_chunk(qc, qpos):
        bias = _mask_bias(qpos, k_positions, kind, window)
        s = _scores(qc, k, cfg) + bias
        p = jax.nn.softmax(s, axis=-1)
        return _weighted_v(p, v, cfg).astype(q.dtype)

    if chunk == sq:
        return one_chunk(q, q_positions)

    n = sq // chunk
    qs = q.reshape(b, n, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    ps = q_positions.reshape(n, chunk)
    body = jax.checkpoint(lambda carry, xs: (carry, one_chunk(*xs))) \
        if cfg.remat else (lambda carry, xs: (carry, one_chunk(*xs)))
    _, out = jax.lax.scan(body, (), (qs, ps))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


# ---------------------------------------------------------------- simulation
def sim_quant_kv(k, v, k_bits, v_bits, mode: str, group_size: int):
    """Fake-quantize K/V ([B,S,H,hd] layout) with traced bits — the offline
    calibration path (paper Appendix B: quantize+dequantize, no packing).
    quant.py expects [..., S, D]; transpose head/seq around the call."""
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    k_hat, v_hat = quant.fake_quant_kv_dynamic(kt, vt, k_bits, v_bits, mode,
                                               group_size)
    return k_hat.transpose(0, 2, 1, 3), v_hat.transpose(0, 2, 1, 3)


# ------------------------------------------------- seq-parallel decode (§Perf)
def _sp_decode_main(qg, cache: LayerKVCache, rules):
    """Sequence-parallel flash decode over the sharded main segment.

    Beyond-paper optimization: with the KV cache sequence-sharded, the naive
    lowering all-gathers the dequantized KV every layer (O(S·D) bytes on the
    ICI). Here each shard attends to its local packed block and only the
    per-query softmax statistics (o, m, l) — O(B·H·D) bytes — cross the
    network (psum/pmax combine, exactly ref.softmax_merge's algebra).

    qg [B, Hkv, G, D] (replicated over the seq axes). Returns (o, m, l)
    un-normalized partials, replicated, ready to merge with the residual.
    """
    try:
        from jax import shard_map
    except ImportError:  # jax < 0.5
        from jax.experimental.shard_map import shard_map

    mesh = rules.mesh
    b, hkv, g, d = qg.shape
    s_cap = cache.s_cap
    codes_spec = rules.spec("batch", "none", "kv_seq", "none",
                            shape=cache.k_codes.shape)
    seq_axes = codes_spec[2]
    if seq_axes is None:
        return None  # cache sequence not sharded → sp is a no-op
    seq_axes_t = seq_axes if isinstance(seq_axes, tuple) else (seq_axes,)
    n_sh = 1
    for a in seq_axes_t:
        n_sh *= mesh.shape[a]
    for arr in (cache.k_scale, cache.k_zero, cache.v_scale, cache.v_zero):
        if arr.ndim == 5 and arr.shape[2] % n_sh:
            return None  # group count not shardable → fall back to XLA path
    if s_cap % n_sh:
        return None
    batch_spec = rules.spec("batch", shape=(b,))[0]

    def scale_spec(arr, mode_is_channel):
        if arr.ndim != 5:
            return jax.sharding.PartitionSpec()
        return jax.sharding.PartitionSpec(batch_spec, None, seq_axes, None, None)

    k_mode, v_mode = _kv_modes_for(cache)
    P = jax.sharding.PartitionSpec
    in_specs = (
        P(batch_spec, None, None, None),                       # qg
        P(batch_spec, None, seq_axes, None),                   # k_codes
        scale_spec(cache.k_scale, k_mode), scale_spec(cache.k_zero, k_mode),
        P(batch_spec, None, seq_axes, None),                   # v_codes
        scale_spec(cache.v_scale, v_mode), scale_spec(cache.v_zero, v_mode),
        P(),                                                   # length
    )
    out_specs = (P(batch_spec, None, None, None),
                 P(batch_spec, None, None),
                 P(batch_spec, None, None))

    n_shards = 1
    for a in seq_axes_t:
        n_shards *= mesh.shape[a]
    s_local = s_cap // n_shards
    r = cache.group_size

    def local(qg_l, kc, ks, kz, vc, vs, vz, length):
        shard_ix = jax.lax.axis_index(seq_axes_t)
        k = _deq_segment(kc, ks, kz, cache.k_bits, k_mode, r, cache.head_dim)
        v = _deq_segment(vc, vs, vz, cache.v_bits, v_mode, r, cache.head_dim)
        scores = jnp.einsum("bhgd,bhsd->bhgs", qg_l.astype(jnp.float32), k) \
            / jnp.sqrt(float(cache.head_dim))
        n_main = jnp.minimum(length // r * r, s_cap)
        pos = shard_ix * s_local + jnp.arange(s_local)
        valid = (pos < n_main)[None, None, None, :]
        scores = jnp.where(valid, scores, NEG_INF)
        m_l = jnp.max(scores, axis=-1)
        p = jnp.where(valid, jnp.exp(scores - m_l[..., None]), 0.0)
        l_l = jnp.sum(p, axis=-1)
        o_l = jnp.einsum("bhgs,bhsd->bhgd", p, v)
        # flash combine across sequence shards: O(B·H·D) on the wire
        m_g = jax.lax.pmax(m_l, seq_axes_t)
        corr = jnp.exp(m_l - m_g)
        l_g = jax.lax.psum(l_l * corr, seq_axes_t)
        o_g = jax.lax.psum(o_l * corr[..., None], seq_axes_t)
        return o_g, m_g, l_g

    try:
        f = shard_map(local, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_vma=False)
    except TypeError:  # jax < 0.5 spells it check_rep
        f = shard_map(local, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)
    return f(qg, cache.k_codes, cache.k_scale, cache.k_zero, cache.v_codes,
             cache.v_scale, cache.v_zero, cache.length)


def _kv_modes_for(cache: LayerKVCache):
    from repro.cache.kvcache import _kv_modes
    return _kv_modes(cache.mode)


def _sp_feasible(cfg, cache: LayerKVCache) -> bool:
    """sp_decode preconditions: active rules, seq-sharded cache, shardable
    group counts (divisibility is checked here; infeasible → XLA path)."""
    from repro.distributed.sharding import active_rules

    rules = active_rules()
    if rules is None:
        return False
    spec = rules.spec("batch", "none", "kv_seq", "none",
                      shape=cache.k_codes.shape)
    seq_axes = spec[2]
    if seq_axes is None:
        return False
    axes = seq_axes if isinstance(seq_axes, tuple) else (seq_axes,)
    n_sh = 1
    for a in axes:
        n_sh *= rules.mesh.shape[a]
    if cache.s_cap % n_sh:
        return False
    for arr in (cache.k_scale, cache.k_zero, cache.v_scale, cache.v_zero):
        if arr.ndim == 5 and arr.shape[2] % n_sh:
            return False
    return True


def _deq_segment(codes, scale, zero, bits, mode, group_size, d):
    """Segment dequant for shard_map bodies — delegates to the shared codec."""
    from repro.cache.codec import SegmentCodec

    return SegmentCodec(bits, mode, group_size, d).decode(
        codes, scale, zero, jnp.float32)


# -------------------------------------------------------------------- decode
def decode_attention(params, cfg, x, cache: LayerKVCache, pos, kind: str,
                     window: int, theta: float, use_pallas: bool = False):
    """One-token decode: q from x [B,1,D] against the quantized cache.

    Returns (attn_out [B,1,D], new_cache). The XLA path materializes the
    dequantized cache; the Pallas path (TPU target) streams packed blocks
    (repro.kernels.qdecode) — selected by ``use_pallas``.
    """
    b = x.shape[0]
    hd = cfg.head_dim
    positions = jnp.full((b, 1), pos, jnp.int32) if jnp.ndim(pos) == 0 else pos
    q, k_new, v_new = qkv(params, cfg, x, positions, theta)
    new_cache = cache.append(k_new.transpose(0, 2, 1, 3), v_new.transpose(0, 2, 1, 3))

    if use_pallas:
        from repro.kernels import ops as kops
        out = kops.qdecode_attention(q, new_cache, positions, kind, window)
    elif getattr(cfg, "sp_decode", False) and kind != "local" \
            and not cache.window and _sp_feasible(cfg, new_cache):
        from repro.distributed.sharding import active_rules
        from repro.kernels import ref as kref

        rules = active_rules()
        qg = q.reshape(b, cfg.num_kv_heads, cfg.q_per_kv, hd)
        o_m, m_m, l_m = _sp_decode_main(qg, new_cache, rules)
        # residual window: tiny, replicated, plain partial softmax
        from repro.kernels.ops import _residual_partial
        r = new_cache.group_size
        n_res = new_cache.length - new_cache.length // r * r
        res = _residual_partial(qg, new_cache.k_res, new_cache.v_res, n_res)
        out = kref.softmax_merge([(o_m, m_m, l_m), res])
        out = out.reshape(b, 1, cfg.num_heads, hd).astype(x.dtype)
    else:
        k_all, v_all, valid = new_cache.dequant(dtype=x.dtype)  # [B,Hkv,S',D]
        k_pos = new_cache.token_positions()
        q_pos = positions[:, 0]  # [B]
        allowed = valid[None, :] & (k_pos[None, :] <= q_pos[:, None])
        if kind == "local" and window:
            allowed &= (q_pos[:, None] - k_pos[None, :]) < window
        bias = jnp.where(allowed, 0.0, NEG_INF)[:, None, None, :]  # [B,1,1,S']
        s = _scores(q, k_all.transpose(0, 2, 1, 3), cfg) + bias
        p = jax.nn.softmax(s, axis=-1)
        out = _weighted_v(p, v_all.transpose(0, 2, 1, 3), cfg).astype(x.dtype)

    y = out.reshape(b, 1, cfg.num_heads * hd) @ params["wo"]
    return y, new_cache


# ------------------------------------------------------------- paged decode
def _concrete_live_pages(lengths, r: int) -> int | None:
    """Batch max live page count when ``lengths`` is concrete (eager calls,
    benchmarks, tests) — lets reference paths gather only the live prefix of
    the page table instead of its full pool-capacity width. Returns None
    under tracing (jitted steps compile once for any length, so the gather
    width must stay static there)."""
    import numpy as np

    try:
        lens = np.asarray(lengths)
    except Exception:  # TracerArrayConversionError and friends
        return None
    if lens.size == 0:
        return 0
    return int(lens.max() // r)


# --------------------------------------------- KV-head-sharded paged attend
def _paged_head_shard(pool):
    """(rules, mesh_axis) when ambient sharding rules shard the paged pool
    by KV head — every pool array (packed codes, scales/zeros, bf16
    residual windows) carries Hkv at dim 1, so one axis name covers the
    whole pytree. None → single-device path (no rules active, KV heads not
    divisible by the axis, or multi-axis kv_heads rules, which the gather
    tile order does not support)."""
    from repro.distributed.sharding import active_rules

    rules = active_rules()
    if rules is None:
        return None
    ax = rules.axes("kv_heads", pool.k_res.shape[1])
    if ax is None or isinstance(ax, tuple):
        return None
    return rules, ax


def _head_sharded_call(core, rules, ax, q, pool, extras, extra_specs):
    """Run ``core(q_local, pool_local, *extras)`` under shard_map with q
    (dim 2 = query heads) and every pool array (dim 1 = KV heads) split
    over mesh axis ``ax``. GQA lays q heads out KV-major (h = kv·g + gi),
    so the contiguous per-device head slice is exactly the local KV heads'
    query group — attention is embarrassingly parallel over KV heads and
    NO collective runs inside the attend. The single wire crossing is the
    O(B·T·H·D) all-gather of per-head outputs; every device then computes
    the ``out @ wo`` reduction on identical replicated data, which keeps
    mesh-engine greedy outputs token-identical to the single-device engine
    (same algebra as the issue's "only the final per-token output
    reduction" — gathering activations instead of psum-ing partial matmul
    products avoids cross-device reduction-order drift)."""
    try:
        from jax import shard_map
    except ImportError:  # jax < 0.5
        from jax.experimental.shard_map import shard_map

    P = jax.sharding.PartitionSpec
    pool_specs = jax.tree.map(
        lambda a: P(None, ax) if jnp.ndim(a) >= 2 else P(), pool)

    def body(q_l, pool_l, *ex):
        out = core(q_l, pool_l, *ex)
        return jax.lax.all_gather(out, ax, axis=2, tiled=True)

    kw = dict(mesh=rules.mesh,
              in_specs=(P(None, None, ax, None), pool_specs, *extra_specs),
              out_specs=P())
    try:
        f = shard_map(body, check_vma=False, **kw)
    except TypeError:  # jax < 0.5 spells it check_rep
        f = shard_map(body, check_rep=False, **kw)
    return f(q, pool, *extras)


def _paged_decode_core(q, pool, page_table, eff_len, alive, *, cfg,
                       use_pallas):
    """Attend-only body of :func:`paged_decode_attention` (post-append,
    pre-``wo``): runs unchanged on the full pool or on a per-device KV-head
    slice inside :func:`_head_sharded_call`."""
    if use_pallas:
        from repro.kernels import ops as kops
        # dead slots get zero live length: the length-aware kernel then
        # streams no blocks for them at all, instead of scoring stale pages
        live_len = jnp.where(alive, eff_len, 0)
        return kops.qdecode_paged_attention(q, pool, page_table, live_len)
    r = pool.group_size
    # gather only the batch's max live page count when lengths are
    # concrete; the full page-table width is pool capacity, not work
    live = _concrete_live_pages(eff_len, r)
    pt = page_table if live is None else page_table[:, :live]
    k_all, v_all = pool.gather_dequant(pt, q.dtype)
    k_full = jnp.concatenate([k_all, pool.k_res.astype(q.dtype)], axis=2)
    v_full = jnp.concatenate([v_all, pool.v_res.astype(q.dtype)], axis=2)
    s_main = k_all.shape[2]
    n_main = eff_len // r * r
    idx = jnp.arange(s_main + r)
    valid = jnp.where(idx[None, :] < s_main,
                      idx[None, :] < n_main[:, None],
                      (idx[None, :] - s_main) < (eff_len - n_main)[:, None])
    # select, don't add: a masked position must be inert even when the
    # gathered bytes are non-finite (a freed slot's stale page-table
    # entry may alias a block another request later corrupts; additive
    # NEG_INF bias would propagate its NaN into this slot's softmax,
    # and an unmasked NaN value row would poison the weighted sum)
    s = jnp.where(valid[:, None, None, :],                    # [B,1,1,S']
                  _scores(q, k_full.transpose(0, 2, 1, 3), cfg), NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    v_t = jnp.where(valid[:, :, None, None],                # [B,S',1,1]
                    v_full.transpose(0, 2, 1, 3), 0.0)
    return _weighted_v(p, v_t, cfg).astype(q.dtype)


def paged_decode_attention(params, cfg, x, pool, page_table, lengths, alive,
                           theta: float, use_pallas: bool = False):
    """One-token decode over the shared paged pool for every serving slot.

    x [max_slots, 1, D]; ``pool`` is this layer's ``PagedKVPool``;
    page_table [max_slots, P]; lengths [max_slots] i32 (pre-append);
    alive [max_slots] bool — dead/empty slots are fully masked and produce
    finite garbage that the engine ignores.

    Returns (attn_out [max_slots, 1, D], new_pool). Slots advance
    independently; the append/flush is batched with no per-slot control flow,
    so ONE jitted decode step serves any mix of request lengths — the
    continuous-batching property.
    """
    b = x.shape[0]
    hd = cfg.head_dim
    positions = lengths[:, None]
    q, k_new, v_new = qkv(params, cfg, x, positions, theta)
    new_pool = pool.append(k_new.transpose(0, 2, 1, 3),
                           v_new.transpose(0, 2, 1, 3),
                           lengths, alive, page_table)
    eff_len = lengths + alive.astype(jnp.int32)

    core = functools.partial(_paged_decode_core, cfg=cfg,
                             use_pallas=use_pallas)
    shard = _paged_head_shard(new_pool)
    if shard is not None:
        rules, ax = shard
        P = jax.sharding.PartitionSpec
        out = _head_sharded_call(core, rules, ax, q, new_pool,
                                 (page_table, eff_len, alive),
                                 (P(), P(), P()))
    else:
        out = core(q, new_pool, page_table, eff_len, alive)

    y = out.reshape(b, 1, cfg.num_heads * hd) @ params["wo"]
    return y, new_pool


# ------------------------------------------------------------ paged verify
def _paged_verify_core(q, pool, page_table, live_len, win_lens, k_att, v_att,
                       *, cfg, use_pallas):
    """Attend-only body of :func:`paged_verify_attention`: candidate window
    K/V ride along as extra per-KV-head-sharded operands (dim 1 = Hkv)."""
    k1 = q.shape[1]
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.qverify_paged_attention(
            q, pool, page_table, live_len, k_att, v_att,
            win_lens).astype(q.dtype)
    r = pool.group_size
    live = _concrete_live_pages(live_len, r)
    pt = page_table if live is None else page_table[:, :live]
    k_ctx, v_ctx = pool.gather_dequant(pt, q.dtype)
    k_cat = jnp.concatenate([k_ctx, pool.k_res.astype(q.dtype),
                             k_att.astype(q.dtype)], axis=2)
    v_cat = jnp.concatenate([v_ctx, pool.v_res.astype(q.dtype),
                             v_att.astype(q.dtype)], axis=2)
    s_main = k_ctx.shape[2]
    n_main = live_len // r * r
    n_res = live_len - n_main
    ii = jnp.arange(s_main + r + k1)[None, None, :]
    qi = jnp.arange(k1)[None, :, None]
    valid = jnp.where(
        ii < s_main, ii < n_main[:, None, None],
        jnp.where(ii < s_main + r,
                  (ii - s_main) < n_res[:, None, None],
                  ((ii - s_main - r) <= qi)
                  & ((ii - s_main - r) < win_lens[:, None, None])))
    # select, don't add — see paged_decode_attention: masked positions
    # must stay inert even over non-finite gathered bytes
    sc = jnp.where(valid[:, None],                          # [S,1,K1,S']
                   _scores(q, k_cat.transpose(0, 2, 1, 3), cfg), NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    dead_key = ~valid.any(axis=1)                           # [S, S']
    v_sel = jnp.where(dead_key[:, :, None, None],           # [S,S',1,1]
                      0.0, v_cat.transpose(0, 2, 1, 3))
    return _weighted_v(p, v_sel, cfg).astype(q.dtype)


def paged_verify_attention(params, cfg, x, pool, page_table, lengths, alive,
                           theta: float, use_pallas: bool = False):
    """Speculative-verify attention: score K1 = speculate_k + 1 candidate
    tokens per slot against the slot's committed context in one pass,
    WITHOUT writing the pool — commit happens after acceptance, via
    ``PagedKVPool.append_tokens`` (the sampling/commit split of the
    speculative engine; rejected candidates never touch pool state).

    x [max_slots, K1, D] — embedded ``[current, draft_1..draft_k]`` per
    slot; lengths [max_slots] i32 committed tokens (pre-verify); alive
    [max_slots] bool (dead lanes are fully masked and produce finite
    garbage the engine ignores). Candidate position c attends the
    committed context plus candidates ``<= c`` — exactly what c serial
    decode steps would see, at the same RoPE positions. Candidate K/V
    round-trips through the residual-window dtype before attention so the
    scores match what the serial step computes after storing the token in
    the window (bitwise the same key bytes).

    Returns (attn_out [max_slots, K1, D], (k_t, v_t) [max_slots, Hkv, K1, D]
    post-rope candidate KV for the later commit).
    """
    s, k1, _ = x.shape
    hd = cfg.head_dim
    lengths = lengths.astype(jnp.int32)
    positions = lengths[:, None] + jnp.arange(k1)[None, :]
    q, k_new, v_new = qkv(params, cfg, x, positions, theta)
    k_t = k_new.transpose(0, 2, 1, 3)   # [S, Hkv, K1, D]
    v_t = v_new.transpose(0, 2, 1, 3)
    k_att = k_t.astype(pool.k_res.dtype)
    v_att = v_t.astype(pool.v_res.dtype)
    live_len = jnp.where(alive, lengths, 0)
    win_lens = jnp.where(alive, k1, 0).astype(jnp.int32)

    core = functools.partial(_paged_verify_core, cfg=cfg,
                             use_pallas=use_pallas)
    shard = _paged_head_shard(pool)
    if shard is not None:
        rules, ax = shard
        P = jax.sharding.PartitionSpec
        out = _head_sharded_call(
            core, rules, ax, q, pool,
            (page_table, live_len, win_lens, k_att, v_att),
            (P(), P(), P(), P(None, ax, None, None),
             P(None, ax, None, None)))
    else:
        out = core(q, pool, page_table, live_len, win_lens, k_att, v_att)

    y = out.reshape(s, k1, cfg.num_heads * hd) @ params["wo"]
    return y, (k_t, v_t)


# ------------------------------------------------------------ paged prefill
def _paged_prefill_core(q, pool, pt_row, k_t, v_t, *, ctx_len, cfg,
                        use_pallas):
    """Attend-only body of :func:`paged_prefill_attention` (static batch-1
    chunk). Pool writes stay OUTSIDE: they are per-KV-head elementwise
    scatters GSPMD keeps shard-local on its own."""
    c_len = q.shape[1]
    r = pool.group_size
    n_ctx = ctx_len // r
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.qprefill_paged_attention(
            q, pool, pt_row[None], jnp.full((1,), ctx_len, jnp.int32),
            k_t, v_t, jnp.full((1,), c_len, jnp.int32)).astype(q.dtype)
    # reference: live pool context [ctx_len] + causal fp intra-chunk [C]
    k_cat, v_cat = k_t.astype(q.dtype), v_t.astype(q.dtype)
    if n_ctx:
        k_ctx, v_ctx = pool.gather_dequant(pt_row[None, :n_ctx], q.dtype)
        k_cat = jnp.concatenate([k_ctx, k_cat], axis=2)
        v_cat = jnp.concatenate([v_ctx, v_cat], axis=2)
    i = jnp.arange(c_len)
    allowed = jnp.concatenate(
        [jnp.ones((c_len, ctx_len), bool),       # context: fully live
         i[None, :] <= i[:, None]], axis=1)      # intra-chunk: causal
    bias = jnp.where(allowed, 0.0, NEG_INF)[None, None]     # [1,1,C,S']
    s = _scores(q, k_cat.transpose(0, 2, 1, 3), cfg) + bias
    p = jax.nn.softmax(s, axis=-1)
    return _weighted_v(p, v_cat.transpose(0, 2, 1, 3), cfg).astype(q.dtype)


def paged_prefill_attention(params, cfg, x, pool, pt_row, slot, ctx_len: int,
                            positions, theta: float,
                            use_pallas: bool = False):
    """One chunk of in-pool prefill for one request (batch-1).

    x [1, C, D] — a group-aligned prompt chunk starting at absolute position
    ``ctx_len`` (a **static** multiple of R: everything before the chunk
    already lives in pool blocks — shared prefix groups plus groups written
    by earlier chunks of this same prefill). The chunk attends over exactly
    the ``ctx_len // R`` live context blocks plus full-precision causal
    intra-chunk keys — on the ``use_pallas`` path through the fused
    ``qprefill_paged`` kernel (packed blocks stream straight from the pool;
    nothing dequantized touches HBM and no dense bias is built), otherwise
    through the dense gather reference below — then writes its own full
    groups straight into the blocks named by ``pt_row`` [P] and any trailing
    partial group (< R tokens, last chunk only) into the slot's residual
    window — no dense batch-1 ``LayerKVCache`` and no adopt copy.

    Returns (attn_out [1, C, D], new_pool).
    """
    b, c_len, _ = x.shape
    hd = cfg.head_dim
    r = pool.group_size
    n_ctx = ctx_len // r
    q, k_new, v_new = qkv(params, cfg, x, positions, theta)
    k_t = k_new.transpose(0, 2, 1, 3)   # [1, Hkv, C, D]
    v_t = v_new.transpose(0, 2, 1, 3)

    core = functools.partial(_paged_prefill_core, ctx_len=ctx_len, cfg=cfg,
                             use_pallas=use_pallas)
    shard = _paged_head_shard(pool)
    if shard is not None:
        rules, ax = shard
        P = jax.sharding.PartitionSpec
        out = _head_sharded_call(
            core, rules, ax, q, pool, (pt_row, k_t, v_t),
            (P(), P(None, ax, None, None), P(None, ax, None, None)))
    else:
        out = core(q, pool, pt_row, k_t, v_t)
    y = out.reshape(b, c_len, cfg.num_heads * hd) @ params["wo"]

    # writes: full groups → pool blocks, trailing partial group → residual
    n_full = c_len // r * r
    new_pool = pool
    if n_full:
        bids = pt_row[n_ctx:n_ctx + n_full // r]
        new_pool = new_pool.write_prefill_groups(
            k_t[:, :, :n_full], v_t[:, :, :n_full], bids)
    if c_len - n_full:
        new_pool = new_pool.write_residual(
            slot, k_t[:, :, n_full:], v_t[:, :, n_full:])
    return y, new_pool


def _paged_prefill_wave_core(q, pool, page_table, ctx_lens, chunk_lens, k_t,
                             v_t, *, cfg, use_pallas):
    """Attend-only body of :func:`paged_prefill_wave_attention`; the
    ``write_wave`` scatter stays outside (elementwise per KV head)."""
    c_len = q.shape[1]
    r = pool.group_size
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.qprefill_paged_attention(
            q, pool, page_table, ctx_lens, k_t, v_t,
            chunk_lens).astype(q.dtype)
    live = _concrete_live_pages(ctx_lens, r)
    pt = page_table if live is None else page_table[:, :live]
    k_ctx, v_ctx = pool.gather_dequant(pt, q.dtype)  # [S,Hkv,P'·R,D]
    k_cat = jnp.concatenate([k_ctx, k_t.astype(q.dtype)], axis=2)
    v_cat = jnp.concatenate([v_ctx, v_t.astype(q.dtype)], axis=2)
    s_ctx = k_ctx.shape[2]
    i = jnp.arange(c_len)
    kidx = jnp.arange(s_ctx + c_len)
    valid = jnp.where(
        kidx[None, None, :] < s_ctx,
        kidx[None, None, :] < ctx_lens[:, None, None],
        ((kidx[None, None, :] - s_ctx) <= i[None, :, None])
        & ((kidx[None, None, :] - s_ctx) < chunk_lens[:, None, None]))
    bias = jnp.where(valid, 0.0, NEG_INF)[:, None]          # [S,1,C,S']
    sc = _scores(q, k_cat.transpose(0, 2, 1, 3), cfg) + bias
    p = jax.nn.softmax(sc, axis=-1)
    return _weighted_v(p, v_cat.transpose(0, 2, 1, 3), cfg).astype(q.dtype)


def paged_prefill_wave_attention(params, cfg, x, pool, page_table, ctx_lens,
                                 chunk_lens, positions, theta: float,
                                 use_pallas: bool = False):
    """One batched prefill chunk wave across ALL serving slots.

    x [max_slots, C, D] — one group-aligned chunk per slot, padded to the
    engine's chunk width; ``ctx_lens [max_slots]`` i32 tokens already in
    pool blocks per slot (**traced**, each a multiple of R; 0 for dead
    lanes) and ``chunk_lens [max_slots]`` i32 live chunk tokens (0 = dead
    lane: a slot mid-decode, or a request out of chunks this wave). Unlike
    the batch-1 :func:`paged_prefill_attention` (static lengths → one
    retrace per distinct context length), lengths here are traced: one
    compiled wave serves every burst composition.

    The ``use_pallas`` path streams packed context blocks through the fused
    ``qprefill_paged`` kernel (work ∝ live context); the reference path
    gathers the page table (clamped to the batch's max live page count when
    lengths are concrete) and builds the dense mask — the oracle the parity
    suite checks against. Writes go through ``PagedKVPool.write_wave``
    (masked scatter; dead lanes write only to the scratch block).

    Returns (attn_out [max_slots, C, D] — dead-lane rows are garbage the
    engine ignores — and the new pool).
    """
    s, c_len, _ = x.shape
    hd = cfg.head_dim
    r = pool.group_size
    ctx_lens = ctx_lens.astype(jnp.int32)
    chunk_lens = chunk_lens.astype(jnp.int32)
    q, k_new, v_new = qkv(params, cfg, x, positions, theta)
    k_t = k_new.transpose(0, 2, 1, 3)   # [S, Hkv, C, D]
    v_t = v_new.transpose(0, 2, 1, 3)

    core = functools.partial(_paged_prefill_wave_core, cfg=cfg,
                             use_pallas=use_pallas)
    shard = _paged_head_shard(pool)
    if shard is not None:
        rules, ax = shard
        P = jax.sharding.PartitionSpec
        out = _head_sharded_call(
            core, rules, ax, q, pool,
            (page_table, ctx_lens, chunk_lens, k_t, v_t),
            (P(), P(), P(), P(None, ax, None, None),
             P(None, ax, None, None)))
    else:
        out = core(q, pool, page_table, ctx_lens, chunk_lens, k_t, v_t)

    y = out.reshape(s, c_len, cfg.num_heads * hd) @ params["wo"]
    new_pool = pool.write_wave(k_t, v_t, page_table, ctx_lens, chunk_lens)
    return y, new_pool


# ----------------------------------------------------------------- training
@dataclasses.dataclass
class AttnSim:
    """Per-layer simulation knobs threaded through full forward passes:
    traced (k_bits, v_bits) + static mode. bits >= 16 disables quantization."""

    k_bits: jax.Array | float = 16.0
    v_bits: jax.Array | float = 16.0
    mode: str = MODE_PER_TOKEN


def attention_block(params, cfg, x, positions, kind: str, window: int,
                    theta: float, sim: AttnSim | None = None, capture=None,
                    layer_id: int | None = None):
    """Full-sequence attention sublayer (train / prefill / calibration).

    * ``sim`` applies fake quantization to K/V before attention — the paper's
      calibration mode where "dequantized KV cache [is used] for self-attention
      during prefilling, enabling error accumulation across layers" (§5.3).
    * ``capture`` (a dict) stashes per-layer Q/K/V/output for sensitivity
      analysis (§4) — only usable on non-scanned stacks.
    Returns (y [B,S,D], (k, v) post-rope tensors in [B,S,Hkv,hd]).
    """
    q, k, v = qkv(params, cfg, x, positions, theta)
    k_used, v_used = k, v
    if sim is not None:
        k_used, v_used = sim_quant_kv(k, v, sim.k_bits, sim.v_bits, sim.mode,
                                      cfg.kv_group_size)
    out = full_attention(q, k_used, v_used, cfg, kind=kind, window=window,
                         q_positions=positions[0] if positions.ndim > 1 else positions,
                         k_positions=positions[0] if positions.ndim > 1 else positions)
    b, s, _, _ = out.shape
    y = out.reshape(b, s, cfg.num_heads * cfg.head_dim) @ params["wo"]
    if capture is not None and layer_id is not None:
        capture[layer_id] = {"q": q, "k": k, "v": v, "o": out}
    return y, (k_used, v_used)
