"""Top-k MoE with capacity-based scatter dispatch (GShard-style, XLA-friendly).

Static shapes throughout: tokens above capacity are dropped (standard capacity
factor semantics). Expert weights are stacked ``[E, ...]`` and shard over the
``experts`` logical axis when E divides the mesh (arctic: 128/16 ✓, jamba:
16/16 ✓); otherwise (grok-1: 8 experts) the ``expert_ff`` axis carries TP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_hint
from repro.models import common


def init_moe(rng, cfg) -> dict:
    dt = common.dtype_of(cfg)
    ks = common.split_keys(rng, 4)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    scale = 0.02
    def stack(key, shape):
        return (scale * jax.random.truncated_normal(
            key, -2.0, 2.0, shape, jnp.float32)).astype(dt)
    return {
        "router": common.dense_init(ks[0], d, e, jnp.float32),
        "w_gate": stack(ks[1], (e, d, f)),
        "w_up": stack(ks[2], (e, d, f)),
        "w_down": stack(ks[3], (e, f, d)),
    }


def apply_moe_ep(params: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array] | None:
    """Expert-parallel MoE via shard_map (§Perf iteration on arctic-480b).

    The jit/GSPMD lowering of the gather-combine materializes a **replicated**
    [T·k, D] f32 intermediate and all-reduces it (measured: 56 GiB fwd +
    112 GiB bwd per layer → 11.8 TB/step on arctic train_4k). Here experts
    stay sharded on ``model``; every shard FFNs only its own experts' tokens
    and contributes a *partial* token-sharded output, combined with one
    psum over the expert axis — O(T_local·D) bytes instead of O(T·D·k)
    replicated.

    Trade-off vs the dense path: capacity is enforced per (expert ×
    data-shard), C_local = cf·T_local·k/E, so drop decisions are local
    (standard EP semantics). Returns None when preconditions fail
    (no active rules / E not divisible by the expert axis / T not divisible).
    """
    from jax import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import active_rules

    rules = active_rules()
    if rules is None:
        return None
    mesh = rules.mesh
    e, k = cfg.num_experts, cfg.experts_per_token
    exp_ax = rules.axes("experts", e)
    if exp_ax is None or isinstance(exp_ax, tuple):
        return None
    n_exp_sh = mesh.shape[exp_ax]
    b, s, d = x.shape
    t = b * s
    tok_axes = rules.axes("flat_tokens", t)
    if tok_axes is None:
        return None
    tok_axes_t = tok_axes if isinstance(tok_axes, tuple) else (tok_axes,)
    if exp_ax in tok_axes_t:
        return None
    n_tok_sh = 1
    for a in tok_axes_t:
        n_tok_sh *= mesh.shape[a]
    t_loc = t // n_tok_sh
    e_loc = e // n_exp_sh
    cap = int(cfg.capacity_factor * t_loc * k / e)
    cap = max(-(-cap // 8) * 8, 8)

    def local(xf, router, wg, wu, wd):
        logits = xf.astype(jnp.float32) @ router            # [T_loc, E]
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_i, e), axis=1), axis=0)
        aux = e * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, tok_axes_t)

        my_base = jax.lax.axis_index(exp_ax) * e_loc
        flat_e = top_i.reshape(-1)
        mine = (flat_e >= my_base) & (flat_e < my_base + e_loc)
        local_e = jnp.clip(flat_e - my_base, 0, e_loc - 1)
        onehot = jax.nn.one_hot(local_e, e_loc, dtype=jnp.int32) * \
            mine[:, None].astype(jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        mypos = jnp.take_along_axis(pos, local_e[:, None], axis=1)[:, 0]
        keep = mine & (mypos < cap)
        safe_pos = jnp.where(keep, mypos, cap - 1)

        xrep = jnp.repeat(xf, k, axis=0)
        buf = jnp.zeros((e_loc, cap, d), x.dtype)
        buf = buf.at[local_e, safe_pos].add(
            jnp.where(keep[:, None], xrep, 0).astype(x.dtype), mode="drop")
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
            jnp.einsum("ecd,edf->ecf", buf, wu)
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd)
        gathered = out_buf[local_e, safe_pos]
        weighted = gathered.astype(jnp.float32) * \
            top_p.reshape(-1)[:, None] * keep[:, None]
        y_part = jnp.sum(weighted.reshape(t_loc, k, d), axis=1)
        y = jax.lax.psum(y_part.astype(jnp.float32), exp_ax)
        return y.astype(x.dtype), aux

    tok_spec = tok_axes_t[0] if len(tok_axes_t) == 1 else tok_axes_t
    f = shard_map(
        local, mesh=mesh,
        in_specs=(P(tok_spec, None), P(None, None),
                  P(exp_ax, None, None), P(exp_ax, None, None),
                  P(exp_ax, None, None)),
        out_specs=(P(tok_spec, None), P()),
        check_vma=False)
    y, aux = f(x.reshape(t, d), params["router"], params["w_gate"],
               params["w_up"], params["w_down"])
    return y.reshape(b, s, d), aux


def apply_moe(params: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x [B,S,D] → (y [B,S,D], aux_loss scalar).

    Dispatch: top-k routing → intra-expert positions via cumsum over the
    one-hot assignment matrix → scatter into an [E, C, D] buffer → batched
    expert matmuls → gather-combine weighted by normalized router probs.

    ``cfg.moe_ep`` switches to the shard_map expert-parallel path (§Perf)
    when its sharding preconditions hold.
    """
    if getattr(cfg, "moe_ep", False):
        out = apply_moe_ep(params, x, cfg)
        if out is not None:
            return out
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ params["router"])  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                # [T, k]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch-style): E * Σ_e f_e · p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_i, e), axis=1), axis=0)
    aux = e * jnp.sum(me * ce)

    cap = int(cfg.capacity_factor * t * k / e)
    cap = max(-(-cap // 8) * 8, 8)  # pad for lane alignment

    flat_e = top_i.reshape(-1)                             # [T*k] token-major
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)    # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) - onehot              # position pre-insert
    mypos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # [T*k]
    keep = (mypos < cap)

    # Scatter tokens into per-expert capacity buffers.
    xrep = jnp.repeat(xf, k, axis=0)                       # [T*k, D]
    xrep = shard_hint(xrep, "flat_tokens", "none")
    safe_pos = jnp.where(keep, mypos, cap - 1)
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[flat_e, safe_pos].add(
        jnp.where(keep[:, None], xrep, 0).astype(x.dtype), mode="drop")
    buf = shard_hint(buf, "experts", "expert_cap", "none")

    # Batched expert FFN (swiglu), sharded over experts / expert_ff.
    wg = shard_hint(params["w_gate"], "experts", "none", "expert_ff")
    wu = shard_hint(params["w_up"], "experts", "none", "expert_ff")
    wd = shard_hint(params["w_down"], "experts", "expert_ff", "none")
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
        jnp.einsum("ecd,edf->ecf", buf, wu)
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd)
    out_buf = shard_hint(out_buf, "experts", "expert_cap", "none")

    # Gather-combine.
    gathered = out_buf[flat_e, safe_pos]                   # [T*k, D]
    gathered = shard_hint(gathered, "flat_tokens", "none")
    weighted = gathered.astype(jnp.float32) * top_p.reshape(-1)[:, None] * keep[:, None]
    y = jnp.sum(weighted.reshape(t, k, d), axis=1).astype(x.dtype)
    return y.reshape(b, s, d), aux
