"""Model assembly for all assigned families: init / forward / train-loss /
prefill / decode, with scan-over-layers for deep homogeneous stacks and
python-loop paths for heterogeneous ones (and for activation capture).

Entry points are pure functions over a params pytree; `repro.models.registry`
wraps them into a `ModelApi`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.cache.kvcache import init_model_cache
from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, MAMBA, MLSTM, SLSTM,
                                ModelConfig)
from repro.core.precision import MODE_PER_TOKEN, KVTunerSchedule
from repro.distributed.sharding import shard_hint
from repro.models import attention, common, mamba as mamba_mod, moe as moe_mod
from repro.models import xlstm as xlstm_mod

AUX_LOSS_WEIGHT = 0.01


# ==================================================================== init
def _init_layer(rng, cfg: ModelConfig, kind: str, layer_id: int) -> dict:
    dt = common.dtype_of(cfg)
    ks = common.split_keys(rng, 4)
    p: dict = {"ln1": jnp.zeros((cfg.d_model,), dt)}
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        p["attn"] = attention.init_attention(ks[0], cfg)
    elif kind == MAMBA:
        p["mamba"] = mamba_mod.init_mamba(ks[0], cfg)
    elif kind == MLSTM:
        p["mlstm"] = xlstm_mod.init_mlstm(ks[0], cfg)
        return p  # xlstm blocks have no separate MLP sublayer
    elif kind == SLSTM:
        p["slstm"] = xlstm_mod.init_slstm(ks[0], cfg)
        p["ln2"] = jnp.zeros((cfg.d_model,), dt)
        d_ff = -(-4 * cfg.d_model // 3 // 128) * 128
        p["mlp"] = common.init_mlp(ks[1], cfg.d_model, d_ff, "silu", dt)
        return p
    p["ln2"] = jnp.zeros((cfg.d_model,), dt)
    is_moe = bool(cfg.num_experts) and layer_id in cfg.moe_layers()
    if is_moe:
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
        if cfg.moe_dense_residual:
            p["mlp"] = common.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dt)
    else:
        p["mlp"] = common.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dt)
    return p


def init_params(cfg: ModelConfig, rng) -> dict:
    dt = common.dtype_of(cfg)
    keys = common.split_keys(rng, cfg.num_layers + 4)
    kinds = cfg.layer_kinds()
    params: dict = {
        "embed": common.embed_init(keys[-1], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(keys[-2], cfg.d_model,
                                              cfg.vocab_size, dt)
    if cfg.family == "vlm":
        params["mm_proj"] = {
            "w1": common.dense_init(keys[-3], cfg.vision_dim, cfg.d_model, dt),
            "w2": common.dense_init(keys[-4], cfg.d_model, cfg.d_model, dt),
        }
    if cfg.is_encoder:
        params["frontend"] = {
            "proj": common.dense_init(keys[-3], cfg.frontend_dim, cfg.d_model, dt),
            "mask_emb": 0.02 * jax.random.normal(keys[-4], (cfg.frontend_dim,), jnp.float32).astype(dt),
        }
    layer_params = [_init_layer(keys[i], cfg, kinds[i], i)
                    for i in range(cfg.num_layers)]
    plan = _scan_plan(cfg)
    if plan == "stack":
        params["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *layer_params)
    elif plan == "period":
        # jamba: stack position-j sublayers across periods → {"sub j": [P, ...]}
        period = cfg.attn_period
        n_periods = cfg.num_layers // period
        params["layers"] = {
            f"sub{j}": jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[layer_params[p * period + j] for p in range(n_periods)])
            for j in range(period)
        }
    else:
        params["layers"] = layer_params
    return params


def _scan_plan(cfg: ModelConfig) -> str:
    """stack: one scan over all layers. period: scan over repeating patterns
    (jamba). loop: python loop (xlstm, capture mode)."""
    if not cfg.scan_layers:
        return "loop"
    if cfg.family == "ssm":
        return "loop"
    if cfg.family == "hybrid" and cfg.attn_period:
        if cfg.num_layers % cfg.attn_period == 0:
            return "period"
        return "loop"
    if cfg.is_homogeneous or cfg.local_global_ratio:
        # gemma local/global layers share param structure → traced mask select
        if cfg.num_experts and cfg.moe_every > 1:
            return "loop"
        return "stack"
    return "loop"


# ============================================================ layer forward
def layer_params_at(params, cfg: ModelConfig, i: int):
    """Extract layer i's params regardless of storage plan (list / stacked /
    period-stacked)."""
    ls = params["layers"]
    if isinstance(ls, list):
        return ls[i]
    if isinstance(ls, dict) and "sub0" in ls:
        period = cfg.attn_period
        return jax.tree.map(lambda a: a[i // period], ls[f"sub{i % period}"])
    return jax.tree.map(lambda a: a[i], ls)


def _rope_theta(cfg, kind):
    if cfg.local_global_ratio and kind == ATTN_GLOBAL and cfg.rope_theta_global:
        return cfg.rope_theta_global
    return cfg.rope_theta


def _attn_sublayer(p, cfg, x, positions, kind, sim, capture, layer_id,
                   is_global=None):
    h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
    if is_global is not None:
        # gemma scan path: traced local/global select (masks and rope theta)
        y, kv = _dual_attention_block(p["attn"], cfg, h, positions, is_global,
                                      sim=sim)
    else:
        window = cfg.local_window if kind == ATTN_LOCAL else 0
        mask_kind = "bidir" if cfg.is_encoder else (
            "local" if kind == ATTN_LOCAL else "causal")
        y, kv = attention.attention_block(
            p["attn"], cfg, h, positions, mask_kind, window,
            _rope_theta(cfg, kind) if not cfg.is_encoder else 0.0,
            sim=sim, capture=capture, layer_id=layer_id)
    return x + y, kv


def _dual_attention_block(p, cfg, h, positions, is_global, sim=None):
    """Gemma3 scanned attention: is_global is a traced bool scalar selecting
    mask window and rope theta, keeping the scan body homogeneous."""
    b, s, _ = h.shape
    hd = cfg.head_dim
    q = (h @ p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = (h @ p["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = (h @ p["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.use_qk_norm:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    th_l, th_g = cfg.rope_theta, cfg.rope_theta_global or cfg.rope_theta
    q = jnp.where(is_global, common.apply_rope(q, positions, th_g),
                  common.apply_rope(q, positions, th_l))
    k = jnp.where(is_global, common.apply_rope(k, positions, th_g),
                  common.apply_rope(k, positions, th_l))
    if sim is not None:
        k_used, v_used = attention.sim_quant_kv(
            k, v, sim.k_bits, sim.v_bits, sim.mode, cfg.kv_group_size)
    else:
        k_used, v_used = k, v
    pos1 = positions[0] if positions.ndim > 1 else positions
    # traced-window trick: local → window, global → larger-than-seq window
    window = jnp.where(is_global, jnp.int32(2 ** 30), jnp.int32(cfg.local_window))
    out = _windowed_attention(q, k_used, v_used, cfg, pos1, window)
    y = out.reshape(b, s, cfg.num_heads * hd) @ p["wo"]
    return y, (k_used, v_used)


def _windowed_attention(q, k, v, cfg, positions, window):
    """full_attention variant whose window is a traced scalar."""
    b, sq, h, hd = q.shape
    chunk = min(cfg.q_chunk, sq)
    if sq % chunk:
        chunk = sq

    def one_chunk(qc, qpos):
        allowed = (positions[None, :] <= qpos[:, None]) & \
            ((qpos[:, None] - positions[None, :]) < window)
        bias = jnp.where(allowed, 0.0, attention.NEG_INF)
        s = attention._scores(qc, k, cfg) + bias
        p = jax.nn.softmax(s, axis=-1)
        return attention._weighted_v(p, v, cfg).astype(q.dtype)

    if chunk == sq:
        return one_chunk(q, positions)
    n = sq // chunk
    qs = q.reshape(b, n, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    ps = positions.reshape(n, chunk)
    body = lambda carry, xs: (carry, one_chunk(*xs))
    if cfg.remat:
        body = jax.checkpoint(body)
    _, out = jax.lax.scan(body, (), (qs, ps))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def _ffn_sublayer(p, cfg, x, layer_id):
    aux = jnp.zeros((), jnp.float32)
    if "mlp" not in p and "moe" not in p:
        return x, aux
    h = common.rms_norm(x, p["ln2"], cfg.norm_eps)
    y = jnp.zeros_like(x)
    if "moe" in p:
        y_moe, aux = moe_mod.apply_moe(p["moe"], h, cfg)
        y = y + y_moe
        if "mlp" in p:  # arctic dense residual in parallel with MoE
            y = y + common.apply_mlp(p["mlp"], h, cfg.act)
    else:
        y = common.apply_mlp(p["mlp"], h, cfg.act)
    return x + y, aux


def _apply_layer_full(p, cfg, kind, x, positions, *, sim=None, capture=None,
                      layer_id=None, is_global=None, rec_state=None):
    """One transformer layer over the full sequence. Returns
    (x, kv_or_None, rec_state_or_None, aux_loss)."""
    kv = None
    new_rec = None
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        x, kv = _attn_sublayer(p, cfg, x, positions, kind, sim, capture,
                               layer_id, is_global=is_global)
    elif kind == MAMBA:
        h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
        y, new_rec = mamba_mod.apply_mamba(p["mamba"], cfg, h, state=rec_state)
        x = x + y
    elif kind == MLSTM:
        h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
        y, new_rec = xlstm_mod.apply_mlstm(p["mlstm"], cfg, h, state=rec_state)
        return x + y, None, new_rec, jnp.zeros((), jnp.float32)
    elif kind == SLSTM:
        h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
        y, new_rec = xlstm_mod.apply_slstm(p["slstm"], cfg, h, state=rec_state)
        x = x + y
    x, aux = _ffn_sublayer(p, cfg, x, layer_id)
    return x, kv, new_rec, aux


# =========================================================== input embedding
def embed_inputs(params, cfg: ModelConfig, batch: dict):
    """Returns (x [B,S,D], positions [B,S]). Handles the three input kinds:
    text tokens, VLM tokens+patch embeds (image first), audio frames+mask."""
    dt = common.dtype_of(cfg)
    if cfg.is_encoder:
        frames = batch["frames"].astype(dt)
        if "mask" in batch:
            frames = jnp.where(batch["mask"][..., None],
                               params["frontend"]["mask_emb"], frames)
        x = frames @ params["frontend"]["proj"]
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        # sinusoidal position embedding (conv-pos frontend is stubbed)
        half = cfg.d_model // 2
        freqs = jnp.exp(-jnp.arange(half) / half * jnp.log(10000.0))
        ang = pos[..., None] * freqs
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dt)
        return x + pe, pos
    tok = batch["tokens"]
    x = params["embed"][tok]
    if cfg.family == "vlm" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(dt)
        img = jax.nn.gelu(pe @ params["mm_proj"]["w1"]) @ params["mm_proj"]["w2"]
        x = jnp.concatenate([img, x], axis=1)  # anyres tiles prepended
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    return x, pos


def unembed(params, cfg, x):
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return shard_hint(logits, "batch", "seq", "vocab")


# ================================================================== forward
def forward(params, cfg: ModelConfig, batch: dict, *, sim_bits=None,
            sim_mode: str = MODE_PER_TOKEN, capture=None, collect_kv=False):
    """Full-sequence forward.

    * ``sim_bits``: [n_attn_layers, 2] traced (k_bits, v_bits) — the paper's
      calibration mode (fake-quant K/V inside attention, errors accumulate
      across layers). One jit serves every schedule.
    * ``capture``: dict → per-attention-layer Q/K/V/attn-out (forces loop path).
    * ``collect_kv``: additionally return per-attention-layer post-rope (K, V)
      ([B,S,Hkv,hd]) for prefill cache construction.

    Returns (logits, aux) where aux = {"aux_loss", "kv"?}.
    """
    x, positions = embed_inputs(params, cfg, batch)
    x = shard_hint(x, "batch", "seq", "d_model")
    kinds = cfg.layer_kinds()
    attn_ids = cfg.attention_layers()
    plan = _scan_plan(cfg) if capture is None else "loop"

    def layer_sim(layer_id):
        if sim_bits is None:
            return None
        ai = attn_ids.index(layer_id)
        return attention.AttnSim(k_bits=sim_bits[ai, 0], v_bits=sim_bits[ai, 1],
                                 mode=sim_mode)

    aux_total = jnp.zeros((), jnp.float32)
    kv_out: list = []

    if plan == "stack":
        x, aux_total, kv_out = _forward_stack(
            params, cfg, x, positions, kinds, sim_bits, sim_mode, collect_kv)
    elif plan == "period":
        x, aux_total, kv_out = _forward_period(
            params, cfg, x, positions, kinds, sim_bits, sim_mode, collect_kv)
    else:
        for i, kind in enumerate(kinds):
            p = layer_params_at(params, cfg, i)
            x, kv, _, aux = _apply_layer_full(
                p, cfg, kind, x, positions, sim=layer_sim(i)
                if kind in (ATTN_GLOBAL, ATTN_LOCAL) else None,
                capture=capture, layer_id=i)
            aux_total += aux
            if collect_kv and kv is not None:
                kv_out.append(kv)

    logits = unembed(params, cfg, x)
    aux = {"aux_loss": aux_total}
    if collect_kv:
        aux["kv"] = kv_out
    return logits, aux


def _forward_stack(params, cfg, x, positions, kinds, sim_bits, sim_mode,
                   collect_kv):
    """lax.scan over stacked layer params (dense / gemma / uniform-MoE)."""
    n = cfg.num_layers
    is_global = jnp.asarray([k == ATTN_GLOBAL for k in kinds])
    gemma = bool(cfg.local_global_ratio)
    bits = sim_bits if sim_bits is not None else jnp.full((n, 2), 16.0)

    def body(carry, xs):
        x, aux = carry
        lp, ig, lb = xs
        sim = attention.AttnSim(k_bits=lb[0], v_bits=lb[1], mode=sim_mode) \
            if sim_bits is not None else None
        x, kv, _, a = _apply_layer_full(
            lp, cfg, ATTN_GLOBAL, x, positions, sim=sim,
            is_global=(ig if gemma else None))
        out = None
        if collect_kv:
            out = tuple(shard_hint(t, "batch", "kv_seq", "none", "none")
                        for t in kv)
        return (x, aux + a), out

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux_total), kvs = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], is_global, bits))
    kv_out = []
    if collect_kv:
        k_all, v_all = kvs  # [L, B, S, Hkv, hd]
        kv_out = [(k_all[i], v_all[i]) for i in range(n)]
    return x, aux_total, kv_out


def _forward_period(params, cfg, x, positions, kinds, sim_bits, sim_mode,
                    collect_kv):
    """Jamba: scan over repeating periods; each period applies its
    heterogeneous sublayers in a python loop inside the scan body."""
    period = cfg.attn_period
    n_periods = cfg.num_layers // period
    pkinds = kinds[:period]
    bits = sim_bits if sim_bits is not None else \
        jnp.full((len(cfg.attention_layers()), 2), 16.0)
    attn_per_period = sum(1 for k in pkinds if k in (ATTN_GLOBAL, ATTN_LOCAL))
    bits_p = bits.reshape(n_periods, attn_per_period, 2)

    def body(carry, xs):
        x, aux = carry
        pparams, pbits = xs
        ai = 0
        kvs = []
        for j, kind in enumerate(pkinds):
            sim = None
            if kind in (ATTN_GLOBAL, ATTN_LOCAL) and sim_bits is not None:
                sim = attention.AttnSim(k_bits=pbits[ai, 0],
                                        v_bits=pbits[ai, 1], mode=sim_mode)
            x, kv, _, a = _apply_layer_full(
                pparams[f"sub{j}"], cfg, kind, x, positions, sim=sim,
                layer_id=j)
            aux += a
            if kind in (ATTN_GLOBAL, ATTN_LOCAL):
                ai += 1
                kvs.append(kv)
        out = None
        if collect_kv and kvs:
            out = tuple(shard_hint(t, "batch", "kv_seq", "none", "none")
                        for t in kvs[0])
        return (x, aux), out

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux_total), kvs = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params["layers"], bits_p))
    kv_out = []
    if collect_kv and kvs is not None:
        k_all, v_all = kvs
        kv_out = [(k_all[i], v_all[i]) for i in range(n_periods)]
    return x, aux_total, kv_out


# =================================================================== losses
def train_loss(params, cfg: ModelConfig, batch: dict, rng=None):
    logits, aux = forward(params, cfg, batch)
    if cfg.is_encoder:
        loss = common.softmax_cross_entropy(
            logits, batch["targets"], batch.get("mask"))
    else:
        logits_txt = logits
        if cfg.family == "vlm":
            # image positions carry no LM loss; logits cover [img ; text]
            s_img = batch["patch_embeds"].shape[1]
            logits_txt = logits[:, s_img:]
        if "labels" in batch:  # labels[t] = target for position t
            loss = common.softmax_cross_entropy(
                logits_txt, batch["labels"], batch.get("loss_mask"))
        else:  # next-token objective
            mask = batch.get("loss_mask")
            loss = common.softmax_cross_entropy(
                logits_txt[:, :-1], batch["tokens"][:, 1:],
                None if mask is None else mask[:, 1:])
    return loss + AUX_LOSS_WEIGHT * aux["aux_loss"], {"nll": loss}


# ============================================================ prefill/decode
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DecodeState:
    caches: list        # per layer: LayerKVCache | None
    rec: list           # per layer: MambaState/MLSTMState/SLSTMState | None
    pos: jax.Array      # [B] next position index


def prefill(params, cfg: ModelConfig, batch: dict,
            schedule: KVTunerSchedule | None, capacity: int | None = None,
            extra_groups: int = 4):
    """Full forward + quantized cache construction per the schedule.

    Quantization of the prefill KV (not just decode KV) matches the paper's
    deployment/calibration setting ("KV cache quantization is enabled during
    both prefilling and decoding stages", §E.1).
    """
    x, positions = embed_inputs(params, cfg, batch)
    seq = x.shape[1]
    b = x.shape[0]
    capacity = capacity or seq
    kinds = cfg.layer_kinds()
    plan = _scan_plan(cfg)
    caches = init_model_cache(cfg, schedule, b, capacity, extra_groups)
    rec: list = [None] * cfg.num_layers

    if plan in ("stack", "period"):
        logits, aux = forward(params, cfg, batch, collect_kv=True)
        kvs = aux["kv"]
        for slot, i in enumerate(cfg.attention_layers()):
            k, v = kvs[slot]  # [B,S,Hkv,hd]
            caches[i] = caches[i].fill(k.transpose(0, 2, 1, 3),
                                       v.transpose(0, 2, 1, 3))
    else:
        x0 = shard_hint(x, "batch", "seq", "d_model")
        xcur = x0
        for i, kind in enumerate(kinds):
            p = layer_params_at(params, cfg, i)
            xcur, kv, new_rec, _ = _apply_layer_full(
                p, cfg, kind, xcur, positions, layer_id=i)
            if kv is not None:
                k, v = kv
                caches[i] = caches[i].fill(k.transpose(0, 2, 1, 3),
                                           v.transpose(0, 2, 1, 3))
            rec[i] = new_rec
        logits = unembed(params, cfg, xcur)

    state = DecodeState(caches=caches, rec=rec,
                        pos=jnp.full((b,), seq, jnp.int32))
    return logits[:, -1], state


def decode_step(params, cfg: ModelConfig, state: DecodeState, token,
                use_pallas: bool = False):
    """One decode step. token [B, 1] int32 → (logits [B, vocab], new state).

    Python loop over layers: per-layer caches are heterogeneous under a mixed
    schedule (different packed widths), which is un-scannable by construction.
    """
    x = params["embed"][token]  # [B,1,D]
    x = shard_hint(x, "batch", "seq", "d_model")
    positions = state.pos[:, None]
    kinds = cfg.layer_kinds()
    new_caches, new_rec = list(state.caches), list(state.rec)

    for i, kind in enumerate(kinds):
        p = layer_params_at(params, cfg, i)
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
            window = cfg.local_window if kind == ATTN_LOCAL else 0
            y, new_caches[i] = attention.decode_attention(
                p["attn"], cfg, h, state.caches[i], positions,
                "local" if kind == ATTN_LOCAL else "causal", window,
                _rope_theta(cfg, kind), use_pallas=use_pallas)
            x = x + y
        elif kind == MAMBA:
            h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
            y, new_rec[i] = mamba_mod.apply_mamba(p["mamba"], cfg, h,
                                                  state=state.rec[i])
            x = x + y
        elif kind == MLSTM:
            h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
            y, new_rec[i] = xlstm_mod.apply_mlstm(p["mlstm"], cfg, h,
                                                  state=state.rec[i])
            x = x + y
            continue
        elif kind == SLSTM:
            h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
            y, new_rec[i] = xlstm_mod.apply_slstm(p["slstm"], cfg, h,
                                                  state=state.rec[i])
            x = x + y
        x, _ = _ffn_sublayer(p, cfg, x, i)

    logits = unembed(params, cfg, x)[:, 0]
    return logits, DecodeState(caches=new_caches, rec=new_rec,
                               pos=state.pos + 1)


# ====================================================== paged decode (serving)
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PagedDecodeState:
    """Decode state for continuous batching over the shared paged KV pool.

    Unlike ``DecodeState`` (one private cache per request wave), every slot of
    a fixed ``max_slots`` batch shares the per-layer block pools; per-slot
    progress lives in ``lengths`` and the physical block mapping in
    ``page_table`` (shared by all layers). Admitting/finishing a request
    never changes any array shape, so one jitted step serves the whole run.
    """

    pools: list          # per layer: PagedKVPool | None
    page_table: jax.Array  # [max_slots, max_pages] i32 physical block ids
    lengths: jax.Array     # [max_slots] i32 tokens cached per slot


def init_paged_state(cfg: ModelConfig, schedule, max_slots: int,
                     num_blocks: int, max_pages: int) -> PagedDecodeState:
    from repro.cache.paged import init_model_pools

    for kind in cfg.layer_kinds():
        if kind in (MAMBA, MLSTM, SLSTM):
            raise NotImplementedError(
                "continuous paged decoding supports attention-only stacks; "
                f"layer kind {kind!r} needs per-slot recurrent-state resets")
    pools = init_model_pools(cfg, schedule, max_slots, num_blocks)
    return PagedDecodeState(
        pools=pools,
        page_table=jnp.zeros((max_slots, max_pages), jnp.int32),
        lengths=jnp.zeros((max_slots,), jnp.int32))


def paged_adopt(cfg: ModelConfig, state: PagedDecodeState, caches: list,
                slot, pages, prompt_len) -> PagedDecodeState:
    """Move a batch-1 prefill (dense per-layer caches) into pool blocks
    ``pages`` at ``slot``. The page-table row itself is updated host-side by
    the engine (it owns the allocator); here we only place KV bytes."""
    pools = list(state.pools)
    for i, cache in enumerate(caches):
        if cache is not None:
            pools[i] = pools[i].adopt_prefill(cache, slot, pages)
    lengths = state.lengths.at[slot].set(jnp.asarray(prompt_len, jnp.int32))
    return dataclasses.replace(state, pools=pools, lengths=lengths)


def prefill_paged(params, cfg: ModelConfig, state: PagedDecodeState, tokens,
                  slot, start: int, *, chunk: int, use_pallas: bool = False):
    """Chunked in-pool prefill: run the non-cached prompt suffix through the
    model in fixed-size chunks, writing each layer's quantized KV groups
    straight into the slot's pool blocks (page-table row must already be
    set) — no transient dense cache and no adopt copy.

    tokens [1, S_suf] i32 — the prompt suffix; ``start`` (static, a multiple
    of both R and ``chunk``) counts prompt tokens already in the pool via a
    shared cached prefix. ``chunk`` must be a multiple of the quant group R
    so every chunk boundary is a group boundary: a chunk attends to
    *quantized* pool blocks for everything before it and full-precision keys
    within itself, so the computation is identical whether the earlier
    groups were just written by this prefill or pinned from the prefix
    cache — the property that keeps prefix-cached serving token-identical
    to cache-off serving. Static ``start`` also lets each chunk gather only
    its live context blocks instead of the whole ``max_pages`` row.

    Returns (last-token logits [1, vocab], new state). Retraces once per
    distinct (suffix length, start) pair — admission cost, like any
    prefill; the decode step is untouched.
    """
    s_suf = tokens.shape[1]
    if not s_suf:
        raise ValueError("paged prefill needs >= 1 suffix token; cap prefix "
                         "matches below the full prompt")
    if chunk % cfg.kv_group_size or start % chunk:
        raise ValueError(
            f"paged prefill alignment: chunk ({chunk}) must be a multiple "
            f"of R ({cfg.kv_group_size}) and start ({start}) of chunk")
    kinds = cfg.layer_kinds()
    pools = list(state.pools)
    pt_row = state.page_table[slot]
    x = None
    for c0 in range(0, s_suf, chunk):
        c1 = min(c0 + chunk, s_suf)
        positions = (start + c0 + jnp.arange(c1 - c0))[None]
        x = params["embed"][tokens[:, c0:c1]]
        x = shard_hint(x, "batch", "seq", "d_model")
        for i, kind in enumerate(kinds):
            p = layer_params_at(params, cfg, i)
            if kind not in (ATTN_GLOBAL, ATTN_LOCAL):
                raise NotImplementedError(f"paged prefill: layer kind {kind!r}")
            h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
            y, pools[i] = attention.paged_prefill_attention(
                p["attn"], cfg, h, pools[i], pt_row, slot, start + c0,
                positions, _rope_theta(cfg, kind), use_pallas=use_pallas)
            x = x + y
            x, _ = _ffn_sublayer(p, cfg, x, i)
    logits = unembed(params, cfg, x)[:, -1]
    lengths = state.lengths.at[slot].set(
        jnp.asarray(start + s_suf, jnp.int32))
    return logits, dataclasses.replace(state, pools=pools, lengths=lengths)


def prefill_paged_wave(params, cfg: ModelConfig, state: PagedDecodeState,
                       tokens, ctx_lens, chunk_lens, *,
                       use_pallas: bool = False):
    """ONE batched group-aligned prefill chunk wave across ALL serving
    slots — the device half of batched multi-request admission.

    tokens [max_slots, C] i32 (padded; dead lanes feed any id); ctx_lens
    [max_slots] i32 tokens already in the pool per slot (multiples of R;
    0 for dead lanes); chunk_lens [max_slots] i32 live tokens of this
    wave's chunk (0 = dead lane — a slot mid-decode, or a request that ran
    out of chunks while a longer burst member still prefills). Page-table
    rows of admitted slots must already be set.

    Unlike :func:`prefill_paged` (python chunk loop, static lengths → one
    retrace per distinct suffix and one device round-trip per *request*),
    lengths here are **traced**: ONE compiled wave serves every burst
    composition, and a burst of arrivals costs one device round-trip per
    chunk wave. Returns (last_logits [max_slots, vocab] — each lane's
    logits at its final live chunk position, garbage for dead lanes — and
    the new state). Dead lanes' lengths and residual windows are untouched.
    """
    c_len = tokens.shape[1]
    if c_len % cfg.kv_group_size:
        raise ValueError(
            f"wave chunk width ({c_len}) must be a multiple of the quant "
            f"group size ({cfg.kv_group_size})")
    kinds = cfg.layer_kinds()
    pools = list(state.pools)
    ctx_lens = ctx_lens.astype(jnp.int32)
    chunk_lens = chunk_lens.astype(jnp.int32)
    positions = ctx_lens[:, None] + jnp.arange(c_len)[None, :]
    x = params["embed"][tokens]
    x = shard_hint(x, "batch", "seq", "d_model")
    for i, kind in enumerate(kinds):
        p = layer_params_at(params, cfg, i)
        if kind not in (ATTN_GLOBAL, ATTN_LOCAL):
            raise NotImplementedError(f"paged prefill: layer kind {kind!r}")
        h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
        y, pools[i] = attention.paged_prefill_wave_attention(
            p["attn"], cfg, h, pools[i], state.page_table, ctx_lens,
            chunk_lens, positions, _rope_theta(cfg, kind),
            use_pallas=use_pallas)
        x = x + y
        x, _ = _ffn_sublayer(p, cfg, x, i)
    logits = unembed(params, cfg, x)                       # [S, C, V]
    last_idx = jnp.clip(chunk_lens - 1, 0, c_len - 1)
    last = jnp.take_along_axis(logits, last_idx[:, None, None], axis=1)[:, 0]
    active = chunk_lens > 0
    lengths = jnp.where(active, ctx_lens + chunk_lens, state.lengths)
    return last, dataclasses.replace(state, pools=pools, lengths=lengths)


def paged_decode_step(params, cfg: ModelConfig, state: PagedDecodeState,
                      token, alive, use_pallas: bool = False):
    """One continuous-batching decode step over all serving slots.

    token [max_slots, 1] i32 (dead slots feed any id); alive [max_slots]
    bool. Returns (logits [max_slots, vocab], new state). Dead slots produce
    finite garbage logits that the engine discards; their lengths do not
    advance and their flushes land in the scratch block.
    """
    x = params["embed"][token]  # [B,1,D]
    x = shard_hint(x, "batch", "seq", "d_model")
    kinds = cfg.layer_kinds()
    new_pools = list(state.pools)

    for i, kind in enumerate(kinds):
        p = layer_params_at(params, cfg, i)
        if kind not in (ATTN_GLOBAL, ATTN_LOCAL):
            raise NotImplementedError(f"paged decode: layer kind {kind!r}")
        h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
        y, new_pools[i] = attention.paged_decode_attention(
            p["attn"], cfg, h, state.pools[i], state.page_table,
            state.lengths, alive, _rope_theta(cfg, kind),
            use_pallas=use_pallas)
        x = x + y
        x, _ = _ffn_sublayer(p, cfg, x, i)

    logits = unembed(params, cfg, x)[:, 0]
    new_state = dataclasses.replace(
        state, pools=new_pools,
        lengths=state.lengths + alive.astype(jnp.int32))
    return logits, new_state


def paged_decode_loop(params, cfg: ModelConfig, state: PagedDecodeState,
                      token, alive, remaining, eos_ids, rng, *, horizon: int,
                      use_pallas: bool = False, greedy: bool = True):
    """``horizon`` decode steps as ONE device-side ``lax.scan`` — sampling
    and EOS/budget liveness masking run on device, so the host syncs once
    per horizon instead of once per token (the per-token
    ``np.asarray(sample(logits))`` round-trip is the continuous engine's
    dominant non-compute cost at small batch).

    token [max_slots] i32 (each slot's current token); alive [max_slots]
    bool; remaining [max_slots] i32 tokens each slot may still emit;
    eos_ids [max_slots] i32 per-slot EOS (-1 = none); rng is consumed only
    when ``greedy=False`` (one split per step — a different stream than the
    host-side sampler, so only greedy outputs are horizon-invariant).

    A slot that hits EOS or exhausts its budget at inner step t stops
    appending KV and emitting from step t+1; the freed slot is only
    re-admitted at the next host sync — the horizon trades admission
    latency (and tail decode steps that run with some slots dead) for
    H× fewer host round-trips.

    Returns (new_state, tokens [H, max_slots], emitted [H, max_slots] bool,
    new_rng). ``tokens[t]`` is meaningful where ``emitted[t]``.
    """
    def body(carry, _):
        st, tok, alv, rem, key = carry
        logits, st = paged_decode_step(params, cfg, st, tok[:, None], alv,
                                       use_pallas=use_pallas)
        if greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits).astype(jnp.int32)
        emitted = alv
        rem = rem - emitted.astype(jnp.int32)
        alv = alv & jnp.not_equal(nxt, eos_ids) & (rem > 0)
        tok = jnp.where(emitted, nxt, tok)
        return (st, tok, alv, rem, key), (nxt, emitted)

    (state, _, _, _, rng), (toks, emitted) = jax.lax.scan(
        body, (state, token.astype(jnp.int32), alive,
               remaining.astype(jnp.int32), rng), None, length=horizon)
    return state, toks, emitted, rng


def _spec_accept(nxt, drafts, n_draft, alive, remaining, eos_ids):
    """Longest greedy-consistent accepted prefix + emission mask, shared by
    both verification backends. ``nxt [S, K1]`` greedy outputs per candidate
    position. Returns (counts [S] i32, emitted [S, K1] bool)."""
    k = drafts.shape[1]
    k1 = k + 1
    if k:
        match = (drafts == nxt[:, :k]) \
            & (jnp.arange(k)[None, :] < n_draft[:, None])
        matched = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1),
                          axis=1)
    else:
        matched = jnp.zeros(nxt.shape[0], jnp.int32)
    pos = jnp.arange(k1)[None, :]
    eos_pos = jnp.min(jnp.where(nxt == eos_ids[:, None], pos, k1), axis=1)
    m = jnp.minimum(jnp.minimum(matched + 1, eos_pos + 1),
                    jnp.maximum(remaining, 1))
    counts = jnp.where(alive, m, 0)
    emitted = alive[:, None] & (pos < counts[:, None])
    return counts, emitted


def paged_spec_step(params, cfg: ModelConfig, state: PagedDecodeState,
                    tokens, drafts, n_draft, alive, remaining, eos_ids,
                    use_pallas: bool = False, fused: bool = False):
    """One speculative draft–verify–commit step over all serving slots —
    sampling is split from state commit: verification scores every
    candidate, acceptance picks the longest greedy-consistent prefix, and
    rejected candidates' KV is rolled back bitwise.

    Two verification backends:

    * default (``fused=False``): ``k+1`` serial-shaped
      :func:`paged_decode_step` sub-steps run inside the ONE dispatch — a
      device-side scan, so the host still syncs once per draft–verify–commit
      round. Each sub-step is the exact op/shape sequence of a plain decode
      step, so accepted tokens are **bitwise identical by construction** to
      serial decode (kernel on or off); the rejected tail's pool writes are
      reverted by :meth:`PagedKVPool.rollback_tail` against per-layer
      :meth:`~PagedKVPool.snapshot_spec` snapshots. The win is amortizing
      the per-token host round-trip (the dominant small-batch cost), not
      the forward FLOPs.
    * ``fused=True``: ONE ``[S, k+1]``-wide forward scores all candidate
      positions in a single pass over the quantized pool
      (:func:`~repro.models.attention.paged_verify_attention`; Pallas
      ``qverify_paged`` or the XLA oracle), committing accepted KV via
      :meth:`~PagedKVPool.append_tokens` — fewer pool passes, but the wide
      matmuls are only numerically (not bitwise) equal to serial steps, so
      greedy outputs can diverge at near-tie argmaxes over long horizons.

    tokens [max_slots] i32 — each slot's current token (KV not yet
    appended, the engine convention); drafts [max_slots, k] i32 candidate
    continuations; n_draft [max_slots] i32 live drafts per slot (0 = no
    match: the slot degenerates to a normal one-token decode inside the
    same dispatch); alive [max_slots] bool; remaining [max_slots] i32
    emission budget per slot (>= 1 for live slots); eos_ids [max_slots]
    i32 per-slot EOS (-1 = none).

    Per slot the step emits ``m = min(matched_prefix + 1, first_eos + 1,
    remaining)`` tokens — accepted candidate c+1 IS the greedy output of
    position c, and EOS/budget cut the accepted prefix exactly where the
    serial loop's liveness mask would stop. The last emitted token's KV is
    NOT appended — it is the next step's input.

    Returns (new_state, out_tokens [max_slots, k+1], emitted
    [max_slots, k+1] bool). ``out_tokens[s, c]`` is meaningful where
    ``emitted[s, c]``; ``emitted[s].sum()`` tokens were committed.
    """
    k = drafts.shape[1]
    k1 = k + 1
    tokens = tokens.astype(jnp.int32)
    drafts = drafts.astype(jnp.int32)
    n_draft = n_draft.astype(jnp.int32)
    remaining = remaining.astype(jnp.int32)
    inputs = jnp.concatenate([tokens[:, None], drafts], axis=1)  # [S, K1]

    if fused:
        return _paged_spec_step_fused(
            params, cfg, state, inputs, drafts, n_draft, alive, remaining,
            eos_ids, use_pallas=use_pallas)

    lengths0 = state.lengths
    snaps = [None if pool is None else
             pool.snapshot_spec(lengths0, state.page_table)
             for pool in state.pools]

    def body(st, xs):
        inp_c, c = xs
        sub_alive = alive & (c <= n_draft)
        logits, st = paged_decode_step(params, cfg, st, inp_c[:, None],
                                       sub_alive, use_pallas=use_pallas)
        return st, jnp.argmax(logits, axis=-1).astype(jnp.int32)

    st, outs = jax.lax.scan(
        body, state, (inputs.T, jnp.arange(k1, dtype=jnp.int32)))
    nxt = outs.T                                          # [S, K1] greedy

    counts, emitted = _spec_accept(nxt, drafts, n_draft, alive, remaining,
                                   eos_ids)
    appended = jnp.where(alive, n_draft + 1, 0)
    new_pools = list(st.pools)
    for i, snap in enumerate(snaps):
        if snap is not None:
            new_pools[i] = new_pools[i].rollback_tail(
                snap, lengths0, counts, appended)
    new_state = dataclasses.replace(
        st, pools=new_pools, lengths=lengths0 + counts)
    return new_state, nxt, emitted


def _paged_spec_step_fused(params, cfg: ModelConfig, state: PagedDecodeState,
                           inputs, drafts, n_draft, alive, remaining,
                           eos_ids, use_pallas: bool = False):
    """Fused verification backend of :func:`paged_spec_step`: one
    ``[S, K1]``-wide forward scores all candidate positions without touching
    the pool, then only accepted tokens' KV is appended
    (:meth:`PagedKVPool.append_tokens`) — rejected drafts vanish without any
    state to roll back."""
    x = params["embed"][inputs]
    x = shard_hint(x, "batch", "seq", "d_model")
    kinds = cfg.layer_kinds()
    stash: list = [None] * len(kinds)
    for i, kind in enumerate(kinds):
        p = layer_params_at(params, cfg, i)
        if kind not in (ATTN_GLOBAL, ATTN_LOCAL):
            raise NotImplementedError(f"paged verify: layer kind {kind!r}")
        h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
        y, stash[i] = attention.paged_verify_attention(
            p["attn"], cfg, h, state.pools[i], state.page_table,
            state.lengths, alive, _rope_theta(cfg, kind),
            use_pallas=use_pallas)
        x = x + y
        x, _ = _ffn_sublayer(p, cfg, x, i)
    logits = unembed(params, cfg, x)                      # [S, K1, V]
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # [S, K1] greedy

    counts, emitted = _spec_accept(nxt, drafts, n_draft, alive, remaining,
                                   eos_ids)
    new_pools = list(state.pools)
    for i, kv in enumerate(stash):
        if kv is not None:
            k_t, v_t = kv
            new_pools[i] = new_pools[i].append_tokens(
                k_t, v_t, state.lengths, counts, state.page_table)
    new_state = dataclasses.replace(
        state, pools=new_pools, lengths=state.lengths + counts)
    return new_state, nxt, emitted


def init_decode_state(cfg: ModelConfig, schedule, batch: int, capacity: int,
                      extra_groups: int = 4, filled_to: int | None = None):
    """Fresh (or pretend-prefilled, for dry-runs) decode state."""
    caches = init_model_cache(cfg, schedule, batch, capacity, extra_groups)
    rec: list = []
    for kind in cfg.layer_kinds():
        if kind == MAMBA:
            rec.append(mamba_mod.init_mamba_state(cfg, batch))
        elif kind == MLSTM:
            rec.append(xlstm_mod.init_mlstm_state(cfg, batch))
        elif kind == SLSTM:
            rec.append(xlstm_mod.init_slstm_state(cfg, batch))
        else:
            rec.append(None)
    pos = jnp.full((batch,), filled_to or 0, jnp.int32)
    if filled_to:
        caches = [None if c is None else dataclasses.replace(
            c, length=jnp.asarray(filled_to, jnp.int32)) for c in caches]
    return DecodeState(caches=caches, rec=rec, pos=pos)
