"""xLSTM blocks (mLSTM + sLSTM) — attention-free recurrent architecture.

KVTuner is **inapplicable** here (no KV cache; see DESIGN.md §5) — the arch is
implemented without the technique. Decode state is O(1) in sequence length,
which is why xlstm runs the long_500k cell.

TPU adaptation: the CUDA fused recurrent kernels become chunked lax.scan with
remat; the mLSTM matrix memory C [B,H,dk,dv] shards its value dim over
``model`` (the recurrence is independent along dv).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_hint
from repro.models import common


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MLSTMState:
    c: jax.Array  # [B, H, dk, dv] f32 matrix memory
    n: jax.Array  # [B, H, dk] f32 normalizer
    m: jax.Array  # [B, H] f32 log-stabilizer


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SLSTMState:
    c: jax.Array  # [B, D] f32 cell
    n: jax.Array  # [B, D]
    m: jax.Array  # [B, D]
    h: jax.Array  # [B, D] recurrent output


# ------------------------------------------------------------------- mLSTM
def init_mlstm(rng, cfg) -> dict:
    dt = common.dtype_of(cfg)
    d = cfg.d_model
    di = int(cfg.mlstm_proj_factor * d)
    h = cfg.num_heads
    ks = common.split_keys(rng, 7)
    return {
        "w_up": common.dense_init(ks[0], d, di, dt),
        "w_gate": common.dense_init(ks[1], d, di, dt),
        "wq": common.dense_init(ks[2], di, di, dt),
        "wk": common.dense_init(ks[3], di, di, dt),
        "wv": common.dense_init(ks[4], di, di, dt),
        "w_if": common.dense_init(ks[5], di, 2 * h, jnp.float32),
        "if_bias": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]),
        "w_down": common.dense_init(ks[6], di, d, dt),
    }


def _mlstm_scan(q, k, v, i_raw, f_raw, state: MLSTMState, chunk: int, remat: bool):
    """q/k/v [B,S,H,hd] f32; gates [B,S,H]. Sequential, chunked + remat."""
    b, s, h, hd = q.shape

    def inner(carry, xs):
        c, n, m = carry
        qt, kt, vt, it, ft = xs  # [B,H,hd] / [B,H]
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        alpha = jnp.exp(logf + m - m_new)[..., None]
        beta = jnp.exp(it - m_new)[..., None]
        c = alpha[..., None] * c + beta[..., None] * (kt[..., :, None] * vt[..., None, :])
        n = alpha * n + beta * kt
        denom = jnp.maximum(jnp.abs(jnp.sum(n * qt, -1)), 1.0)[..., None]
        ht = jnp.einsum("bhkv,bhk->bhv", c, qt) / denom
        return (c, n, m_new), ht

    def outer(carry, xs):
        return jax.lax.scan(inner, carry, xs)

    c = min(chunk, s)
    while s % c:
        c -= 1
    nc = s // c
    if remat and nc > 1:
        outer = jax.checkpoint(outer)

    def chunks(x):  # [B,S,...] → [nc, c, B, ...]
        return x.reshape(b, nc, c, *x.shape[2:]).transpose(
            1, 2, 0, *range(3, x.ndim + 1))

    carry = (state.c, state.n, state.m)
    carry, hs = jax.lax.scan(outer, carry, tuple(map(chunks, (q, k, v, i_raw, f_raw))))
    out = hs.transpose(2, 0, 1, 3, 4).reshape(b, s, h, hd)
    return out, MLSTMState(*carry)


def apply_mlstm(params, cfg, x, state: MLSTMState | None = None, chunk: int = 128):
    b, s, d = x.shape
    di = int(cfg.mlstm_proj_factor * d)
    h = cfg.num_heads
    hd = di // h
    u = x @ params["w_up"]
    z = x @ params["w_gate"]
    u = shard_hint(u, "batch", "seq", "mamba_inner")

    def heads(w):
        return (u @ w).reshape(b, s, h, hd).astype(jnp.float32)

    q, k, v = heads(params["wq"]) / jnp.sqrt(hd), heads(params["wk"]), heads(params["wv"])
    gates = (u.astype(jnp.float32) @ params["w_if"]) + params["if_bias"]
    i_raw, f_raw = gates[..., :h], gates[..., h:]
    if state is None:
        state = init_mlstm_state(cfg, b)
    out, new_state = _mlstm_scan(q, k, v, i_raw, f_raw, state, chunk, cfg.remat)
    out = out.reshape(b, s, di).astype(x.dtype) * jax.nn.silu(z)
    return out @ params["w_down"], new_state


def init_mlstm_state(cfg, batch: int) -> MLSTMState:
    di = int(cfg.mlstm_proj_factor * cfg.d_model)
    h = cfg.num_heads
    hd = di // h
    return MLSTMState(c=jnp.zeros((batch, h, hd, hd), jnp.float32),
                      n=jnp.zeros((batch, h, hd), jnp.float32),
                      m=jnp.full((batch, h), -1e9, jnp.float32))


# ------------------------------------------------------------------- sLSTM
def init_slstm(rng, cfg) -> dict:
    dt = common.dtype_of(cfg)
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    ks = common.split_keys(rng, 3)
    return {
        "w_in": common.dense_init(ks[0], d, 4 * d, dt),
        # block-diagonal recurrent matrices, one [hd, 4*hd] block per head
        "r": (0.02 * jax.random.truncated_normal(
            ks[1], -2.0, 2.0, (h, hd, 4 * hd), jnp.float32)),
        "bias": jnp.concatenate(
            [jnp.zeros((d,)), 3.0 * jnp.ones((d,)), jnp.zeros((2 * d,))]),
        "w_out": common.dense_init(ks[2], d, d, dt),
    }


def apply_slstm(params, cfg, x, state: SLSTMState | None = None, chunk: int = 128):
    """Strictly sequential (h feeds back into the gates); chunked remat scan."""
    b, s, d = x.shape
    h = cfg.num_heads
    hd = d // h
    wx = (x @ params["w_in"]).astype(jnp.float32)  # [B,S,4D]
    if state is None:
        state = init_slstm_state(cfg, b)

    def inner(carry, xs):
        c, n, m, hprev = carry
        wxt = xs  # [B, 4D]
        hr = hprev.reshape(b, h, hd)
        rec = jnp.einsum("bhk,hkj->bhj", hr, params["r"]).reshape(b, 4 * d)
        pre = wxt + rec + params["bias"]
        ig, fg, zg, og = jnp.split(pre, 4, axis=-1)
        logf = jax.nn.log_sigmoid(fg)
        m_new = jnp.maximum(logf + m, ig)
        alpha = jnp.exp(logf + m - m_new)
        beta = jnp.exp(ig - m_new)
        c = alpha * c + beta * jnp.tanh(zg)
        n = alpha * n + beta
        hnew = jax.nn.sigmoid(og) * c / jnp.maximum(n, 1.0)
        return (c, n, m_new, hnew), hnew

    def outer(carry, xs):
        return jax.lax.scan(inner, carry, xs)

    c = min(chunk, s)
    while s % c:
        c -= 1
    nc = s // c
    if cfg.remat and nc > 1:
        outer = jax.checkpoint(outer)
    wxc = wx.reshape(b, nc, c, 4 * d).transpose(1, 2, 0, 3)
    carry, hs = jax.lax.scan(outer, (state.c, state.n, state.m, state.h), wxc)
    out = hs.transpose(2, 0, 1, 3).reshape(b, s, d).astype(x.dtype)
    return out @ params["w_out"], SLSTMState(*carry)


def init_slstm_state(cfg, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z, m=jnp.full((batch, d), -1e9, jnp.float32), h=z)
