"""Synthetic datasets, including the error-amplifying calibration task.

The paper's calibration-set design principle (§5.3): long-context *reasoning*
chains where a single flipped token invalidates the final answer (their GSM8K
CoT example, Table 1). Offline we mirror that with **modular arithmetic
chains**: the model must track a running value across many steps — any
intermediate attention error corrupts every later step, maximizing the
separation between KV precision pairs. A copy/recall task exercises retrieval
heads (the quantization-sensitive pattern of §4.4 / Lemma 1).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Token map: digits 0..9 → ids 0..9, ops and control tokens follow.
PAD, BOS, EOS, EQ, PLUS, MINUS, SEP, QUERY = 10, 11, 12, 13, 14, 15, 16, 17
VOCAB_BASE = 18


@dataclasses.dataclass(frozen=True)
class TaskConfig:
    vocab_size: int = 64          # ≥ VOCAB_BASE; extra ids used by recall keys
    modulus: int = 10
    chain_len: int = 8            # arithmetic steps per chain
    seq_len: int = 64


def chain_batch(cfg: TaskConfig, batch: int, rng: np.random.Generator):
    """Running modular arithmetic: BOS a0 ± d1 = a1 ± d2 = a2 ... EOS.

    Every `=` position must emit the correct running value — the LM loss on
    those positions is the calibration metric (exact-match accuracy is the
    fraction of chains with *all* results correct, mirroring GSM8K's
    final-answer scoring where one flip breaks the chain).
    """
    toks = np.full((batch, cfg.seq_len), PAD, np.int32)
    mask = np.zeros((batch, cfg.seq_len), np.float32)
    for b in range(batch):
        val = int(rng.integers(cfg.modulus))
        seq = [BOS, val]
        results = []
        for _ in range(cfg.chain_len):
            d = int(rng.integers(1, cfg.modulus))
            op = PLUS if rng.random() < 0.5 else MINUS
            val = (val + d) % cfg.modulus if op == PLUS else \
                (val - d) % cfg.modulus
            seq.extend([op, d, EQ, val])
            results.append(len(seq) - 1)
        seq.append(EOS)
        seq = seq[:cfg.seq_len]
        toks[b, :len(seq)] = seq
        for p in results:
            if p < cfg.seq_len:
                mask[b, p] = 1.0  # loss/accuracy measured at result tokens
    return {"tokens": toks, "loss_mask": mask}


def recall_batch(cfg: TaskConfig, batch: int, rng: np.random.Generator,
                 n_pairs: int = 6):
    """Key-value recall: SEP k1 v1 k2 v2 ... QUERY k_i → v_i.
    Exercises content-addressed (retrieval-head) attention."""
    n_keys = cfg.vocab_size - VOCAB_BASE
    toks = np.full((batch, cfg.seq_len), PAD, np.int32)
    mask = np.zeros((batch, cfg.seq_len), np.float32)
    for b in range(batch):
        keys = rng.choice(n_keys, size=n_pairs, replace=False) + VOCAB_BASE
        vals = rng.integers(0, 10, size=n_pairs)
        seq = [BOS]
        for k, v in zip(keys, vals):
            seq.extend([SEP, int(k), int(v)])
        qi = int(rng.integers(n_pairs))
        seq.extend([QUERY, int(keys[qi]), int(vals[qi]), EOS])
        seq = seq[:cfg.seq_len]
        toks[b, :len(seq)] = seq
        ans = len(seq) - 2
        if 0 < ans < cfg.seq_len:
            mask[b, ans] = 1.0
    return {"tokens": toks, "loss_mask": mask}


def mixed_batch(cfg: TaskConfig, batch: int, rng: np.random.Generator):
    a = chain_batch(cfg, batch // 2, rng)
    b = recall_batch(cfg, batch - batch // 2, rng)
    return {k: np.concatenate([a[k], b[k]]) for k in a}


def exact_match_accuracy(logits, batch) -> float:
    """Fraction of *sequences* whose every masked position is argmax-correct
    (chain-level accuracy: one intermediate flip fails the sample — the
    paper's error-accumulation story in miniature)."""
    import numpy as np

    preds = np.asarray(logits).argmax(-1)[:, :-1]
    targets = np.asarray(batch["tokens"])[:, 1:]
    mask = np.asarray(batch["loss_mask"])[:, 1:] > 0
    correct = (preds == targets) | ~mask
    return float(np.all(correct, axis=1).mean())
