"""Sharded, resumable data pipeline.

Production posture: every batch is derived **statelessly** from (seed, step),
so restart/elastic-rescale resumes exactly — no iterator state in checkpoints.
A memmap-backed token corpus covers file-based training; synthetic task
generators cover calibration/benchmarks. A background prefetch thread overlaps
host batch assembly with device compute.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from repro.data import synthetic


@dataclasses.dataclass
class SyntheticSource:
    """Deterministic (seed, step) → batch synthesis."""

    task: synthetic.TaskConfig
    batch_size: int
    kind: str = "mixed"  # chain | recall | mixed
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        fn = {"chain": synthetic.chain_batch, "recall": synthetic.recall_batch,
              "mixed": synthetic.mixed_batch}[self.kind]
        return fn(self.task, self.batch_size, rng)


@dataclasses.dataclass
class MemmapSource:
    """Flat token file → next-token LM batches, sharded by data-parallel rank.

    Window selection is a pure function of (seed, step, rank), so any number
    of ranks can re-derive their shard after an elastic resize.
    """

    path: str
    batch_size: int
    seq_len: int
    rank: int = 0
    world: int = 1
    seed: int = 0

    def __post_init__(self):
        self._tokens = np.memmap(self.path, dtype=np.int32, mode="r")
        self._n = len(self._tokens) - self.seq_len - 1

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step, self.rank))
        per_rank = self.batch_size // self.world
        starts = rng.integers(0, self._n, size=per_rank)
        toks = np.stack([self._tokens[s:s + self.seq_len + 1] for s in starts])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def write_token_corpus(path: str, tokens: np.ndarray) -> None:
    np.asarray(tokens, np.int32).tofile(path)


class Prefetcher:
    """Background-thread prefetch over a stateless source. Overlaps host-side
    batch synthesis with device steps; `close()` is idempotent."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self.source.batch_at(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
