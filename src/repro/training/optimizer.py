"""AdamW + schedules in pure JAX (optax is unavailable offline).

Optimizer state is a pytree mirroring params; the trainer shards it over
(data, model) — ZeRO-1 style — via the sharding rules, so 67B-class training
fits 16 GB/chip (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                          nu=zeros(params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state: AdamWState, params):
        """Returns (new_params, new_state, metrics). f32 moments, params keep
        their storage dtype (bf16 master-in-compute pattern)."""
        gflat = jax.tree.leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in gflat))
        clip = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9)) \
            if self.grad_clip else 1.0

        step = state.step + 1
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * clip
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mhat = m / b1c
            vhat = v / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step=step, mu=mu, nu=nu), {
            "grad_norm": gnorm, "lr": lr}


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr
