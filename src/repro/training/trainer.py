"""Fault-tolerant training loop: checkpoint/restart, retry-on-failure,
stateless-resumable data, optional int8 error-feedback gradient compression.

The jitted step is mesh-agnostic: under `Mesh`+sharding rules it lowers to the
production SPMD program (launch/train.py); on a single CPU device it runs the
same code for smoke tests and the small-model end-to-end example.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.training import grad_compress
from repro.training.optimizer import AdamW, AdamWState


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: dict
    opt: AdamWState
    ef: grad_compress.EFState | None


def make_train_step(api, optimizer: AdamW, compress_grads: bool = False,
                    grad_shardings=None):
    """(state, batch) → (state, metrics). Pure; jit at call site with
    in_shardings/out_shardings for the production mesh.

    ``grad_shardings`` (params-shaped NamedSharding tree) pins gradients to
    the ZeRO layout so GSPMD emits **reduce-scatter** instead of materializing
    replicated gradients through a full all-reduce — on arctic-480b this was
    a 13 TB/device/step collective (§Perf iteration 2)."""

    def step(state: TrainState, batch: dict):
        def loss_fn(p):
            loss, metrics = api.train_loss(p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)
        if grad_shardings is not None:
            grads = jax.tree.map(jax.lax.with_sharding_constraint, grads,
                                 grad_shardings)
        ef = state.ef
        if compress_grads and ef is not None:
            grads, ef = grad_compress.apply_error_feedback(grads, ef)
        new_params, opt, opt_metrics = optimizer.update(
            grads, state.opt, state.params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(params=new_params, opt=opt, ef=ef), metrics

    return step


@dataclasses.dataclass
class Trainer:
    api: object
    optimizer: AdamW
    source: object                      # stateless: batch_at(step)
    ckpt: CheckpointManager | None = None
    ckpt_every: int = 100
    compress_grads: bool = False
    max_retries: int = 3
    log_every: int = 25
    log_fn: Callable = print

    def init_state(self, rng) -> TrainState:
        params = self.api.init(rng)
        ef = grad_compress.init_error_feedback(params) \
            if self.compress_grads else None
        return TrainState(params=params, opt=self.optimizer.init(params), ef=ef)

    def run(self, total_steps: int, rng=None, state: TrainState | None = None,
            jit: bool = True) -> tuple[TrainState, list[dict]]:
        """Train with checkpoint-resume. On a step failure (hardware fault in
        production; any exception here) the loop restores the last committed
        checkpoint and continues — up to max_retries per step index."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        step_fn = make_train_step(self.api, self.optimizer,
                                  self.compress_grads)
        if jit:
            step_fn = jax.jit(step_fn, donate_argnums=(0,))

        start = 0
        if state is None:
            state = self.init_state(rng)
            if self.ckpt is not None:
                restored = self.ckpt.restore_latest(state)
                if restored is not None:
                    start, state, extra = restored
                    self.log_fn(f"[trainer] resumed from step {start}")

        history: list[dict] = []
        retries = 0
        step = start
        t0 = time.time()
        while step < total_steps:
            batch = {k: jnp.asarray(v)
                     for k, v in self.source.batch_at(step).items()}
            try:
                state, metrics = step_fn(state, batch)
            except Exception as e:  # noqa: BLE001 — fault-tolerance boundary
                retries += 1
                if retries > self.max_retries or self.ckpt is None:
                    raise
                self.log_fn(f"[trainer] step {step} failed ({e}); "
                            f"restoring last checkpoint")
                restored = self.ckpt.restore_latest(state)
                if restored is not None:
                    step, state, _ = restored
                continue
            retries = 0
            step += 1
            if step % self.log_every == 0 or step == total_steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["steps_per_s"] = self.log_every / max(time.time() - t0, 1e-9)
                t0 = time.time()
                history.append(m)
                self.log_fn(f"[trainer] step {step}: loss={m['loss']:.4f} "
                            f"gnorm={m.get('grad_norm', 0):.3f}")
            if self.ckpt is not None and step % self.ckpt_every == 0:
                self.ckpt.save(step, state, blocking=False)
        if self.ckpt is not None:
            self.ckpt.save(total_steps, state, blocking=True)
        return state, history
