"""Int8 gradient compression with error feedback for the DP all-reduce.

Distributed-optimization trick for the 1000-node posture: the data-parallel
gradient all-reduce moves |params| bytes per step per chip; compressing to
int8 (per-tensor symmetric scale) cuts that 2× vs bf16 / 4× vs f32.
Error feedback (residual accumulation) keeps SGD/Adam convergence — the
compression error of step t is re-injected at t+1, so bias does not
accumulate (Karimireddy et al., 2019).

Implementation note: under jit+GSPMD the all-reduce is implicit (psum over
sharded grads); we compress *before* the mean-reduction boundary by applying
quantize→dequantize inside the loss-grad computation per microbatch. The
lowered HLO then all-reduces int8-scaled values. On CPU dry-runs this is
visible as reduced collective bytes in the §Roofline table.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: dict  # pytree like grads, f32


def init_error_feedback(params) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_decompress(g: jax.Array) -> jax.Array:
    """Per-tensor symmetric int8 quantize→dequantize (the all-reduce payload)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def apply_error_feedback(grads, ef: EFState) -> tuple[dict, EFState]:
    """grads+residual → compressed grads, new residual."""
    def one(g, r):
        target = g.astype(jnp.float32) + r
        comp = compress_decompress(target)
        return comp, target - comp

    out = jax.tree.map(one, grads, ef.residual)
    comp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    resid = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return comp, EFState(residual=resid)
