"""Precision-pair datatypes for layer-wise mixed-precision KV cache quantization.

The paper's search space (§5.1) is the per-layer pair ``(P_k^l, P_v^l)`` with
candidate bits {2, 4, 8} (16 = no quantization). A full-model assignment is a
``KVTunerSchedule``; its memory objective is the *equivalent bits*
``f_m(P) = sum(P) / (2L)`` (paper eq. 4).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Sequence

SUPPORTED_BITS = (2, 4, 8, 16)

# Quantization modes (paper §4.2):
#  - per-token-asym: one (scale, zero) per token row (reduced over head_dim),
#    used for both K and V in the simple baseline mode.
#  - per-channel-asym: one (scale, zero) per channel, grouped along the token
#    axis (KIVI's key mode; values stay per-token).
MODE_PER_TOKEN = "per-token-asym"
MODE_PER_CHANNEL = "per-channel-asym"
MODE_KIVI = "kivi"  # keys per-channel-asym, values per-token-asym
MODES = (MODE_PER_TOKEN, MODE_PER_CHANNEL, MODE_KIVI)


@dataclasses.dataclass(frozen=True, order=True)
class PrecisionPair:
    """Bits for (key, value) cache of one transformer layer."""

    k_bits: int
    v_bits: int

    def __post_init__(self):
        if self.k_bits not in SUPPORTED_BITS or self.v_bits not in SUPPORTED_BITS:
            raise ValueError(f"unsupported bits: {self}")

    @property
    def equivalent_bits(self) -> float:
        return (self.k_bits + self.v_bits) / 2.0

    @property
    def name(self) -> str:
        if self.k_bits == self.v_bits:
            return f"KV{self.k_bits}"
        return f"K{self.k_bits}V{self.v_bits}"

    @classmethod
    def from_name(cls, name: str) -> "PrecisionPair":
        name = name.strip()
        if name.startswith("KV"):
            b = int(name[2:])
            return cls(b, b)
        if name.startswith("K") and "V" in name:
            k, v = name[1:].split("V")
            return cls(int(k), int(v))
        raise ValueError(f"cannot parse precision pair {name!r}")

    def astuple(self) -> tuple[int, int]:
        return (self.k_bits, self.v_bits)


# The 9 uniform candidates evaluated throughout the paper (Tables 2, 3, 5).
CANDIDATE_PAIRS: tuple[PrecisionPair, ...] = tuple(
    PrecisionPair(k, v) for k in (8, 4, 2) for v in (8, 4, 2)
)
FULL_PRECISION = PrecisionPair(16, 16)

# The "key-first" Pareto set the paper finds for most layers (§D.1.1).
KEY_FIRST_SET: tuple[PrecisionPair, ...] = tuple(
    PrecisionPair.from_name(n) for n in ("KV8", "K8V4", "KV4", "K4V2", "KV2")
)


@dataclasses.dataclass
class KVTunerSchedule:
    """A full per-layer precision assignment plus provenance metadata."""

    pairs: list[PrecisionPair]
    mode: str = MODE_PER_TOKEN
    model_name: str = ""
    # Optional provenance from the offline search:
    groups: list[list[int]] | None = None  # clustered layer-id groups
    objectives: dict | None = None  # recorded (bits, accuracy/error) at search time

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r}")
        self.pairs = [
            p if isinstance(p, PrecisionPair) else PrecisionPair(*p) for p in self.pairs
        ]

    def __len__(self) -> int:
        return len(self.pairs)

    def __getitem__(self, layer: int) -> PrecisionPair:
        return self.pairs[layer]

    @property
    def equivalent_bits(self) -> float:
        """f_m(P) = sum(P) / 2L  (paper eq. 4)."""
        if not self.pairs:
            return 0.0
        return sum(p.k_bits + p.v_bits for p in self.pairs) / (2 * len(self.pairs))

    @property
    def name(self) -> str:
        return f"KVTuner-C{self.equivalent_bits:.2f}"

    @classmethod
    def uniform(cls, num_layers: int, pair: PrecisionPair, mode: str = MODE_PER_TOKEN,
                model_name: str = "") -> "KVTunerSchedule":
        return cls([pair] * num_layers, mode=mode, model_name=model_name)

    @classmethod
    def from_groups(cls, num_layers: int, groups: Sequence[Sequence[int]],
                    group_pairs: Sequence[PrecisionPair], mode: str = MODE_PER_TOKEN,
                    model_name: str = "") -> "KVTunerSchedule":
        """Expand a per-group assignment (the MOO decision vector) to per-layer."""
        pairs: list[PrecisionPair | None] = [None] * num_layers
        for gids, pair in zip(groups, group_pairs):
            for layer in gids:
                if pairs[layer] is not None:
                    raise ValueError(f"layer {layer} assigned twice")
                pairs[layer] = pair
        missing = [i for i, p in enumerate(pairs) if p is None]
        if missing:
            raise ValueError(f"layers without precision assignment: {missing}")
        return cls(pairs, mode=mode, model_name=model_name,
                   groups=[list(g) for g in groups])

    # ---------------------------------------------------------------- io
    def to_json(self) -> str:
        return json.dumps({
            "model_name": self.model_name,
            "mode": self.mode,
            "pairs": [p.astuple() for p in self.pairs],
            "groups": self.groups,
            "objectives": self.objectives,
            "equivalent_bits": self.equivalent_bits,
        }, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "KVTunerSchedule":
        d = json.loads(text)
        sched = cls([PrecisionPair(*p) for p in d["pairs"]], mode=d["mode"],
                    model_name=d.get("model_name", ""), groups=d.get("groups"))
        sched.objectives = d.get("objectives")
        return sched

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path) -> "KVTunerSchedule":
        with open(path) as f:
            return cls.from_json(f.read())

    # ------------------------------------------------------------ arrays
    def bits_array(self):
        """[L, 2] float array of (k_bits, v_bits) — feeds the dynamic-bits
        fake-quant simulation path (single jit for any schedule)."""
        import numpy as np

        return np.asarray([[p.k_bits, p.v_bits] for p in self.pairs], dtype=np.float32)


def pareto_front(points: Iterable[tuple[float, ...]]) -> list[int]:
    """Indices of non-dominated points, minimizing every objective."""
    pts = list(points)
    keep = []
    for i, p in enumerate(pts):
        dominated = False
        for j, q in enumerate(pts):
            if j == i:
                continue
            if all(qi <= pi for qi, pi in zip(q, p)) and any(qi < pi for qi, pi in zip(q, p)):
                dominated = True
                break
        if not dominated:
            keep.append(i)
    return keep
