"""NSGA-II multi-objective search over grouped layer-wise precision pairs.

Replaces the paper's Optuna/MOEA-D (unavailable offline) for problem (4):

    min_P ( f_m(P), f_a(P) )   s.t.  f_m(P) ≤ M

Decision vector: one candidate-pair index per clustered layer group. Both
objectives are minimized: f_m = equivalent bits, f_a = accuracy loss (or NLL
increase) on the calibration set. Evaluations are memoized — the evaluator is
a single jitted fake-quant forward, so a 200-candidate search needs no
retracing (repro.core.quant.fake_quant_dynamic).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np


@dataclasses.dataclass
class MOOResult:
    genotypes: list[tuple[int, ...]]
    objectives: np.ndarray          # [N, 2] (bits, acc_loss)
    front: list[int]                # indices of the final Pareto frontier
    history: list[dict]             # per-generation stats
    evaluations: int = 0


def non_dominated_sort(obj: np.ndarray) -> list[np.ndarray]:
    """Fast non-dominated sort; returns list of fronts (index arrays)."""
    n = obj.shape[0]
    dominates = (obj[:, None, :] <= obj[None, :, :]).all(-1) & \
        (obj[:, None, :] < obj[None, :, :]).any(-1)
    dom_count = dominates.sum(0)  # how many dominate i
    fronts = []
    current = np.where(dom_count == 0)[0]
    assigned = np.zeros(n, bool)
    while current.size:
        fronts.append(current)
        assigned[current] = True
        dom_count = dom_count - dominates[current].sum(0)
        dom_count[assigned] = 1 << 30
        current = np.where(dom_count == 0)[0]
    return fronts


def crowding_distance(obj: np.ndarray) -> np.ndarray:
    n, m = obj.shape
    if n <= 2:
        return np.full(n, np.inf)
    dist = np.zeros(n)
    for k in range(m):
        order = np.argsort(obj[:, k])
        lo, hi = obj[order[0], k], obj[order[-1], k]
        dist[order[0]] = dist[order[-1]] = np.inf
        if hi - lo < 1e-12:
            continue
        dist[order[1:-1]] += (obj[order[2:], k] - obj[order[:-2], k]) / (hi - lo)
    return dist


class NSGA2:
    """Integer-vector NSGA-II with memoized evaluations."""

    def __init__(self, arity: Sequence[int],
                 evaluate: Callable[[tuple[int, ...]], tuple[float, float]],
                 pop_size: int = 32, mutation_rate: float | None = None,
                 max_bits: float | None = None, seed: int = 0):
        self.arity = list(arity)
        self.evaluate_fn = evaluate
        self.pop = pop_size
        self.mut = mutation_rate or max(1.0 / len(arity), 0.1)
        self.max_bits = max_bits
        self.rng = np.random.default_rng(seed)
        self._cache: dict[tuple[int, ...], tuple[float, float]] = {}
        self.history: list[dict] = []

    # ------------------------------------------------------------ helpers
    def _eval(self, g: tuple[int, ...]) -> tuple[float, float]:
        if g not in self._cache:
            bits, loss = self.evaluate_fn(g)
            if self.max_bits is not None and bits > self.max_bits:
                loss = loss + 10.0 * (bits - self.max_bits)  # soft constraint
            self._cache[g] = (float(bits), float(loss))
        return self._cache[g]

    def _random(self) -> tuple[int, ...]:
        return tuple(int(self.rng.integers(a)) for a in self.arity)

    def _mutate(self, g: tuple[int, ...]) -> tuple[int, ...]:
        out = list(g)
        for i, a in enumerate(self.arity):
            if self.rng.random() < self.mut and a > 1:
                out[i] = int(self.rng.integers(a))
        return tuple(out)

    def _crossover(self, a, b) -> tuple[int, ...]:
        take = self.rng.random(len(a)) < 0.5
        return tuple(int(x if t else y) for x, y, t in zip(a, b, take))

    # --------------------------------------------------------------- main
    def run(self, generations: int = 12,
            seeds: Sequence[tuple[int, ...]] = ()) -> MOOResult:
        pop = list(dict.fromkeys(list(seeds) +
                                 [self._random() for _ in range(self.pop)]))[:self.pop]
        while len(pop) < self.pop:
            pop.append(self._random())
        for gen in range(generations):
            obj = np.asarray([self._eval(g) for g in pop])
            fronts = non_dominated_sort(obj)
            rank = np.zeros(len(pop), int)
            for fi, f in enumerate(fronts):
                rank[f] = fi
            crowd = np.zeros(len(pop))
            for f in fronts:
                crowd[f] = crowding_distance(obj[f])

            def tournament():
                i, j = self.rng.integers(len(pop), size=2)
                if rank[i] != rank[j]:
                    return pop[i] if rank[i] < rank[j] else pop[j]
                return pop[i] if crowd[i] >= crowd[j] else pop[j]

            children = []
            while len(children) < self.pop:
                c = self._crossover(tournament(), tournament())
                children.append(self._mutate(c))
            union = list(dict.fromkeys(pop + children))
            uobj = np.asarray([self._eval(g) for g in union])
            ufronts = non_dominated_sort(uobj)
            new_pop: list[tuple[int, ...]] = []
            for f in ufronts:
                if len(new_pop) + len(f) <= self.pop:
                    new_pop.extend(union[i] for i in f)
                else:
                    cd = crowding_distance(uobj[f])
                    order = f[np.argsort(-cd)]
                    new_pop.extend(union[i] for i in
                                   order[: self.pop - len(new_pop)])
                    break
            pop = new_pop
            front0 = ufronts[0]
            self.history.append({
                "gen": gen, "evals": len(self._cache),
                "front_size": len(front0),
                "best_loss": float(uobj[front0][:, 1].min()),
                "min_bits": float(uobj[front0][:, 0].min()),
            })

        genos = list(self._cache.keys())
        objs = np.asarray([self._cache[g] for g in genos])
        front = non_dominated_sort(objs)[0]
        return MOOResult(genotypes=genos, objectives=objs,
                         front=[int(i) for i in front], history=self.history,
                         evaluations=len(self._cache))
