"""Layer-wise KV quantization sensitivity analysis (paper §3.2, §4, App. B).

Pipeline: run the model on calibration prompts with activation **capture**
(per-layer post-rope Q/K/V and attention output), then **simulate** every
candidate (quant-mode × precision-pair) offline — quantize+dequantize the
captured K/V and recompute attention, *without* error accumulation — yielding
the four error metrics of §3.2:

  e_k, e_v : mean relative KV reconstruction error
  e_a      : mean absolute attention-score error
  e_o      : mean relative attention-output error  (the pruning metric)

The paper's finding that these profiles are prompt-independent model
properties (§4.5) is what licenses offline search; tests + benchmarks verify
it empirically on our trained models.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.precision import (CANDIDATE_PAIRS, MODE_KIVI, MODE_PER_CHANNEL,
                                  MODE_PER_TOKEN, PrecisionPair)


@dataclasses.dataclass
class LayerErrors:
    """Per-(layer × pair) error table for one quant mode."""

    mode: str
    pairs: list[PrecisionPair]
    e_k: np.ndarray  # [L, P]
    e_v: np.ndarray
    e_a: np.ndarray
    e_o: np.ndarray

    def profile(self) -> np.ndarray:
        """[L, P] sensitivity profile used for inter-layer clustering
        (relative attention output errors, paper §5.3)."""
        return self.e_o


def capture_activations(api, params, batches: list[dict]) -> list[dict]:
    """Run calibration prompts with per-attention-layer capture.

    Returns one dict per attention layer: {"q","k","v","o"} with tensors
    concatenated over prompts ([B*, S, H, hd] layout from attention.py).
    """
    per_batch = []
    for batch in batches:
        cap: dict = {}
        api.forward(params, batch, capture=cap)
        per_batch.append(cap)
    layers = sorted(per_batch[0].keys())
    out = []
    for l in layers:
        merged = {k: jnp.concatenate([c[l][k] for c in per_batch], axis=0)
                  for k in ("q", "k", "v", "o")}
        out.append(merged)
    return out


def _attn_with_kv(q, k, v, q_per_kv: int):
    """Reference attention recomputation on captured tensors.
    q [B,S,H,hd], k/v [B,S,Hkv,hd] → (scores [B,H,S,S] f32, out [B,S,H,hd])."""
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    qg = q.reshape(b, s, hkv, q_per_kv, hd).astype(jnp.float32)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    scores = scores.reshape(b, h, s, s) / jnp.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    pg = p.reshape(b, hkv, q_per_kv, s, s)
    out = jnp.einsum("bkgqs,bskh->bqkgh", pg, v.astype(jnp.float32))
    return p, out.reshape(b, s, h, hd)


def _errors_one_impl(q, k, v, k_bits, v_bits, kc: bool, vc: bool,
                     q_per_kv: int, group_size: int):
    """Dynamic-bits single-layer error computation (one jit for all pairs)."""
    k_mode = MODE_PER_CHANNEL if kc else MODE_PER_TOKEN
    v_mode = MODE_PER_CHANNEL if vc else MODE_PER_TOKEN
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    k_hat = quant.fake_quant_dynamic(kt, k_bits, k_mode, group_size).transpose(0, 2, 1, 3)
    v_hat = quant.fake_quant_dynamic(vt, v_bits, v_mode, group_size).transpose(0, 2, 1, 3)
    a_ref, o_ref = _attn_with_kv(q, k, v, q_per_kv)
    a_hat, o_hat = _attn_with_kv(q, k_hat, v_hat, q_per_kv)
    return (quant.relative_error(k, k_hat), quant.relative_error(v, v_hat),
            quant.absolute_error(a_ref, a_hat), quant.relative_error(o_ref, o_hat))


_errors_one = jax.jit(_errors_one_impl,
                      static_argnames=("kc", "vc", "q_per_kv", "group_size"))


def layer_errors(captures: list[dict], cfg, mode: str = MODE_PER_TOKEN,
                 pairs=CANDIDATE_PAIRS) -> LayerErrors:
    """Simulated per-layer errors for every candidate pair (paper Table 9 /
    Fig. 3 reproduction)."""
    kc = mode in (MODE_PER_CHANNEL, MODE_KIVI)
    vc = mode == MODE_PER_CHANNEL
    L, P = len(captures), len(pairs)
    tabs = {m: np.zeros((L, P)) for m in ("e_k", "e_v", "e_a", "e_o")}
    for li, cap in enumerate(captures):
        for pi, pair in enumerate(pairs):
            ek, ev, ea, eo = _errors_one(
                cap["q"], cap["k"], cap["v"],
                jnp.float32(pair.k_bits), jnp.float32(pair.v_bits),
                kc=kc, vc=vc, q_per_kv=cfg.q_per_kv,
                group_size=cfg.kv_group_size)
            tabs["e_k"][li, pi] = float(ek)
            tabs["e_v"][li, pi] = float(ev)
            tabs["e_a"][li, pi] = float(ea)
            tabs["e_o"][li, pi] = float(eo)
    return LayerErrors(mode=mode, pairs=list(pairs), **tabs)


def model_errors(errors: LayerErrors) -> dict[str, np.ndarray]:
    """Layer-averaged error per pair (paper Table 9 rows)."""
    return {m: getattr(errors, m).mean(axis=0) for m in ("e_k", "e_v", "e_a", "e_o")}


def attention_pattern_stats(captures: list[dict], q_per_kv: int) -> np.ndarray:
    """Per-layer attention *sparsity* (mean max attention weight): high →
    concentrated/streaming heads, robust to quantization (Lemma 1); low →
    retrieval heads, sensitive. Used to validate §4.4's correlation."""
    out = []
    for cap in captures:
        p, _ = _attn_with_kv(cap["q"], cap["k"], cap["v"], q_per_kv)
        out.append(float(jnp.mean(jnp.max(p, axis=-1))))
    return np.asarray(out)
