"""KVTuner: the end-to-end offline tuning pipeline (paper Fig. 1).

    capture → layer_errors → intra-layer pruning → inter-layer clustering
            → NSGA-II over group assignments (accuracy × memory)
            → Pareto frontier of KVTunerSchedules (saved as JSON)

Online serving loads a schedule and pays zero decision overhead — precision is
static per layer (repro.serving / repro.cache).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sensitivity
from repro.core.clustering import LayerGroups, cluster_layers
from repro.core.moo import MOOResult, NSGA2
from repro.core.precision import (CANDIDATE_PAIRS, MODE_PER_TOKEN,
                                  KVTunerSchedule, PrecisionPair)
from repro.core.pruning import PrunedSpace, prune_intra_layer


@dataclasses.dataclass
class TunerReport:
    mode: str
    errors: sensitivity.LayerErrors
    pruned: PrunedSpace
    groups: LayerGroups
    moo: MOOResult | None
    frontier: list[KVTunerSchedule]

    def space_reduction(self) -> tuple[float, float, float]:
        """(full 9^L, after pruning Π|S_p|, after clustering Π over groups)."""
        L = self.pruned.num_layers
        return (float(len(CANDIDATE_PAIRS)) ** L, self.pruned.space_size(),
                self.groups.search_space_size())

    def best_under_bits(self, max_bits: float) -> KVTunerSchedule | None:
        ok = [s for s in self.frontier if s.equivalent_bits <= max_bits + 1e-9]
        if not ok:
            return None
        return min(ok, key=lambda s: s.objectives["loss"])


def make_sim_evaluator(api, params, batches: Sequence[dict],
                       metric: Callable | None = None,
                       mode: str = MODE_PER_TOKEN):
    """Accuracy evaluator over the calibration set: a single jitted forward
    with traced per-layer bits (no retrace per candidate schedule).

    ``metric(logits, batch) -> scalar loss`` defaults to next-token NLL.
    Returns fn(bits_array [L_attn, 2]) -> float loss (lower = better).
    """
    def default_metric(logits, batch):
        from repro.models import common
        mask = batch.get("loss_mask")
        return common.softmax_cross_entropy(
            logits[:, :-1], batch["tokens"][:, 1:],
            None if mask is None else mask[:, 1:])

    metric = metric or default_metric

    @jax.jit
    def one(bits, batch):
        logits, _ = api.forward(params, batch, sim_bits=bits, sim_mode=mode)
        return metric(logits, batch)

    def evaluate(bits_array: np.ndarray) -> float:
        bits = jnp.asarray(bits_array, jnp.float32)
        vals = [float(one(bits, b)) for b in batches]
        return float(np.mean(vals))

    return evaluate


class KVTuner:
    """Adaptive layer-wise mixed-precision KV quantization tuner."""

    def __init__(self, api, params, mode: str = MODE_PER_TOKEN,
                 pairs=CANDIDATE_PAIRS, group_eps: float = 0.05):
        self.api = api
        self.params = params
        self.mode = mode
        self.pairs = list(pairs)
        self.group_eps = group_eps

    # ------------------------------------------------------- offline stages
    def analyze(self, calib_batches: Sequence[dict]) -> tuple[
            sensitivity.LayerErrors, PrunedSpace, LayerGroups]:
        caps = sensitivity.capture_activations(self.api, self.params,
                                               list(calib_batches))
        errors = sensitivity.layer_errors(caps, self.api.cfg, self.mode,
                                          self.pairs)
        pruned = prune_intra_layer(errors)
        groups = cluster_layers(pruned, eps=self.group_eps)
        return errors, pruned, groups

    def search(self, calib_batches: Sequence[dict],
               eval_batches: Sequence[dict] | None = None,
               metric: Callable | None = None, generations: int = 12,
               pop_size: int = 32, max_bits: float | None = None,
               seed: int = 0) -> TunerReport:
        errors, pruned, groups = self.analyze(calib_batches)
        evaluator = make_sim_evaluator(
            self.api, self.params, list(eval_batches or calib_batches),
            metric=metric, mode=self.mode)
        n_attn = len(self.api.cfg.attention_layers())

        def geno_to_bits(g: tuple[int, ...]) -> np.ndarray:
            bits = np.zeros((n_attn, 2), np.float32)
            for gi, choice in enumerate(g):
                pair = self.pairs[groups.candidates[gi][choice]]
                for layer in groups.groups[gi]:
                    bits[layer] = (pair.k_bits, pair.v_bits)
            return bits

        def geno_to_schedule(g: tuple[int, ...]) -> KVTunerSchedule:
            return KVTunerSchedule.from_groups(
                n_attn, groups.groups,
                [self.pairs[groups.candidates[gi][c]] for gi, c in enumerate(g)],
                mode=self.mode, model_name=self.api.cfg.name)

        def evaluate(g: tuple[int, ...]) -> tuple[float, float]:
            bits = geno_to_bits(g)
            return float(bits.mean()), evaluator(bits)

        # seed with the uniform schedules expressible in every group
        seeds = []
        for pair in (PrecisionPair(8, 8), PrecisionPair(8, 4),
                     PrecisionPair(4, 4), PrecisionPair(4, 2)):
            try:
                g = tuple(cand.index(self.pairs.index(pair))
                          for cand in groups.candidates)
                seeds.append(g)
            except ValueError:
                pass

        nsga = NSGA2([len(c) for c in groups.candidates], evaluate,
                     pop_size=pop_size, max_bits=max_bits, seed=seed)
        result = nsga.run(generations=generations, seeds=seeds)

        frontier = []
        for idx in sorted(result.front,
                          key=lambda i: result.objectives[i][0]):
            sched = geno_to_schedule(result.genotypes[idx])
            sched.objectives = {"bits": float(result.objectives[idx][0]),
                                "loss": float(result.objectives[idx][1])}
            frontier.append(sched)
        return TunerReport(mode=self.mode, errors=errors, pruned=pruned,
                           groups=groups, moo=result, frontier=frontier)
