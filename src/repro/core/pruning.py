"""Intra-layer KV precision-pair pruning (paper §5.3, Table 4).

Per layer, keep only pairs on the Pareto frontier of
(equivalent bits  ↓, relative attention output error e_o ↓). The paper finds
the "key-first" set {KV8, K8V4, KV4, K4V2, KV2} survives for most layers under
per-token-asym, with first/last layers and per-channel modes preferring
value-first pairs — our benchmarks reproduce this structure.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.precision import PrecisionPair, pareto_front
from repro.core.sensitivity import LayerErrors


@dataclasses.dataclass
class PrunedSpace:
    """Per-layer surviving candidate pairs + their e_o (the clustering metric)."""

    pairs: list[PrecisionPair]            # full candidate list (column order)
    keep: list[list[int]]                 # per layer: indices into `pairs`
    e_o: np.ndarray                       # [L, P] full table (for clustering)

    def layer_candidates(self, layer: int) -> list[PrecisionPair]:
        return [self.pairs[i] for i in self.keep[layer]]

    def candidate_key(self, layer: int) -> tuple[int, ...]:
        """Hashable id of the layer's surviving set — the paper's first
        grouping criterion (layers sharing a candidate set cluster together)."""
        return tuple(self.keep[layer])

    @property
    def num_layers(self) -> int:
        return len(self.keep)

    def space_size(self) -> float:
        out = 1.0
        for k in self.keep:
            out *= len(k)
        return out


def prune_intra_layer(errors: LayerErrors, always_keep_fp16: bool = False,
                      eps: float = 1e-6) -> PrunedSpace:
    """Pareto-prune (bits, e_o) per layer.

    ``eps`` merges numerically-tied errors so strictly-dominated duplicates
    drop (float noise between e.g. KV8 and K8V4 at tiny calibration sets).
    """
    pairs = errors.pairs
    bits = np.asarray([p.equivalent_bits for p in pairs])
    keep: list[list[int]] = []
    for l in range(errors.e_o.shape[0]):
        eo = errors.e_o[l]
        pts = [(bits[i], round(float(eo[i]) / eps) * eps) for i in range(len(pairs))]
        front = pareto_front(pts)
        keep.append(sorted(front, key=lambda i: -bits[i]))
    return PrunedSpace(pairs=list(pairs), keep=keep, e_o=errors.e_o.copy())
