"""Round-to-nearest asymmetric KV cache quantization (paper §3.2, eq. 2).

Two families of entry points:

* **Deployment path** (static bits, packed storage): ``quantize`` →
  ``QuantizedTensor`` (uint8 codes packed along head_dim + per-group scale/zero)
  → ``dequantize``. Bits are compile-time constants per layer, which is the
  property KVTuner exploits for static-graph/TPU friendliness.

* **Simulation path** (``fake_quant`` / ``fake_quant_dynamic``): quantize +
  dequantize in one shot without packing. The *dynamic* variant takes bits as a
  traced array so a single jitted computation can evaluate **any** layer-wise
  schedule — this is what makes the NSGA-II search cheap (no retrace per
  candidate), mirroring the paper's offline "simulated quantization" calibration
  (Appendix B).

Modes (paper §4.2):
* per-token-asym: one (scale, zero) per token, reduced over head_dim groups.
* per-channel-asym: one (scale, zero) per channel, reduced over token groups
  (KIVI's key mode — exploits the strong channel-wise outliers of keys).

Tensor convention: KV tensors are ``[..., S, D]`` (sequence, head_dim); leading
axes are batch/heads.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.precision import MODE_PER_CHANNEL, MODE_PER_TOKEN

_EPS = 1e-8


# ----------------------------------------------------------------- packing
def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """Pack uint8 codes in [0, 2^bits) into uint8 along the last axis.

    2-bit → 4 codes/byte, 4-bit → 2 codes/byte, 8-bit → identity. The last
    axis (head_dim) must be divisible by ``8 // bits``.
    """
    if bits == 8:
        return codes.astype(jnp.uint8)
    if bits not in (2, 4):
        raise ValueError(f"cannot pack bits={bits}")
    vpb = 8 // bits  # values per byte
    d = codes.shape[-1]
    if d % vpb:
        raise ValueError(f"last dim {d} not divisible by {vpb} (bits={bits})")
    grouped = codes.reshape(*codes.shape[:-1], d // vpb, vpb).astype(jnp.uint32)
    shifts = (jnp.arange(vpb, dtype=jnp.uint32) * bits)
    packed = jnp.sum(grouped << shifts, axis=-1)
    return packed.astype(jnp.uint8)


def unpack_codes(packed: jax.Array, bits: int) -> jax.Array:
    """Inverse of :func:`pack_codes`; returns uint8 codes."""
    if bits == 8:
        return packed.astype(jnp.uint8)
    vpb = 8 // bits
    mask = jnp.uint32((1 << bits) - 1)
    shifts = (jnp.arange(vpb, dtype=jnp.uint32) * bits)
    codes = (packed.astype(jnp.uint32)[..., None] >> shifts) & mask
    return codes.reshape(*packed.shape[:-1], packed.shape[-1] * vpb).astype(jnp.uint8)


# ----------------------------------------------------------- scale/zero math
def _group_reshape(x: jax.Array, axis: int, group_size: int):
    """Split ``axis`` into (n_groups, group_size). Returns reshaped array and
    the positional index of the group_size axis."""
    axis = axis % x.ndim
    n = x.shape[axis]
    g = min(group_size, n) if group_size > 0 else n
    if n % g:
        raise ValueError(f"axis size {n} not divisible by group size {g}")
    new_shape = x.shape[:axis] + (n // g, g) + x.shape[axis + 1:]
    return x.reshape(new_shape), axis + 1


def compute_scale_zero(x: jax.Array, bits, axis: int, group_size: int):
    """Asymmetric (scale, zero) over groups along ``axis``.

    z = min(X), s = (max(X) - min(X)) / (2^B - 1)      (paper eq. 2)

    ``bits`` may be a python int or a traced array (dynamic path). Returned
    scale/zero have the group axis reduced to n_groups (keepdims within the
    reshaped view).
    """
    xg, gaxis = _group_reshape(x.astype(jnp.float32), axis, group_size)
    mn = jnp.min(xg, axis=gaxis, keepdims=True)
    mx = jnp.max(xg, axis=gaxis, keepdims=True)
    levels = jnp.asarray(2.0, dtype=jnp.float32) ** bits - 1.0
    scale = jnp.maximum((mx - mn) / levels, _EPS)
    return scale, mn, xg, gaxis


def _mode_axis(mode: str) -> int:
    # [..., S, D]: per-token reduces over D (-1); per-channel over S (-2).
    if mode == MODE_PER_TOKEN:
        return -1
    if mode == MODE_PER_CHANNEL:
        return -2
    raise ValueError(f"unknown quant mode {mode!r} (KIVI resolves to per-mode per K/V)")


# ------------------------------------------------------------- deployment
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """Packed quantized tensor + dequantization metadata.

    ``codes`` is uint8, packed along head_dim. ``scale``/``zero`` are float32
    with a broadcastable grouped shape. Static (aux) fields make the layout a
    stable pytree so it can live inside jitted cache state.
    """

    codes: jax.Array
    scale: jax.Array
    zero: jax.Array
    bits: int = dataclasses.field(metadata=dict(static=True))
    mode: str = dataclasses.field(metadata=dict(static=True))
    group_size: int = dataclasses.field(metadata=dict(static=True))
    orig_shape: tuple = dataclasses.field(metadata=dict(static=True))
    orig_dtype: jnp.dtype = dataclasses.field(metadata=dict(static=True))

    @property
    def packed_bytes(self) -> int:
        import numpy as np

        return int(np.prod(self.codes.shape)) + 8 * int(np.prod(self.scale.shape))


def quantize(x: jax.Array, bits: int, mode: str = MODE_PER_TOKEN,
             group_size: int = 32) -> QuantizedTensor:
    """Quantize to packed codes (static-bits deployment path)."""
    if bits == 16:
        raise ValueError("bits=16 means no quantization; keep the raw tensor")
    axis = _mode_axis(mode)
    scale, zero, xg, gaxis = compute_scale_zero(x, bits, axis, group_size)
    q = jnp.round((xg.astype(jnp.float32) - zero) / scale)
    q = jnp.clip(q, 0, 2 ** bits - 1).astype(jnp.uint8)
    q = q.reshape(x.shape)
    # scale/zero keep the grouped shape (broadcastable after a reshape in dequant)
    return QuantizedTensor(
        codes=pack_codes(q, bits), scale=scale, zero=zero, bits=bits, mode=mode,
        group_size=group_size, orig_shape=tuple(x.shape), orig_dtype=x.dtype)


def dequantize(qt: QuantizedTensor) -> jax.Array:
    """X̂ = Q(X) · s + z  (paper eq. 2)."""
    codes = unpack_codes(qt.codes, qt.bits).astype(jnp.float32)
    axis = _mode_axis(qt.mode)
    cg, gaxis = _group_reshape(codes, axis, qt.group_size)
    xhat = cg * qt.scale + qt.zero
    return xhat.reshape(qt.orig_shape).astype(qt.orig_dtype)


# ------------------------------------------------------------- simulation
def fake_quant(x: jax.Array, bits: int, mode: str = MODE_PER_TOKEN,
               group_size: int = 32) -> jax.Array:
    """Static-bits quantize→dequantize without packing (for error metrics)."""
    if bits >= 16:
        return x
    axis = _mode_axis(mode)
    scale, zero, xg, gaxis = compute_scale_zero(x, bits, axis, group_size)
    q = jnp.clip(jnp.round((xg.astype(jnp.float32) - zero) / scale), 0, 2 ** bits - 1)
    return (q * scale + zero).reshape(x.shape).astype(x.dtype)


def fake_quant_dynamic(x: jax.Array, bits: jax.Array, mode: str = MODE_PER_TOKEN,
                       group_size: int = 32) -> jax.Array:
    """Traced-bits fake quantization: `bits` is a scalar array.

    One jitted graph evaluates any precision; `bits >= 16` passes through.
    This powers the search loop over layer-wise schedules.
    """
    axis = _mode_axis(mode)
    bits_f = jnp.asarray(bits, dtype=jnp.float32)
    scale, zero, xg, gaxis = compute_scale_zero(x, bits_f, axis, group_size)
    levels = 2.0 ** bits_f - 1.0
    q = jnp.clip(jnp.round((xg.astype(jnp.float32) - zero) / scale), 0.0, levels)
    out = (q * scale + zero).reshape(x.shape).astype(x.dtype)
    return jnp.where(bits_f >= 16.0, x, out)


def fake_quant_kv_dynamic(k: jax.Array, v: jax.Array, k_bits: jax.Array,
                          v_bits: jax.Array, mode: str, group_size: int = 32):
    """Apply the layer's (k_bits, v_bits) pair; `mode` may be 'kivi' which
    resolves to per-channel keys + per-token values (paper §4.2)."""
    from repro.core.precision import MODE_KIVI

    if mode == MODE_KIVI:
        k_mode, v_mode = MODE_PER_CHANNEL, MODE_PER_TOKEN
    else:
        k_mode = v_mode = mode
    k_hat = fake_quant_dynamic(k, k_bits, k_mode, group_size)
    v_hat = fake_quant_dynamic(v, v_bits, v_mode, group_size)
    return k_hat, v_hat


# ------------------------------------------------------------- error metrics
def relative_error(x: jax.Array, x_hat: jax.Array) -> jax.Array:
    """Relative error Σ|X - X̂| / Σ|X| (paper §3.2 e_k / e_v / e_o).

    Norm-ratio form rather than mean elementwise ratio: attention outputs have
    near-zero entries that make the elementwise ratio diverge; the norm ratio
    reproduces the paper's Table 9 magnitudes (KV8 ≈ 1e-2, KV2 ≈ 0.6-0.9)."""
    x = x.astype(jnp.float32)
    x_hat = x_hat.astype(jnp.float32)
    return jnp.sum(jnp.abs(x - x_hat)) / jnp.maximum(jnp.sum(jnp.abs(x)), _EPS)


def absolute_error(x: jax.Array, x_hat: jax.Array) -> jax.Array:
    """mean(|X - X̂|) — used for attention scores e_a (already normalized)."""
    return jnp.mean(jnp.abs(x - x_hat))


def kv_cache_bytes(shape, bits: int, group_size: int = 32) -> int:
    """Bytes for one quantized [..., S, D] tensor incl. scale/zero overhead
    (fp16 scale + fp16 zero per group). Used by the throughput roofline."""
    import numpy as np

    n = int(np.prod(shape))
    groups = n // min(group_size, shape[-1])
    return n * bits // 8 + groups * 4
