"""Inter-layer clustering (paper §5.3, §D.1.2): DBSCAN over layer sensitivity
profiles, applied *within* groups of layers that share the same pruned
candidate set. Own DBSCAN implementation (eps=0.05, min_samples=2 defaults
matching the paper; sklearn is unavailable offline).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.pruning import PrunedSpace


def dbscan(x: np.ndarray, eps: float = 0.05, min_samples: int = 2) -> np.ndarray:
    """Labels [N]; -1 = noise (each noise point later becomes its own group).
    Plain O(N²) density clustering — N is the layer count (≤ 95 here)."""
    n = x.shape[0]
    d = np.linalg.norm(x[:, None, :] - x[None, :, :], axis=-1)
    neighbors = [np.where(d[i] <= eps)[0] for i in range(n)]
    core = np.asarray([len(nb) >= min_samples for nb in neighbors])
    labels = np.full(n, -2)
    cluster = 0
    for i in range(n):
        if labels[i] != -2 or not core[i]:
            continue
        labels[i] = cluster
        stack = list(neighbors[i])
        while stack:
            j = stack.pop()
            if labels[j] == -1:
                labels[j] = cluster
            if labels[j] != -2:
                continue
            labels[j] = cluster
            if core[j]:
                stack.extend(neighbors[j])
        cluster += 1
    labels[labels == -2] = -1
    return labels


@dataclasses.dataclass
class LayerGroups:
    """Clustered layer groups sharing (candidate set, sensitivity profile)."""

    groups: list[list[int]]               # layer ids per group
    candidates: list[list[int]]           # per group: indices into pruned.pairs
    pruned: PrunedSpace

    @property
    def num_groups(self) -> int:
        return len(self.groups)

    def search_space_size(self) -> float:
        out = 1.0
        for c in self.candidates:
            out *= len(c)
        return out


def cluster_layers(pruned: PrunedSpace, eps: float = 0.05,
                   min_samples: int = 2, normalize: bool = True) -> LayerGroups:
    """Two-step grouping:
    1. partition layers by identical pruned candidate sets;
    2. DBSCAN within each partition on the e_o sensitivity profile.
    Noise points become singleton groups (a layer more sensitive than its
    peers keeps its own precision decision — paper §6.5's "crucial groups").
    """
    by_key: dict[tuple, list[int]] = {}
    for l in range(pruned.num_layers):
        by_key.setdefault(pruned.candidate_key(l), []).append(l)

    groups: list[list[int]] = []
    candidates: list[list[int]] = []
    for key, layers in sorted(by_key.items()):
        prof = pruned.e_o[layers]  # [n, P]
        if normalize and prof.max() > 0:
            prof = prof / (prof.max(axis=0, keepdims=True) + 1e-12)
        if len(layers) == 1:
            labels = np.asarray([-1])
        else:
            labels = dbscan(prof, eps=eps, min_samples=min_samples)
        for c in sorted(set(labels.tolist())):
            members = [layers[i] for i in np.where(labels == c)[0]]
            if c == -1:
                for m in members:  # noise → singletons
                    groups.append([m])
                    candidates.append(list(key))
            else:
                groups.append(members)
                candidates.append(list(key))
    order = np.argsort([g[0] for g in groups])
    return LayerGroups(groups=[groups[i] for i in order],
                       candidates=[candidates[i] for i in order],
                       pruned=pruned)
