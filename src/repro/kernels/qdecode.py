"""Pallas TPU kernel: fused dequantization + flash decode attention over the
packed mixed-precision KV main segment.

This is the paper's hot spot mapped to TPU (DESIGN.md §3): decode attention is
HBM-bandwidth-bound; quantized KV reduces the bytes streamed, and fusing
dequant into the online-softmax loop means the bf16 K/V never materialize in
HBM. Each KVTuner layer gets a **static** (k_bits, v_bits) specialization —
coarse-grained per-layer precision keeps the kernel free of dynamic control
flow, unlike token-level methods (QAQ/MiKV) that cannot avoid it.

Geometry per grid step (b, h_kv, s_blk):
  q tile      [G, D]       (G = query heads per kv head, MXU lhs)
  K codes     [S_blk, D·kb/8] uint8 → unpack+dequant in VMEM → [S_blk, D]
  scores      [G, S_blk]   (MXU), online-softmax into VMEM scratch acc [G, D]
S_blk = 128 rows; D (lanes) is 64–256 for the assigned archs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.precision import MODE_PER_CHANNEL
from repro.kernels.runtime import resolve_interpret

DEFAULT_BLOCK_S = 128
NEG = -1e30


def pick_block_s(s: int, requested: int, group_size: int) -> int:
    """Largest group-aligned divisor of ``s`` that is <= ``requested``.

    The dense kernel tiles the main segment in ``block_s`` rows; the tile must
    divide ``s`` (the grid has no partial steps) and stay group-aligned (so a
    tile never straddles a quantization group). ``min(requested, s)`` alone
    breaks for valid lengths like s=192 with the default 128-row tile.
    """
    if s % group_size:
        raise ValueError(f"segment length {s} not a multiple of the quant "
                         f"group size {group_size}")
    bs = max(min(requested, s) // group_size * group_size, group_size)
    while s % bs:
        bs -= group_size
    return bs


def _unpack_lanes(packed: jax.Array, bits: int, d: int) -> jax.Array:
    if bits == 8:
        return packed.astype(jnp.uint8)
    vpb = 8 // bits
    mask = jnp.uint32((1 << bits) - 1)
    shifts = jnp.arange(vpb, dtype=jnp.uint32) * bits
    s = packed.shape[0]
    codes = (packed.astype(jnp.uint32)[..., None] >> shifts) & mask
    return codes.reshape(s, d).astype(jnp.uint8)


def _dequant_block(codes_ref, scale_ref, zero_ref, bits, mode, group_size, d):
    """→ [S_blk, D] f32 from one VMEM-resident packed block."""
    if bits >= 16:
        return codes_ref[0, 0].astype(jnp.float32)
    raw = _unpack_lanes(codes_ref[0, 0], bits, d).astype(jnp.float32)
    s_blk = raw.shape[0]
    if mode == MODE_PER_CHANNEL:
        sc = scale_ref[0, 0]  # [S_blk/g, 1, D]
        z = zero_ref[0, 0]
        rg = raw.reshape(s_blk // group_size, group_size, d)
        return (rg * sc + z).reshape(s_blk, d)
    g = min(group_size, d)
    sc = scale_ref[0, 0]      # [S_blk, D/g, 1]
    z = zero_ref[0, 0]
    rg = raw.reshape(s_blk, d // g, g)
    return (rg * sc + z).reshape(s_blk, d)


def _qdecode_kernel(q_ref, kc_ref, ks_ref, kz_ref, vc_ref, vs_ref, vz_ref,
                    nv_ref, o_ref, m_ref, l_ref, acc_sc, m_sc, l_sc, *,
                    k_bits, v_bits, k_mode, v_mode, group_size, block_s,
                    num_blocks, d):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)

    q = q_ref[0, 0].astype(jnp.float32)  # [G, D]
    k = _dequant_block(kc_ref, ks_ref, kz_ref, k_bits, k_mode, group_size, d)
    scores = (q @ k.T) / jnp.sqrt(float(d))  # [G, S_blk]

    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    valid = pos < nv_ref[0, 0]
    scores = jnp.where(valid, scores, NEG)

    m_prev, l_prev = m_sc[...], l_sc[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new) * valid.astype(jnp.float32)

    v = _dequant_block(vc_ref, vs_ref, vz_ref, v_bits, v_mode, group_size, d)
    acc_sc[...] = acc_sc[...] * alpha + p @ v
    l_sc[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    m_sc[...] = m_new

    @pl.when(s_idx == num_blocks - 1)
    def _done():
        o_ref[0, 0] = acc_sc[...]
        m_ref[0, 0] = m_sc[...][:, 0]
        l_ref[0, 0] = l_sc[...][:, 0]


@functools.partial(jax.jit, static_argnames=(
    "k_bits", "v_bits", "k_mode", "v_mode", "group_size", "block_s",
    "interpret"))
def qdecode(q, k_codes, k_scale, k_zero, v_codes, v_scale, v_zero, n_valid, *,
            k_bits: int, v_bits: int, k_mode: str, v_mode: str,
            group_size: int = 32, block_s: int = DEFAULT_BLOCK_S,
            interpret: bool | None = None):
    """Fused dequant+attention over the packed main segment.

    q [B, Hkv, G, D]; codes [B, Hkv, S, D·bits/8] (raw dtype when bits=16);
    n_valid [B] i32. Returns (o [B,Hkv,G,D] f32 un-normalized, m, l) for
    softmax-merging with the residual window (repro.kernels.ref.softmax_merge).

    ``interpret=None`` resolves backend-aware: compiled on TPU, interpret
    elsewhere (repro.kernels.runtime).
    """
    interpret = resolve_interpret(interpret)
    b, hkv, g, d = q.shape
    s = k_codes.shape[2]
    block_s = pick_block_s(s, block_s, group_size)
    ns = s // block_s

    def seg_specs(bits, mode):
        cd = d if bits >= 16 else d * bits // 8
        cspec = pl.BlockSpec((1, 1, block_s, cd), lambda b_, h, j: (b_, h, j, 0))
        if bits >= 16:
            dummy = pl.BlockSpec((1,), lambda b_, h, j: (0,))
            return cspec, dummy, dummy
        if mode == MODE_PER_CHANNEL:
            sspec = pl.BlockSpec((1, 1, block_s // group_size, 1, d),
                                 lambda b_, h, j: (b_, h, j, 0, 0))
        else:
            gg = min(group_size, d)
            sspec = pl.BlockSpec((1, 1, block_s, d // gg, 1),
                                 lambda b_, h, j: (b_, h, j, 0, 0))
        return cspec, sspec, sspec

    kc_spec, ks_spec, kz_spec = seg_specs(k_bits, k_mode)
    vc_spec, vs_spec, vz_spec = seg_specs(v_bits, v_mode)

    kernel = functools.partial(
        _qdecode_kernel, k_bits=k_bits, v_bits=v_bits, k_mode=k_mode,
        v_mode=v_mode, group_size=group_size, block_s=block_s, num_blocks=ns,
        d=d)

    o, m, l = pl.pallas_call(
        kernel,
        grid=(b, hkv, ns),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h, j: (b_, h, 0, 0)),
            kc_spec, ks_spec, kz_spec, vc_spec, vs_spec, vz_spec,
            pl.BlockSpec((1, 1), lambda b_, h, j: (b_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, g), lambda b_, h, j: (b_, h, 0)),
            pl.BlockSpec((1, 1, g), lambda b_, h, j: (b_, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_codes, k_scale, k_zero, v_codes, v_scale, v_zero,
      n_valid[:, None].astype(jnp.int32))
    return o, m, l


# ===================================================================== paged
def _qdecode_paged_kernel(pt_ref, nv_ref, nr_ref, q_ref, kc_ref, ks_ref,
                          kz_ref, vc_ref, vs_ref, vz_ref, kr_ref, vr_ref,
                          o_ref, acc_sc, m_sc, l_sc, *, k_bits, v_bits,
                          k_mode, v_mode, group_size, d):
    b_idx = pl.program_id(0)
    j = pl.program_id(2)
    r = group_size
    live = (nv_ref[b_idx] + r - 1) // r  # this slot's live page count

    @pl.when(j == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)

    q = q_ref[0, 0].astype(jnp.float32)  # [G, D]

    @pl.when(j < live)
    def _main_block():
        # only in-range steps score a block: out-of-range steps' index maps
        # alias the slot's last live block (no fresh DMA) and skip compute
        k = _dequant_block(kc_ref, ks_ref, kz_ref, k_bits, k_mode,
                           group_size, d)
        scores = (q @ k.T) / jnp.sqrt(float(d))  # [G, R]
        pos = j * r + jax.lax.broadcasted_iota(jnp.int32, (1, r), 1)
        valid = pos < nv_ref[b_idx]
        scores = jnp.where(valid, scores, NEG)

        m_prev, l_prev = m_sc[...], l_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new) * valid.astype(jnp.float32)

        v = _dequant_block(vc_ref, vs_ref, vz_ref, v_bits, v_mode,
                           group_size, d)
        acc_sc[...] = acc_sc[...] * alpha + p @ v
        l_sc[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_sc[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _merge_residual_and_store():
        # final grid step for this (slot, head): fold the bf16 residual
        # window in as one more online-softmax block, normalize, store —
        # no (o, m, l) round-trip through HBM, no separate merge launch.
        kr = kr_ref[0, 0].astype(jnp.float32)  # [R, D]
        scores = (q @ kr.T) / jnp.sqrt(float(d))  # [G, R]
        valid = jax.lax.broadcasted_iota(jnp.int32, (1, r), 1) < nr_ref[b_idx]
        scores = jnp.where(valid, scores, NEG)

        m_prev, l_prev = m_sc[...], l_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new) * valid.astype(jnp.float32)

        vr = vr_ref[0, 0].astype(jnp.float32)
        acc = acc_sc[...] * alpha + p @ vr
        l_tot = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        o_ref[0, 0] = acc / jnp.maximum(l_tot, 1e-20)


@functools.partial(jax.jit, static_argnames=(
    "k_bits", "v_bits", "k_mode", "v_mode", "group_size", "interpret"))
def qdecode_paged(q, k_codes, k_scale, k_zero, v_codes, v_scale, v_zero,
                  k_res, v_res, page_table, n_valid, n_res, *, k_bits: int,
                  v_bits: int, k_mode: str, v_mode: str, group_size: int = 32,
                  interpret: bool | None = None):
    """Fused dequant + decode attention over the shared paged block pool,
    residual window included — ONE Pallas launch, normalized output.

    **Length-aware**: the page axis of the grid runs only to the batch's max
    live page count (``max(ceil(n_valid / R))``, a traced dimension — Mosaic
    supports dynamic grid bounds), not ``page_table.shape[1]``; a pool sized
    for long contexts costs nothing extra for short requests. Per slot, grid
    steps past its own live count alias the slot's last live block in every
    BlockSpec index map — the pipeline sees an unchanged block index and
    issues **no fresh DMA** — and skip their compute under ``pl.when``, so
    both bytes streamed and FLOPs are proportional to live tokens. Dead
    slots (``n_valid = n_res = 0``) stream nothing and produce zeros. The
    batch and head axes are marked ``parallel`` (``dimension_semantics``) so
    Mosaic may split them across TensorCores; only the page axis carries the
    online-softmax recurrence.

    The page table / lengths are **scalar-prefetch** arguments: index maps
    read ``page_table[b, j]`` to pick the physical block DMA'd for logical
    group ``j`` of slot ``b``, streaming live blocks in logical order
    straight out of the global pool.

    q [B, Hkv, G, D]; pool codes [N, Hkv, R, D·bits/8] (raw dtype when
    bits=16); k_res/v_res [B, Hkv, R, D] per-slot residual windows;
    page_table [B, P] i32 physical block ids; n_valid [B] i32 tokens in the
    main (paged) segment; n_res [B] i32 tokens in the residual window.
    Returns normalized attention output [B, Hkv, G, D] f32.
    """
    interpret = resolve_interpret(interpret)
    b, hkv, g, d = q.shape
    r = group_size
    assert k_codes.shape[2] == r, (k_codes.shape, r)
    assert k_res.shape == (b, hkv, r, d), (k_res.shape, (b, hkv, r, d))

    n_valid = n_valid.astype(jnp.int32)
    n_res = n_res.astype(jnp.int32)
    live_pages = (n_valid + r - 1) // r
    # >= 1 so every slot reaches its final step (where the residual merges)
    max_live = jnp.maximum(jnp.max(live_pages), 1)

    def block_at(pt, nv, b_, j):
        """Physical block for grid step j of slot b_, clamped to the live
        range: out-of-range steps re-name the last live block, which the
        pipeline recognizes as already resident (no DMA)."""
        live = (nv[b_] + r - 1) // r
        return pt[b_, jnp.minimum(j, jnp.maximum(live - 1, 0))]

    def seg_specs(bits, mode):
        cd = d if bits >= 16 else d * bits // 8
        cspec = pl.BlockSpec(
            (1, 1, r, cd),
            lambda b_, h, j, pt, nv, nr: (block_at(pt, nv, b_, j), h, 0, 0))
        if bits >= 16:
            dummy = pl.BlockSpec((1,), lambda b_, h, j, pt, nv, nr: (0,))
            return cspec, dummy, dummy
        if mode == MODE_PER_CHANNEL:
            sspec = pl.BlockSpec(
                (1, 1, 1, 1, d),
                lambda b_, h, j, pt, nv, nr:
                    (block_at(pt, nv, b_, j), h, 0, 0, 0))
        else:
            gg = min(group_size, d)
            sspec = pl.BlockSpec(
                (1, 1, r, d // gg, 1),
                lambda b_, h, j, pt, nv, nr:
                    (block_at(pt, nv, b_, j), h, 0, 0, 0))
        return cspec, sspec, sspec

    kc_spec, ks_spec, kz_spec = seg_specs(k_bits, k_mode)
    vc_spec, vs_spec, vz_spec = seg_specs(v_bits, v_mode)
    res_spec = pl.BlockSpec((1, 1, r, d),
                            lambda b_, h, j, pt, nv, nr: (b_, h, 0, 0))

    kernel = functools.partial(
        _qdecode_paged_kernel, k_bits=k_bits, v_bits=v_bits, k_mode=k_mode,
        v_mode=v_mode, group_size=group_size, d=d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # (page_table, n_valid, n_res)
        grid=(b, hkv, max_live),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b_, h, j, pt, nv, nr: (b_, h, 0, 0)),
            kc_spec, ks_spec, kz_spec, vc_spec, vs_spec, vz_spec,
            res_spec, res_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, h, j, pt, nv, nr: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), n_valid, n_res,
      q, k_codes, k_scale, k_zero, v_codes, v_scale, v_zero, k_res, v_res)
