"""Pure-jnp oracles for the Pallas kernels. Every kernel in this package is
validated against these references in interpret mode across shape/dtype sweeps
(tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.precision import MODE_PER_CHANNEL


def kvquant_ref(x: jax.Array, bits: int, mode: str, group_size: int = 32):
    """x [BH, S, D] → (codes packed uint8, scale f32, zero f32) with the
    repro.core.quant grouped-scale convention."""
    qt = quant.quantize(x, bits, mode, group_size)
    return qt.codes, qt.scale, qt.zero


def qdecode_ref(q: jax.Array, k_codes, k_scale, k_zero, v_codes, v_scale,
                v_zero, n_valid, *, k_bits: int, v_bits: int, k_mode: str,
                v_mode: str, group_size: int = 32):
    """Fused dequant + single-token attention over the packed main segment.

    q [B, Hkv, G, D] (G = query heads per kv head); codes [B, Hkv, S, D·bits/8].
    Returns partial-softmax stats (o [B,Hkv,G,D] f32, m [B,Hkv,G], l [B,Hkv,G])
    so the caller can merge with the bf16 residual window.
    """
    b, hkv, g, d = q.shape
    s = k_codes.shape[2]

    def deq(codes, scale, zero, bits, mode):
        if bits >= 16:
            return codes.astype(jnp.float32)
        raw = quant.unpack_codes(codes, bits).astype(jnp.float32)
        if mode == MODE_PER_CHANNEL:
            rg = raw.reshape(b, hkv, s // group_size, group_size, d)
            return (rg * scale + zero).reshape(b, hkv, s, d)
        gsz = min(group_size, d)
        rg = raw.reshape(b, hkv, s, d // gsz, gsz)
        return (rg * scale + zero).reshape(b, hkv, s, d)

    k = deq(k_codes, k_scale, k_zero, k_bits, k_mode)
    v = deq(v_codes, v_scale, v_zero, v_bits, v_mode)
    scores = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32), k) / jnp.sqrt(d)
    mask = (jnp.arange(s)[None, :] < n_valid[:, None])[:, None, None, :]
    scores = jnp.where(mask, scores, -jnp.inf)
    m = jnp.max(scores, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(scores - m_safe[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, v)
    return o, m_safe, l


def softmax_merge(parts):
    """Merge [(o_i, m_i, l_i)] partial attention results (flash combine).
    o_i are un-normalized (Σ p·V); returns normalized output f32."""
    m = parts[0][1]
    for _, mi, _ in parts[1:]:
        m = jnp.maximum(m, mi)
    l_tot = 0.0
    o_tot = 0.0
    for o_i, m_i, l_i in parts:
        c = jnp.exp(m_i - m)
        l_tot = l_tot + c * l_i
        o_tot = o_tot + c[..., None] * o_i
    return o_tot / jnp.maximum(l_tot, 1e-20)[..., None]
