"""Backend-aware kernel execution defaults, shared by every Pallas entry
point in this package (kvquant, qdecode, qdecode_paged).

``interpret=None`` everywhere means "decide from the backend": on TPU the
kernels compile natively; anywhere else (CPU CI containers) the kernel body
runs in Pallas interpret mode for validation.
"""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else interpret
