"""Pallas TPU kernel: fused flash-style prefill attention over the shared
paged pool of packed quantized KV blocks.

This is the admission-side twin of ``qdecode_paged`` (repro.kernels.qdecode).
The chunked in-pool prefill path previously materialized the whole live
context per chunk per layer (``pool.gather_dequant`` → dense bf16 [S', D]
in HBM, plus a dense O(C×S') mask) — exactly the memory blowup packed-group
streaming is meant to avoid. Here the packed blocks stream straight from HBM
through scalar-prefetch page-table index maps, dequantize per-block in VMEM
(shared ``_dequant_block``/``_unpack_lanes`` helpers), and fold into an
online softmax; the full-precision causal intra-chunk tile rides along as
the final block. One launch, normalized output, nothing dequantized ever
touches HBM.

Geometry per grid step (slot, h_kv, q_tile, j):
  q tile     [Bq, D]        Bq rows of the flattened (chunk_pos, q_head)
                            axis (row = c·G + g, chunk-position-major)
  ctx block  [R, D·kb/8]    one packed pool block → unpack+dequant in VMEM
  final j    [C, D]         fp chunk K/V tile, causal-masked
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.precision import MODE_PER_CHANNEL
from repro.kernels.qdecode import NEG, _dequant_block
from repro.kernels.runtime import resolve_interpret

DEFAULT_BLOCK_Q = 256


def pick_block_q(rows: int, requested: int, g: int) -> int:
    """Largest divisor of ``rows`` that is <= ``requested`` and a multiple
    of ``g`` (so a q tile always holds whole query positions — all G query
    heads of a chunk position land in the same tile)."""
    if rows % g:
        raise ValueError(f"q rows {rows} not a multiple of q-per-kv {g}")
    bq = max(min(requested, rows) // g * g, g)
    while rows % bq:
        bq -= g
    return bq


def _qprefill_kernel(pt_ref, nctx_ref, nchunk_ref, q_ref, kc_ref, ks_ref,
                     kz_ref, vc_ref, vs_ref, vz_ref, kch_ref, vch_ref,
                     o_ref, acc_sc, m_sc, l_sc, *, k_bits, v_bits, k_mode,
                     v_mode, group_size, g, block_q, chunk, d):
    s_idx = pl.program_id(0)
    qt = pl.program_id(2)
    j = pl.program_id(3)
    r = group_size
    live = nctx_ref[s_idx] // r  # this slot's live context block count

    @pl.when(j == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)

    q = q_ref[0, 0].astype(jnp.float32)  # [Bq, D]

    @pl.when(j < live)
    def _ctx_block():
        # in-range steps score one packed context block; out-of-range steps'
        # index maps alias the slot's last live block (no fresh DMA) and
        # skip compute entirely — work ∝ live context, not pool capacity
        k = _dequant_block(kc_ref, ks_ref, kz_ref, k_bits, k_mode,
                           group_size, d)
        scores = (q @ k.T) / jnp.sqrt(float(d))  # [Bq, R]
        pos = j * r + jax.lax.broadcasted_iota(jnp.int32, (1, r), 1)
        valid = pos < nctx_ref[s_idx]
        scores = jnp.where(valid, scores, NEG)

        m_prev, l_prev = m_sc[...], l_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new) * valid.astype(jnp.float32)

        v = _dequant_block(vc_ref, vs_ref, vz_ref, v_bits, v_mode,
                           group_size, d)
        acc_sc[...] = acc_sc[...] * alpha + p @ v
        l_sc[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_sc[...] = m_new

    @pl.when(j == pl.num_programs(3) - 1)
    def _chunk_tile_and_store():
        # final grid step: fold the full-precision intra-chunk K/V tile in
        # as one more online-softmax block — causal within the chunk and
        # ragged-masked to the slot's live chunk length — then normalize
        # and store. Dead lanes (n_ctx = n_chunk = 0) emit exact zeros.
        kch = kch_ref[0, 0].astype(jnp.float32)  # [C, D]
        scores = (q @ kch.T) / jnp.sqrt(float(d))  # [Bq, C]
        qpos = (qt * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, chunk), 0)) // g
        kpos = jax.lax.broadcasted_iota(jnp.int32, (block_q, chunk), 1)
        valid = (kpos <= qpos) & (kpos < nchunk_ref[s_idx])
        scores = jnp.where(valid, scores, NEG)

        m_prev, l_prev = m_sc[...], l_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new) * valid.astype(jnp.float32)

        vch = vch_ref[0, 0].astype(jnp.float32)
        acc = acc_sc[...] * alpha + p @ vch
        l_tot = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        o_ref[0, 0] = acc / jnp.maximum(l_tot, 1e-20)


@functools.partial(jax.jit, static_argnames=(
    "k_bits", "v_bits", "k_mode", "v_mode", "group_size", "block_q",
    "interpret"))
def qprefill_paged(q, k_codes, k_scale, k_zero, v_codes, v_scale, v_zero,
                   k_chunk, v_chunk, page_table, n_ctx, n_chunk, *,
                   k_bits: int, v_bits: int, k_mode: str, v_mode: str,
                   group_size: int = 32, block_q: int = DEFAULT_BLOCK_Q,
                   interpret: bool | None = None):
    """Fused dequant + flash prefill attention of one chunk wave over the
    shared paged block pool — ONE Pallas launch, normalized output.

    **Work-proportional and retrace-free**: the context axis of the grid
    runs to the batch's max live context block count plus one
    (``max(n_ctx // R) + 1``, a traced dimension) — the ``+1`` step folds
    the full-precision intra-chunk tile. Per slot, context steps past its
    own live count alias the slot's last live block in every BlockSpec
    index map (the pipeline sees an unchanged block index → no fresh DMA)
    and skip their compute under ``pl.when``. Lengths are traced, so one
    compiled kernel serves every (context length, chunk occupancy) mix —
    the batched-admission property. Dead lanes (``n_ctx = n_chunk = 0``)
    stream one aliased block plus their chunk tile and emit exact zeros.

    q [S, Hkv, C·G, D] — chunk queries flattened chunk-position-major
    (row = c·G + g); pool codes [N, Hkv, R, D·bits/8] (raw dtype when
    bits >= 16); k_chunk/v_chunk [S, Hkv, C, D] full-precision post-rope
    chunk K/V; page_table [S, P] i32; n_ctx [S] i32 context tokens already
    in pool blocks (each a multiple of R — prefill chunks are
    group-aligned); n_chunk [S] i32 live tokens of this wave's chunk.
    Returns normalized attention output [S, Hkv, C·G, D] f32.
    """
    interpret = resolve_interpret(interpret)
    s, hkv, cg, d = q.shape
    c = k_chunk.shape[2]
    assert cg % c == 0, (cg, c)
    g = cg // c
    r = group_size
    assert k_codes.shape[2] == r, (k_codes.shape, r)
    assert k_chunk.shape == (s, hkv, c, d), (k_chunk.shape, (s, hkv, c, d))
    block_q = pick_block_q(cg, block_q, g)
    nq = cg // block_q

    n_ctx = n_ctx.astype(jnp.int32)
    n_chunk = n_chunk.astype(jnp.int32)
    live_pages = n_ctx // r
    max_live = jnp.maximum(jnp.max(live_pages), 0)

    def block_at(pt, nc, s_, j):
        """Physical block for context step j of slot s_, clamped to the live
        range: out-of-range steps re-name the last live block, which the
        pipeline recognizes as already resident (no DMA)."""
        live = nc[s_] // r
        return pt[s_, jnp.minimum(j, jnp.maximum(live - 1, 0))]

    def seg_specs(bits, mode):
        cd = d if bits >= 16 else d * bits // 8
        cspec = pl.BlockSpec(
            (1, 1, r, cd),
            lambda s_, h, qt, j, pt, nc, nk: (block_at(pt, nc, s_, j), h,
                                              0, 0))
        if bits >= 16:
            dummy = pl.BlockSpec((1,), lambda s_, h, qt, j, pt, nc, nk: (0,))
            return cspec, dummy, dummy
        if mode == MODE_PER_CHANNEL:
            sspec = pl.BlockSpec(
                (1, 1, 1, 1, d),
                lambda s_, h, qt, j, pt, nc, nk:
                    (block_at(pt, nc, s_, j), h, 0, 0, 0))
        else:
            gg = min(group_size, d)
            sspec = pl.BlockSpec(
                (1, 1, r, d // gg, 1),
                lambda s_, h, qt, j, pt, nc, nk:
                    (block_at(pt, nc, s_, j), h, 0, 0, 0))
        return cspec, sspec, sspec

    kc_spec, ks_spec, kz_spec = seg_specs(k_bits, k_mode)
    vc_spec, vs_spec, vz_spec = seg_specs(v_bits, v_mode)
    q_spec = pl.BlockSpec((1, 1, block_q, d),
                          lambda s_, h, qt, j, pt, nc, nk: (s_, h, qt, 0))
    chunk_spec = pl.BlockSpec((1, 1, c, d),
                              lambda s_, h, qt, j, pt, nc, nk: (s_, h, 0, 0))

    kernel = functools.partial(
        _qprefill_kernel, k_bits=k_bits, v_bits=v_bits, k_mode=k_mode,
        v_mode=v_mode, group_size=group_size, g=g, block_q=block_q, chunk=c,
        d=d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # (page_table, n_ctx, n_chunk)
        grid=(s, hkv, nq, max_live + 1),
        in_specs=[
            q_spec,
            kc_spec, ks_spec, kz_spec, vc_spec, vs_spec, vz_spec,
            chunk_spec, chunk_spec,
        ],
        out_specs=q_spec,
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, hkv, cg, d), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), n_ctx, n_chunk,
      q, k_codes, k_scale, k_zero, v_codes, v_scale, v_zero,
      k_chunk, v_chunk)


# ============================================================ decode-verify
def _qverify_kernel(pt_ref, nctx_ref, nres_ref, nwin_ref, q_ref, kc_ref,
                    ks_ref, kz_ref, vc_ref, vs_ref, vz_ref, kr_ref, vr_ref,
                    kw_ref, vw_ref, o_ref, acc_sc, m_sc, l_sc, *, k_bits,
                    v_bits, k_mode, v_mode, group_size, g, block_q, win, d):
    s_idx = pl.program_id(0)
    qt = pl.program_id(2)
    j = pl.program_id(3)
    r = group_size
    live = nctx_ref[s_idx] // r  # this slot's live context block count

    @pl.when(j == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)

    q = q_ref[0, 0].astype(jnp.float32)  # [Bq, D]

    def _online(scores, valid, v):
        scores = jnp.where(valid, scores, NEG)
        m_prev, l_prev = m_sc[...], l_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new) * valid.astype(jnp.float32)
        acc_sc[...] = acc_sc[...] * alpha + p @ v
        l_sc[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_sc[...] = m_new

    @pl.when(j < live)
    def _ctx_block():
        # in-range steps score one packed context block; out-of-range steps'
        # index maps alias the slot's last live block (no fresh DMA) and
        # skip compute — work ∝ committed tokens, not pool capacity
        k = _dequant_block(kc_ref, ks_ref, kz_ref, k_bits, k_mode,
                           group_size, d)
        scores = (q @ k.T) / jnp.sqrt(float(d))  # [Bq, R]
        pos = j * r + jax.lax.broadcasted_iota(jnp.int32, (1, r), 1)
        valid = pos < nctx_ref[s_idx]
        v = _dequant_block(vc_ref, vs_ref, vz_ref, v_bits, v_mode,
                           group_size, d)
        _online(scores, valid, v)

    @pl.when(j == pl.num_programs(3) - 2)
    def _residual():
        # second-to-last step: the committed partial group lives in the bf16
        # residual window (the kernel never streams a partial pool block)
        kr = kr_ref[0, 0].astype(jnp.float32)  # [R, D]
        scores = (q @ kr.T) / jnp.sqrt(float(d))
        valid = jax.lax.broadcasted_iota(jnp.int32, (1, r), 1) \
            < nres_ref[s_idx]
        _online(scores, valid, vr_ref[0, 0].astype(jnp.float32))

    @pl.when(j == pl.num_programs(3) - 1)
    def _window_and_store():
        # final step: the full-precision candidate window [current, k drafts]
        # folds in causally (verify position c sees drafts <= c) and the
        # normalized output stores. Dead lanes (all counts 0) emit zeros.
        kw = kw_ref[0, 0].astype(jnp.float32)  # [K1, D]
        scores = (q @ kw.T) / jnp.sqrt(float(d))  # [Bq, K1]
        qpos = (qt * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, win), 0)) // g
        kpos = jax.lax.broadcasted_iota(jnp.int32, (block_q, win), 1)
        valid = (kpos <= qpos) & (kpos < nwin_ref[s_idx])
        scores = jnp.where(valid, scores, NEG)

        m_prev, l_prev = m_sc[...], l_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new) * valid.astype(jnp.float32)
        acc = acc_sc[...] * alpha + p @ vw_ref[0, 0].astype(jnp.float32)
        l_tot = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        o_ref[0, 0] = acc / jnp.maximum(l_tot, 1e-20)


@functools.partial(jax.jit, static_argnames=(
    "k_bits", "v_bits", "k_mode", "v_mode", "group_size", "block_q",
    "interpret"))
def qverify_paged(q, k_codes, k_scale, k_zero, v_codes, v_scale, v_zero,
                  k_res, v_res, k_win, v_win, page_table, n_ctx, n_res,
                  n_win, *, k_bits: int, v_bits: int, k_mode: str,
                  v_mode: str, group_size: int = 32,
                  block_q: int = DEFAULT_BLOCK_Q,
                  interpret: bool | None = None):
    """Fused speculative-verify attention: K1 = k+1 candidate tokens per
    slot score against the slot's ENTIRE committed context — live packed
    pool blocks, then the bf16 residual window, then the full-precision
    candidate window itself as the final causal block — in ONE Pallas
    launch with one normalized output. The decode-shaped sibling of
    :func:`qprefill_paged`: same scalar-prefetch page-table streaming, same
    index-map clamping and ``pl.when`` dead-lane masking, but lengths are
    arbitrary (not group-aligned), so the committed partial group rides in
    via the residual stage exactly as in ``qdecode_paged``.

    The amortization this buys: one launch re-streams each live block once
    to score K1 query positions, where K1 single-token decode launches
    stream the same blocks K1 times — the HBM-bound win speculative decode
    exists for.

    q [S, Hkv, K1·G, D] — candidate queries flattened window-position-major
    (row = c·G + g); pool codes [N, Hkv, R, D·bits/8] (raw dtype when
    bits >= 16); k_res/v_res [S, Hkv, R, D] per-slot residual windows;
    k_win/v_win [S, Hkv, K1, D] full-precision post-rope candidate K/V;
    page_table [S, P] i32; n_ctx [S] i32 committed tokens in pool blocks
    (multiples of R — pass ``lengths // R * R``); n_res [S] i32 committed
    residual tokens (``lengths - n_ctx``); n_win [S] i32 live candidate
    tokens (K1, or 0 for a dead lane). Returns normalized attention output
    [S, Hkv, K1·G, D] f32; dead-lane rows are exact zeros.
    """
    interpret = resolve_interpret(interpret)
    s, hkv, cg, d = q.shape
    win = k_win.shape[2]
    assert cg % win == 0, (cg, win)
    g = cg // win
    r = group_size
    assert k_codes.shape[2] == r, (k_codes.shape, r)
    assert k_res.shape == (s, hkv, r, d), (k_res.shape, (s, hkv, r, d))
    assert k_win.shape == (s, hkv, win, d), (k_win.shape, (s, hkv, win, d))
    block_q = pick_block_q(cg, block_q, g)
    nq = cg // block_q

    n_ctx = n_ctx.astype(jnp.int32)
    n_res = n_res.astype(jnp.int32)
    n_win = n_win.astype(jnp.int32)
    live_pages = n_ctx // r
    max_live = jnp.maximum(jnp.max(live_pages), 0)

    def block_at(pt, nc, s_, j):
        """Clamp out-of-range context steps to the slot's last live block
        (already resident → no DMA), as in the prefill/decode kernels."""
        live = nc[s_] // r
        return pt[s_, jnp.minimum(j, jnp.maximum(live - 1, 0))]

    def seg_specs(bits, mode):
        cd = d if bits >= 16 else d * bits // 8
        cspec = pl.BlockSpec(
            (1, 1, r, cd),
            lambda s_, h, qt, j, pt, nc, nr, nw:
                (block_at(pt, nc, s_, j), h, 0, 0))
        if bits >= 16:
            dummy = pl.BlockSpec(
                (1,), lambda s_, h, qt, j, pt, nc, nr, nw: (0,))
            return cspec, dummy, dummy
        if mode == MODE_PER_CHANNEL:
            sspec = pl.BlockSpec(
                (1, 1, 1, 1, d),
                lambda s_, h, qt, j, pt, nc, nr, nw:
                    (block_at(pt, nc, s_, j), h, 0, 0, 0))
        else:
            gg = min(group_size, d)
            sspec = pl.BlockSpec(
                (1, 1, r, d // gg, 1),
                lambda s_, h, qt, j, pt, nc, nr, nw:
                    (block_at(pt, nc, s_, j), h, 0, 0, 0))
        return cspec, sspec, sspec

    kc_spec, ks_spec, kz_spec = seg_specs(k_bits, k_mode)
    vc_spec, vs_spec, vz_spec = seg_specs(v_bits, v_mode)
    q_spec = pl.BlockSpec((1, 1, block_q, d),
                          lambda s_, h, qt, j, pt, nc, nr, nw: (s_, h, qt, 0))
    res_spec = pl.BlockSpec((1, 1, r, d),
                            lambda s_, h, qt, j, pt, nc, nr, nw:
                                (s_, h, 0, 0))
    win_spec = pl.BlockSpec((1, 1, win, d),
                            lambda s_, h, qt, j, pt, nc, nr, nw:
                                (s_, h, 0, 0))

    kernel = functools.partial(
        _qverify_kernel, k_bits=k_bits, v_bits=v_bits, k_mode=k_mode,
        v_mode=v_mode, group_size=group_size, g=g, block_q=block_q, win=win,
        d=d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,  # (page_table, n_ctx, n_res, n_win)
        grid=(s, hkv, nq, max_live + 2),
        in_specs=[
            q_spec,
            kc_spec, ks_spec, kz_spec, vc_spec, vs_spec, vz_spec,
            res_spec, res_spec, win_spec, win_spec,
        ],
        out_specs=q_spec,
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, hkv, cg, d), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), n_ctx, n_res, n_win,
      q, k_codes, k_scale, k_zero, v_codes, v_scale, v_zero,
      k_res, v_res, k_win, v_win)
