"""jit'd wrappers binding the Pallas kernels to the framework's cache layout.

``interpret`` defaults to True off-TPU (the kernel body runs in Python on CPU
for validation); on a TPU backend the compiled kernels run natively.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.cache.kvcache import LayerKVCache, _kv_modes
from repro.core.precision import MODE_PER_TOKEN
from repro.kernels import kvquant as kvquant_kernel
from repro.kernels import qdecode as qdecode_kernel
from repro.kernels import qprefill as qprefill_kernel
from repro.kernels import ref
from repro.kernels.runtime import default_interpret


def kvquant(x: jax.Array, bits: int, mode: str = MODE_PER_TOKEN,
            group_size: int = 32, interpret: bool | None = None):
    """x [B, H, S, D] → (codes, scale, zero) in cache layout."""
    b, h, s, d = x.shape
    interpret = default_interpret() if interpret is None else interpret
    codes, scale, zero = kvquant_kernel.kvquant(
        x.reshape(b * h, s, d), bits, mode, group_size,
        interpret=interpret)
    cd = codes.shape[-1]
    codes = codes.reshape(b, h, s, cd)
    scale = scale.reshape(b, h, *scale.shape[1:])
    zero = zero.reshape(b, h, *zero.shape[1:])
    return codes, scale, zero


def qdecode_attention(q: jax.Array, cache: LayerKVCache, positions, kind: str,
                      window: int, interpret: bool | None = None) -> jax.Array:
    """Fused decode attention over a quantized cache.

    q: [B, 1, H, hd] (one new token, post-rope). Main segment goes through the
    Pallas kernel; the bf16 residual window is attended in plain XLA and the
    two partial softmaxes are merged (flash combine). Returns [B, 1, H, hd].

    Restriction: windowed ring caches (gemma local layers) use the XLA path —
    their ring position arithmetic is not worth a kernel (bounded S ≤ window).
    """
    if kind == "local" or cache.window:
        raise NotImplementedError("windowed layers use the XLA decode path")
    interpret = default_interpret() if interpret is None else interpret
    b, one, h, d = q.shape
    hkv = cache.k_res.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    k_mode, v_mode = _kv_modes(cache.mode)

    r = cache.group_size
    n_main = jnp.minimum(cache.length // r * r, cache.s_cap)
    n_valid = jnp.broadcast_to(n_main, (b,))

    def seg(codes, scale, zero, bits):
        if bits >= 16:
            return codes, jnp.zeros((1,), jnp.float32), jnp.zeros((1,), jnp.float32)
        return codes, scale, zero

    kc, ks, kz = seg(cache.k_codes, cache.k_scale, cache.k_zero, cache.k_bits)
    vc, vs, vz = seg(cache.v_codes, cache.v_scale, cache.v_zero, cache.v_bits)

    o_main, m_main, l_main = qdecode_kernel.qdecode(
        qg, kc, ks, kz, vc, vs, vz, n_valid,
        k_bits=cache.k_bits, v_bits=cache.v_bits, k_mode=k_mode, v_mode=v_mode,
        group_size=cache.group_size, interpret=interpret)

    res = _residual_partial(qg, cache.k_res, cache.v_res,
                            cache.length - cache.length // r * r)
    out = ref.softmax_merge([(o_main, m_main, l_main), res])
    return out.reshape(b, 1, h, d).astype(q.dtype)


def _residual_partial(qg, k_res, v_res, n_res):
    """Partial softmax over the bf16 residual window (≤ R recent tokens),
    plain XLA. qg [B,Hkv,G,D]; k_res/v_res [B,Hkv,R,D]; n_res [] or [B] i32.
    Returns un-normalized (o, m, l) for flash-merging with the main segment."""
    d = qg.shape[-1]
    r = k_res.shape[2]
    kf = k_res.astype(jnp.float32)
    vf = v_res.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg.astype(jnp.float32), kf) \
        / jnp.sqrt(float(d))
    n_res = jnp.asarray(n_res)
    if n_res.ndim == 0:
        valid = (jnp.arange(r) < n_res)[None, None, None, :]
    else:
        valid = (jnp.arange(r)[None, :] < n_res[:, None])[:, None, None, :]
    scores = jnp.where(valid, scores, -jnp.inf)
    m_res = jnp.max(scores, axis=-1)
    m_res_safe = jnp.where(jnp.isfinite(m_res), m_res, qdecode_kernel.NEG)
    p = jnp.where(valid, jnp.exp(scores - m_res_safe[..., None]), 0.0)
    l_res = jnp.sum(p, axis=-1)
    o_res = jnp.einsum("bhgs,bhsd->bhgd", p, vf)
    return o_res, m_res_safe, l_res


def qdecode_paged_attention(q: jax.Array, pool, page_table: jax.Array,
                            lengths: jax.Array,
                            interpret: bool | None = None) -> jax.Array:
    """Fused decode attention over the shared paged block pool.

    q [B, 1, H, hd] (one new token per slot, post-rope); ``pool`` is a
    ``repro.cache.paged.PagedKVPool``; page_table [B, P] physical block ids;
    lengths [B] effective per-slot token counts (post-append; pass 0 for
    dead slots so they stream nothing). ONE Pallas launch per layer: the
    length-aware kernel streams each slot's live blocks only and folds the
    bf16 residual window in as its final online-softmax block — no separate
    residual/merge launches, no (o, m, l) HBM round-trip.
    Returns [B, 1, H, hd].
    """
    from repro.cache.paged import PagedKVPool  # noqa: F401 (doc/type only)

    interpret = default_interpret() if interpret is None else interpret
    b, _, h, d = q.shape
    hkv = pool.k_res.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    k_mode, v_mode = _kv_modes(pool.mode)
    r = pool.group_size
    n_main = (lengths // r * r).astype(jnp.int32)

    out = qdecode_kernel.qdecode_paged(
        qg, pool.k_codes, pool.k_scale, pool.k_zero,
        pool.v_codes, pool.v_scale, pool.v_zero,
        pool.k_res, pool.v_res, page_table, n_main, lengths - n_main,
        k_bits=pool.k_bits, v_bits=pool.v_bits, k_mode=k_mode, v_mode=v_mode,
        group_size=r, interpret=interpret)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def qverify_paged_attention(q: jax.Array, pool, page_table: jax.Array,
                            lengths: jax.Array, k_win: jax.Array,
                            v_win: jax.Array, win_lens: jax.Array,
                            interpret: bool | None = None) -> jax.Array:
    """Fused speculative-verify attention over the shared paged pool.

    q [S, K1, H, hd] — K1 = speculate_k + 1 candidate-token queries per
    slot (post-rope); ``pool`` is a ``repro.cache.paged.PagedKVPool``;
    page_table [S, P]; lengths [S] i32 committed tokens per slot (main +
    residual; pass 0 for dead lanes); k_win/v_win [S, Hkv, K1, D]
    full-precision candidate K/V; win_lens [S] i32 live candidate tokens
    (K1, or 0 for dead lanes). ONE Pallas launch per layer scores every
    candidate position against live pool blocks + the residual window +
    the causal candidate window — the decode-verify dispatch of the
    speculative engine. Returns [S, K1, H, hd].
    """
    from repro.cache.paged import PagedKVPool  # noqa: F401 (doc/type only)

    interpret = default_interpret() if interpret is None else interpret
    s, k1, h, d = q.shape
    hkv = pool.k_res.shape[1]
    g = h // hkv
    # flatten (window_pos, q_head) window-position-major: row = c·G + g
    qg = q.reshape(s, k1, hkv, g, d).transpose(0, 2, 1, 3, 4) \
        .reshape(s, hkv, k1 * g, d)
    k_mode, v_mode = _kv_modes(pool.mode)
    r = pool.group_size
    n_main = (lengths // r * r).astype(jnp.int32)

    out = qprefill_kernel.qverify_paged(
        qg, pool.k_codes, pool.k_scale, pool.k_zero,
        pool.v_codes, pool.v_scale, pool.v_zero,
        pool.k_res, pool.v_res, k_win, v_win, page_table,
        n_main, lengths - n_main, win_lens,
        k_bits=pool.k_bits, v_bits=pool.v_bits, k_mode=k_mode, v_mode=v_mode,
        group_size=r, interpret=interpret)
    return out.reshape(s, hkv, k1, g, d).transpose(0, 2, 1, 3, 4) \
        .reshape(s, k1, h, d).astype(q.dtype)


def qprefill_paged_attention(q: jax.Array, pool, page_table: jax.Array,
                             ctx_lens: jax.Array, k_chunk: jax.Array,
                             v_chunk: jax.Array, chunk_lens: jax.Array,
                             interpret: bool | None = None) -> jax.Array:
    """Fused flash prefill attention of one chunk wave over the paged pool.

    q [S, C, H, hd] (post-rope chunk queries per slot); ``pool`` is a
    ``repro.cache.paged.PagedKVPool``; page_table [S, P] physical block ids;
    ctx_lens [S] i32 context tokens already in pool blocks (multiples of R;
    0 for dead lanes); k_chunk/v_chunk [S, Hkv, C, D] full-precision chunk
    K/V; chunk_lens [S] i32 live chunk tokens (0 = dead lane). ONE Pallas
    launch per layer: packed context blocks stream via the page table and
    the causal intra-chunk tile folds in as the final online-softmax block —
    no ``gather_dequant``, no materialized O(C×S') bias. Returns
    [S, C, H, hd]; rows of dead lanes are exact zeros.
    """
    from repro.cache.paged import PagedKVPool  # noqa: F401 (doc/type only)

    interpret = default_interpret() if interpret is None else interpret
    s, c, h, d = q.shape
    hkv = pool.k_res.shape[1]
    g = h // hkv
    # flatten (chunk_pos, q_head) chunk-position-major: row = c·G + g
    qg = q.reshape(s, c, hkv, g, d).transpose(0, 2, 1, 3, 4) \
        .reshape(s, hkv, c * g, d)
    k_mode, v_mode = _kv_modes(pool.mode)

    out = qprefill_kernel.qprefill_paged(
        qg, pool.k_codes, pool.k_scale, pool.k_zero,
        pool.v_codes, pool.v_scale, pool.v_zero,
        k_chunk, v_chunk, page_table, ctx_lens, chunk_lens,
        k_bits=pool.k_bits, v_bits=pool.v_bits, k_mode=k_mode, v_mode=v_mode,
        group_size=pool.group_size, interpret=interpret)
    return out.reshape(s, hkv, c, g, d).transpose(0, 2, 1, 3, 4) \
        .reshape(s, c, h, d).astype(q.dtype)
