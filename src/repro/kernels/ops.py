"""jit'd wrappers binding the Pallas kernels to the framework's cache layout.

``interpret`` defaults to True off-TPU (the kernel body runs in Python on CPU
for validation); on a TPU backend the compiled kernels run natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.cache.kvcache import LayerKVCache, _kv_modes
from repro.core.precision import MODE_PER_TOKEN
from repro.kernels import kvquant as kvquant_kernel
from repro.kernels import qdecode as qdecode_kernel
from repro.kernels import ref


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def kvquant(x: jax.Array, bits: int, mode: str = MODE_PER_TOKEN,
            group_size: int = 32, interpret: bool | None = None):
    """x [B, H, S, D] → (codes, scale, zero) in cache layout."""
    b, h, s, d = x.shape
    interpret = default_interpret() if interpret is None else interpret
    codes, scale, zero = kvquant_kernel.kvquant(
        x.reshape(b * h, s, d), bits, mode, group_size,
        interpret=interpret)
    cd = codes.shape[-1]
    codes = codes.reshape(b, h, s, cd)
    scale = scale.reshape(b, h, *scale.shape[1:])
    zero = zero.reshape(b, h, *zero.shape[1:])
    return codes, scale, zero


def qdecode_attention(q: jax.Array, cache: LayerKVCache, positions, kind: str,
                      window: int, interpret: bool | None = None) -> jax.Array:
    """Fused decode attention over a quantized cache.

    q: [B, 1, H, hd] (one new token, post-rope). Main segment goes through the
    Pallas kernel; the bf16 residual window is attended in plain XLA and the
    two partial softmaxes are merged (flash combine). Returns [B, 1, H, hd].

    Restriction: windowed ring caches (gemma local layers) use the XLA path —
    their ring position arithmetic is not worth a kernel (bounded S ≤ window).
    """
    if kind == "local" or cache.window:
        raise NotImplementedError("windowed layers use the XLA decode path")
    interpret = default_interpret() if interpret is None else interpret
    b, one, h, d = q.shape
    hkv = cache.k_res.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    k_mode, v_mode = _kv_modes(cache.mode)

    r = cache.group_size
    n_main = jnp.minimum(cache.length // r * r, cache.s_cap)
    n_valid = jnp.broadcast_to(n_main, (b,))

    def seg(codes, scale, zero, bits):
        if bits >= 16:
            return codes, jnp.zeros((1,), jnp.float32), jnp.zeros((1,), jnp.float32)
        return codes, scale, zero

    kc, ks, kz = seg(cache.k_codes, cache.k_scale, cache.k_zero, cache.k_bits)
    vc, vs, vz = seg(cache.v_codes, cache.v_scale, cache.v_zero, cache.v_bits)

    o_main, m_main, l_main = qdecode_kernel.qdecode(
        qg, kc, ks, kz, vc, vs, vz, n_valid,
        k_bits=cache.k_bits, v_bits=cache.v_bits, k_mode=k_mode, v_mode=v_mode,
        group_size=cache.group_size, interpret=interpret)

    # Residual window (≤ R recent bf16 tokens): plain XLA partial softmax.
    n_res = cache.length - cache.length // r * r
    k_res = cache.k_res.astype(jnp.float32)  # [B,Hkv,R,D]
    v_res = cache.v_res.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg.astype(jnp.float32), k_res) \
        / jnp.sqrt(float(d))
    valid = (jnp.arange(cache.residual_len) < n_res)[None, None, None, :]
    scores = jnp.where(valid, scores, -jnp.inf)
    m_res = jnp.max(scores, axis=-1)
    m_res_safe = jnp.where(jnp.isfinite(m_res), m_res, qdecode_kernel.NEG)
    p = jnp.where(valid, jnp.exp(scores - m_res_safe[..., None]), 0.0)
    l_res = jnp.sum(p, axis=-1)
    o_res = jnp.einsum("bhgs,bhsd->bhgd", p, v_res)

    out = ref.softmax_merge([(o_main, m_main, l_main),
                             (o_res, m_res_safe, l_res)])
    return out.reshape(b, 1, h, d).astype(q.dtype)
