"""Pallas TPU kernel: asymmetric KV quantization with in-VMEM packing.

Tiles [S_blk, D] KV blocks HBM→VMEM, computes per-token or per-channel
(scale, zero) on the VPU, packs 2/4-bit codes into uint8 along the lane
(head_dim) axis, and writes packed codes + f32 scales back to HBM.

Block geometry: S_blk = 128 rows (16 × 8-sublane tiles), D = head_dim on the
lane axis (64–256 for the assigned archs). Per-channel groups span 32 rows —
S_blk is a multiple of the group so each block owns whole groups (no
cross-block reductions).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.precision import MODE_PER_CHANNEL, MODE_PER_TOKEN
from repro.kernels.runtime import resolve_interpret

DEFAULT_BLOCK_S = 128


def _pack_lanes(codes: jax.Array, bits: int) -> jax.Array:
    """uint8 codes [S, D] → packed uint8 [S, D·bits/8] (lane-axis packing)."""
    if bits == 8:
        return codes.astype(jnp.uint8)
    vpb = 8 // bits
    s, d = codes.shape
    grouped = codes.reshape(s, d // vpb, vpb).astype(jnp.uint32)
    shifts = jnp.arange(vpb, dtype=jnp.uint32) * bits
    return jnp.sum(grouped << shifts, axis=-1).astype(jnp.uint8)


def _kvquant_kernel(x_ref, codes_ref, scale_ref, zero_ref, *, bits: int,
                    mode: str, group_size: int):
    x = x_ref[0].astype(jnp.float32)  # [S_blk, D]
    s_blk, d = x.shape
    levels = float(2 ** bits - 1)
    if mode == MODE_PER_CHANNEL:
        # groups of `group_size` rows share one (scale, zero) per channel
        xg = x.reshape(s_blk // group_size, group_size, d)
        mn = jnp.min(xg, axis=1, keepdims=True)
        mx = jnp.max(xg, axis=1, keepdims=True)
        scale = jnp.maximum((mx - mn) / levels, 1e-8)
        q = jnp.clip(jnp.round((xg - mn) / scale), 0, levels)
        codes = q.reshape(s_blk, d).astype(jnp.uint8)
        scale_ref[0] = scale
        zero_ref[0] = mn
    else:
        g = min(group_size, d)
        xg = x.reshape(s_blk, d // g, g)
        mn = jnp.min(xg, axis=2, keepdims=True)
        mx = jnp.max(xg, axis=2, keepdims=True)
        scale = jnp.maximum((mx - mn) / levels, 1e-8)
        q = jnp.clip(jnp.round((xg - mn) / scale), 0, levels)
        codes = q.reshape(s_blk, d).astype(jnp.uint8)
        scale_ref[0] = scale
        zero_ref[0] = mn
    codes_ref[0] = _pack_lanes(codes, bits)


@functools.partial(jax.jit, static_argnames=("bits", "mode", "group_size",
                                             "block_s", "interpret"))
def kvquant(x: jax.Array, bits: int, mode: str = MODE_PER_TOKEN,
            group_size: int = 32, block_s: int = DEFAULT_BLOCK_S,
            interpret: bool | None = None):
    """x [N, S, D] → (codes [N,S,D·bits/8] u8, scale, zero f32) matching the
    repro.core.quant layout. N is flattened batch×kv_heads.

    ``interpret=None`` resolves backend-aware (repro.kernels.runtime)."""
    interpret = resolve_interpret(interpret)
    n, s, d = x.shape
    block_s = min(block_s, s)
    assert s % block_s == 0 and block_s % group_size == 0, (s, block_s)
    ns = s // block_s
    cd = d if bits == 8 else d * bits // 8
    g = min(group_size, d)
    if mode == MODE_PER_CHANNEL:
        sshape = (n, s // group_size, 1, d)
        sblock = (1, block_s // group_size, 1, d)
        smap = lambda i, j: (i, j, 0, 0)
    else:
        sshape = (n, s, d // g, 1)
        sblock = (1, block_s, d // g, 1)
        smap = lambda i, j: (i, j, 0, 0)

    codes, scale, zero = pl.pallas_call(
        functools.partial(_kvquant_kernel, bits=bits, mode=mode,
                          group_size=group_size),
        grid=(n, ns),
        in_specs=[pl.BlockSpec((1, block_s, d), lambda i, j: (i, j, 0))],
        out_specs=[
            pl.BlockSpec((1, block_s, cd), lambda i, j: (i, j, 0)),
            pl.BlockSpec(sblock, smap),
            pl.BlockSpec(sblock, smap),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, s, cd), jnp.uint8),
            jax.ShapeDtypeStruct(sshape, jnp.float32),
            jax.ShapeDtypeStruct(sshape, jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return codes, scale, zero
