"""Pluggable admission & preemption scheduling for ``ContinuousEngine``.

A policy decides two things, both between jitted steps (the device never
sees scheduling):

* **admission order** — how the arrived-but-waiting queue is sorted before
  slots/blocks are handed out (``admission_key``);
* **preemption** — when the head of that queue cannot be admitted under pool
  pressure, whether a running *victim* should be preempted for it
  (``wants_preempt``) and which victim to prefer (``victim_key``). A
  preempted request's exclusively-owned blocks swap out to the host tier
  (or are dropped for recompute-from-prompt when the tier is full), and it
  re-enters the waiting queue to be resumed token-identically later.

Every policy's ``wants_preempt`` is a *strict* comparison on a quantity
that never increases for a given request (arrival time, priority, remaining
work), so preemption cannot livelock: A can displace B and B later displace
A only after A made real progress, and total progress is bounded by the
workload.

Built-in policies:

* ``fcfs`` — earliest arrival wins, always. A waiting request preempts only
  victims that *arrived* strictly later than it did; the latest-arrived
  victim goes first. Equal-arrival traffic degrades to today's
  stall-and-wait admission.
* ``priority`` — higher ``Request.priority`` wins. Victims must have
  strictly lower priority than the waiting request; lowest priority (then
  latest arrival) is evicted first.
* ``ssf`` — shortest-suffix-first: the request with the least remaining
  work (non-cached prefill suffix + undecoded token budget) wins, the
  classic mean-latency heuristic. Victims must have strictly more remaining
  work; the largest-remaining victim goes first.
"""
from __future__ import annotations


class SchedulerPolicy:
    """Base policy. Subclasses override the three hooks; ``engine`` is the
    calling ``ContinuousEngine`` (gives access to prefix-match state for
    suffix-aware policies)."""

    name = "base"
    preemptive = True

    def admission_key(self, req, engine):
        """Sort key over waiting requests; smallest admits first."""
        raise NotImplementedError

    def wants_preempt(self, waiting, victim, engine) -> bool:
        """True if ``waiting`` justifies preempting running ``victim``.
        MUST be a strict comparison (see module docstring)."""
        raise NotImplementedError

    def victim_key(self, victim, engine):
        """Sort key over eligible victims; smallest is preempted first."""
        raise NotImplementedError

    def shed_key(self, req, engine):
        """Sort key over waiting requests under overload; the LARGEST is
        shed first. Defaults to the policy's own admission ranking, so the
        request the policy would admit last is the one dropped when the
        bounded queue overflows."""
        return self.admission_key(req, engine)

    # ------------------------------------------------------------- shared
    @staticmethod
    def remaining_work(req, engine) -> int:
        """Tokens of work left: the prefill suffix not covered by a cached
        prefix (zero once running) plus the undecoded token budget. Never
        increases for a given request."""
        return engine.suffix_tokens(req) + req.max_new_tokens \
            - len(req.output)


class FCFSScheduler(SchedulerPolicy):
    name = "fcfs"

    def admission_key(self, req, engine):
        return (req.arrival_step, req.uid)

    def wants_preempt(self, waiting, victim, engine) -> bool:
        return waiting.arrival_step < victim.arrival_step

    def victim_key(self, victim, engine):
        return (-victim.arrival_step, -victim.uid)


class PriorityScheduler(SchedulerPolicy):
    name = "priority"

    def admission_key(self, req, engine):
        return (-req.priority, req.arrival_step, req.uid)

    def wants_preempt(self, waiting, victim, engine) -> bool:
        return waiting.priority > victim.priority

    def victim_key(self, victim, engine):
        return (victim.priority, -victim.arrival_step, -victim.uid)


class ShortestSuffixScheduler(SchedulerPolicy):
    name = "ssf"

    def admission_key(self, req, engine):
        return (self.remaining_work(req, engine), req.arrival_step, req.uid)

    def wants_preempt(self, waiting, victim, engine) -> bool:
        return self.remaining_work(waiting, engine) \
            < self.remaining_work(victim, engine)

    def victim_key(self, victim, engine):
        return (-self.remaining_work(victim, engine), -victim.uid)


POLICIES = {p.name: p for p in (FCFSScheduler, PriorityScheduler,
                                ShortestSuffixScheduler)}


def make_scheduler(spec) -> SchedulerPolicy:
    """Resolve ``spec`` — a policy name, class, or instance — to a policy
    instance."""
    if isinstance(spec, SchedulerPolicy):
        return spec
    if isinstance(spec, type) and issubclass(spec, SchedulerPolicy):
        return spec()
    if isinstance(spec, str):
        if spec not in POLICIES:
            raise ValueError(f"unknown scheduler {spec!r}; "
                             f"have {sorted(POLICIES)}")
        return POLICIES[spec]()
    raise TypeError(f"scheduler spec must be a name, SchedulerPolicy class, "
                    f"or instance; got {type(spec).__name__}")
