"""Draft-token proposers for speculative decode.

The engine's verify pass (``models.transformer.paged_spec_step``) accepts
any candidate source — acceptance keeps only the longest greedy-consistent
prefix, so a bad draft costs one wasted verify lane, never a wrong token.
``Drafter`` is the host-side protocol a draft model can later plug into;
the default is **prompt lookup** (model-free n-gram matching, in the spirit
of "Prompt Lookup Decoding" / REST): the request's own history — prompt
plus everything generated so far — doubles as the n-gram table, which is
exactly right for the shared-template serving workloads where speculation
pays (templated few-shot prompts, retrieved context, code with repeated
identifiers).

Drafting runs on host between device dispatches: the engine must sync for
emitted tokens every speculative step anyway, so the numpy suffix match
rides in that gap and costs no device time.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class Drafter(Protocol):
    """Per-slot candidate proposer. ``history`` is the request's prompt
    followed by every token generated so far (1-D int array, the last
    entry being the token about to be fed to the model); returns up to
    ``k`` draft continuations (int32, possibly empty — an empty draft
    degrades that slot to normal one-token decode for the step)."""

    def draft(self, history: np.ndarray, k: int) -> np.ndarray:
        ...


@dataclasses.dataclass
class PromptLookupDrafter:
    """Model-free n-gram drafter: find an earlier occurrence of the
    history's trailing n-gram and propose the tokens that followed it.
    Tries ``max_ngram`` down to ``min_ngram`` (longer matches are more
    specific, so they win); among same-length matches the one with the
    LONGEST continuation wins, most recent on ties — a hit near the end of
    history may be followed by only a token or two, and every unfilled
    draft lane is a verify lane wasted, so an older full-``k`` occurrence
    beats a newer truncated one. Vectorized with a sliding-window view —
    one numpy pass per n-gram size, no python loop over positions."""

    max_ngram: int = 3
    min_ngram: int = 1

    def draft(self, history: np.ndarray, k: int) -> np.ndarray:
        h = np.ascontiguousarray(np.asarray(history).ravel())
        empty = np.zeros(0, np.int32)
        if k <= 0 or len(h) < 2:
            return empty
        hi = min(self.max_ngram, len(h) - 1)
        for n in range(hi, self.min_ngram - 1, -1):
            suffix = h[-n:]
            # windows over h[:-1]: every match has >= 1 continuation token,
            # and the trailing n-gram cannot match itself
            wins = np.lib.stride_tricks.sliding_window_view(h[:-1], n)
            hits = np.nonzero((wins == suffix).all(axis=1))[0]
            if len(hits):
                cont = np.minimum(len(h) - (hits + n), k)
                p = int(hits[cont == cont.max()][-1])
                return h[p + n:p + n + k].astype(np.int32)
        return empty
