"""Serving metrics: counters, gauges, bounded-reservoir histograms, and the
online quantization-quality probe.

The :class:`MetricsRegistry` is the single metrics surface of the serving
stack. ``EngineStats`` (``repro.serving.engine``) is a *facade* over one —
every counter the engine bumps (``stats.completed += 1``) and every wall-
time sample it records lands in a named registry metric, so one
``registry.snapshot()`` / ``registry.to_prometheus()`` call exports the
whole engine state without a second bookkeeping system. Metric names are
dotted (``engine.completed``, ``engine.decode_dispatch_wall_s``,
``faults.alloc``, ``probe.e_k.layer3``); the Prometheus exposition
sanitizes them to underscore form.

Histograms keep a **bounded reservoir** (deterministic seeded sampling, cap
:data:`RESERVOIR_CAP`): under the cap they hold every sample exactly, so
percentiles and ``min``/``max``/``sum`` match an unbounded list bit-for-bit
(existing callers iterate them like the plain lists they replace); past the
cap memory stays bounded while ``count``/``total``/``min``/``max`` remain
exact and percentiles become reservoir estimates.

:class:`QuantProbe` is the serve-time mirror of the offline sensitivity
table (``repro.core.sensitivity.layer_errors``): every N host syncs it
dequantizes a small random sample of live pool blocks per layer and reports
per-layer e_k/e_v — the relative error (``repro.core.quant.relative_error``)
of re-quantizing that live KV data at a fixed *reference* precision
(``probe_bits``). The dequantized blocks stand in for the layer's true KV
distribution, so the probe orders layers by sensitivity the same way the
offline table does at the matching pair. Pick ``probe_bits`` strictly below
the stored schedule bits: RTN asymmetric quantization round-trips
losslessly, so probing a layer at its own stored precision reads ~0 — which
is itself the "stored precision is exact under re-quantization" signal, not
a sensitivity measurement.
"""
from __future__ import annotations

import json
import random
import zlib

import numpy as np

RESERVOIR_CAP = 4096


# ==================================================================== metrics
class Counter:
    """Monotonic-by-convention integer metric (``inc``); ``set`` exists so
    the ``EngineStats`` facade can route ``stats.field += n`` through it."""

    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += int(n)

    def set(self, v: int) -> None:
        self.value = int(v)


class Gauge:
    """Last-write-wins float metric."""

    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Bounded-reservoir sample distribution.

    ``count``/``total``/``min``/``max`` are exact for every observation
    ever made; the reservoir holds at most ``cap`` samples (all of them
    while ``count <= cap``, a uniform random subset after — classic
    reservoir sampling with a deterministic per-name seed, so two runs of
    the same workload keep identical reservoirs). Iteration and ``len()``
    expose the reservoir, which under the cap is exactly the full sample
    list the engine's old ad-hoc lists held.
    """

    kind = "histogram"

    def __init__(self, name: str, cap: int = RESERVOIR_CAP):
        if cap < 1:
            raise ValueError(f"histogram cap ({cap}) must be >= 1")
        self.name = name
        self.cap = cap
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self._samples: list[float] = []
        # per-name deterministic seed: reruns reproduce the same reservoir
        self._rng = random.Random(zlib.crc32(name.encode()))

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        if len(self._samples) < self.cap:
            self._samples.append(v)
        else:
            j = self._rng.randrange(self.count)
            if j < self.cap:
                self._samples[j] = v

    # list-compatible surface (the engine's old raw lists)
    append = observe

    def extend(self, vs) -> None:
        for v in vs:
            self.observe(v)

    def __len__(self) -> int:
        return len(self._samples)

    def __iter__(self):
        return iter(self._samples)

    def __bool__(self) -> bool:
        return bool(self._samples)

    def percentile(self, q: float) -> float:
        if not self._samples:
            return 0.0
        return float(np.percentile(np.asarray(self._samples), q))

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class MetricsRegistry:
    """Name → metric map with get-or-create accessors and two exporters
    (structured JSON snapshot, Prometheus text exposition)."""

    def __init__(self):
        self._metrics: dict = {}

    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, **kw)
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested {cls.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, cap: int = RESERVOIR_CAP) -> Histogram:
        return self._get(name, Histogram, cap=cap)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    # ------------------------------------------------------------ exporters
    def snapshot(self) -> dict:
        """Structured-JSON view: every metric with its kind and values
        (histograms as summary stats + p50/p95, never raw reservoirs)."""
        out: dict = {}
        for name in self.names():
            m = self._metrics[name]
            if m.kind == "histogram":
                out[name] = {
                    "kind": "histogram", "count": m.count, "total": m.total,
                    "min": m.vmin if m.count else 0.0,
                    "max": m.vmax if m.count else 0.0, "mean": m.mean,
                    "p50": m.percentile(50), "p95": m.percentile(95),
                }
            else:
                out[name] = {"kind": m.kind, "value": m.value}
        return out

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    @staticmethod
    def _prom_name(name: str) -> str:
        return "".join(c if c.isalnum() or c == "_" else "_" for c in name)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (one TYPE line + value lines per
        metric; histograms export _count/_sum plus p50/p95 quantile
        gauges — summary-style, reservoir-estimated)."""
        lines: list[str] = []
        for name in self.names():
            m = self._metrics[name]
            p = self._prom_name(name)
            if m.kind == "counter":
                lines += [f"# TYPE {p} counter", f"{p} {m.value}"]
            elif m.kind == "gauge":
                lines += [f"# TYPE {p} gauge", f"{p} {m.value}"]
            else:
                lines += [
                    f"# TYPE {p} summary",
                    f'{p}{{quantile="0.5"}} {m.percentile(50)}',
                    f'{p}{{quantile="0.95"}} {m.percentile(95)}',
                    f"{p}_sum {m.total}", f"{p}_count {m.count}",
                ]
        return "\n".join(lines) + "\n"


# ================================================================ quant probe
class QuantProbe:
    """Online per-layer e_k/e_v probe over live pool blocks (see module
    docstring for the reference-precision semantics).

    ``every`` — probe once per that many host syncs (serve-loop
    iterations); ``sample_blocks`` — max fully-written live blocks sampled
    per probe (the same block ids are read from every layer's pool, like a
    page-table row); ``bits`` — the (k_bits, v_bits) reference pair errors
    are measured at. Sampling is seeded and the probe only *reads* device
    state, so a probed run is token-identical to an unprobed one.
    """

    def __init__(self, every: int = 8, sample_blocks: int = 4,
                 bits: tuple = (2, 2), seed: int = 0):
        if every < 1:
            raise ValueError(f"probe every ({every}) must be >= 1")
        if sample_blocks < 1:
            raise ValueError(
                f"probe sample_blocks ({sample_blocks}) must be >= 1")
        self.every = every
        self.sample_blocks = sample_blocks
        self.k_bits, self.v_bits = bits
        self.rng = np.random.default_rng(seed)
        self.syncs = 0
        self.skipped = 0         # probes with no fully-written live block
        self.history: list[dict] = []

    # ------------------------------------------------------------- hooks
    def on_sync(self, engine) -> None:
        """Called by the engine once per host sync; probes every Nth."""
        self.syncs += 1
        if self.syncs % self.every == 0:
            self.probe(engine)

    def _candidate_blocks(self, engine) -> list[int]:
        """Fully-written live blocks: each slot-resident request's first
        ``cached_len // R`` pages hold complete quantized groups (the tail
        group lives in the full-precision residual window)."""
        cands: set[int] = set()
        for slot, req in enumerate(engine._slots):
            if req is None or slot in engine._reserved:
                continue
            n_full = (len(req.prompt) + len(req.output) - 1) \
                // engine.group_size
            cands.update(engine._slot_pages[slot][:n_full])
        return sorted(cands)

    def probe(self, engine) -> dict | None:
        """Sample blocks, dequantize, re-quantize at the reference pair,
        record per-layer e_k/e_v (and mirror them into registry gauges)."""
        from repro.core import quant

        cands = self._candidate_blocks(engine)
        if not cands:
            self.skipped += 1
            return None
        if len(cands) > self.sample_blocks:
            cands = sorted(self.rng.choice(
                cands, self.sample_blocks, replace=False).tolist())
        idx = np.asarray(cands, np.int32)
        rec: dict = {"sync": self.syncs, "blocks": list(map(int, cands)),
                     "layers": [], "e_k": [], "e_v": []}
        reg = engine.stats.registry
        for li, pool in enumerate(engine.state.pools):
            if pool is None:
                continue
            c = pool.codec
            e_k = self._side_error(quant, pool.k_codes, pool.k_scale,
                                   pool.k_zero, c.k, idx, self.k_bits)
            e_v = self._side_error(quant, pool.v_codes, pool.v_scale,
                                   pool.v_zero, c.v, idx, self.v_bits)
            rec["layers"].append(li)
            rec["e_k"].append(e_k)
            rec["e_v"].append(e_v)
            reg.gauge(f"probe.e_k.layer{li}").set(e_k)
            reg.gauge(f"probe.e_v.layer{li}").set(e_v)
        reg.counter("probe.samples").inc()
        self.history.append(rec)
        return rec

    @staticmethod
    def _side_error(quant, codes, scale, zero, seg, idx, bits) -> float:
        """One side's (K or V) reference-precision error over the sampled
        blocks: dequantize [n, Hkv, R, D], fake-quantize at ``bits`` with
        the segment's own mode/group (token axis -2, matching the offline
        ``layer_errors`` layout), relative error between the two."""
        import jax.numpy as jnp

        sc = scale[idx] if seg.quantized else scale
        zr = zero[idx] if seg.quantized else zero
        x = jnp.asarray(seg.decode(codes[idx], sc, zr, jnp.float32))
        x_hat = quant.fake_quant(x, bits, seg.mode, seg.group_size)
        return float(quant.relative_error(x, x_hat))

    # ---------------------------------------------------------- reporting
    def summary(self) -> dict:
        """Per-layer mean e_k/e_v over every probe taken (the table the
        benchmark rank-compares against the offline sensitivity table)."""
        if not self.history:
            return {"samples": 0, "skipped": self.skipped,
                    "probe_bits": [self.k_bits, self.v_bits],
                    "layers": [], "e_k": [], "e_v": []}
        return {
            "samples": len(self.history), "skipped": self.skipped,
            "probe_bits": [self.k_bits, self.v_bits],
            "layers": self.history[-1]["layers"],
            "e_k": np.mean([h["e_k"] for h in self.history], axis=0).tolist(),
            "e_v": np.mean([h["e_v"] for h in self.history], axis=0).tolist(),
        }
