"""Batched serving engine with KVTuner mixed-precision KV cache.

Wave-based continuous batching: queued requests are grouped by prompt length
(static-shape buckets — TPU/XLA friendly), prefilled together, then decoded
step-by-step with per-request stop tracking. The KVTunerSchedule is loaded
once; every layer's cache ops lower with **static** per-layer precision —
the paper's "no online decision overhead" property (§5).

Throughput accounting mirrors the paper's Table 8 definition: generated
tokens per second end-to-end, including quantization/dequantization work.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import KVTunerSchedule


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    generated_tokens: int = 0
    prefill_tokens: int = 0
    wall_s: float = 0.0
    waves: int = 0

    @property
    def throughput(self) -> float:
        return self.generated_tokens / max(self.wall_s, 1e-9)


class ServeEngine:
    def __init__(self, api, params, schedule: KVTunerSchedule | None,
                 max_batch: int = 8, extra_groups: int = 8,
                 greedy: bool = True, use_pallas: bool = False, seed: int = 0):
        self.api = api
        self.params = params
        self.schedule = schedule
        self.max_batch = max_batch
        self.extra_groups = extra_groups
        self.greedy = greedy
        self.use_pallas = use_pallas
        self.rng = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.stats = EngineStats()
        self._decode_jit = {}

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------ serving
    def _decode_fn(self, key):
        if key not in self._decode_jit:
            self._decode_jit[key] = jax.jit(
                partial(self.api.decode_step, use_pallas=self.use_pallas))
        return self._decode_jit[key]

    def run(self) -> list[Request]:
        """Drain the queue; returns completed requests."""
        done: list[Request] = []
        buckets: dict[int, list[Request]] = defaultdict(list)
        for r in self.queue:
            buckets[len(r.prompt)].append(r)
        self.queue.clear()
        for plen, reqs in sorted(buckets.items()):
            for i in range(0, len(reqs), self.max_batch):
                wave = reqs[i:i + self.max_batch]
                self._run_wave(wave, plen)
                done.extend(wave)
        return done

    def _run_wave(self, wave: list[Request], plen: int) -> None:
        t0 = time.time()
        b = len(wave)
        toks = jnp.asarray(np.stack([r.prompt for r in wave]), jnp.int32)
        max_new = max(r.max_new_tokens for r in wave)
        capacity = plen + max_new

        last_logits, state = self.api.prefill(
            self.params, {"tokens": toks}, self.schedule, capacity=capacity,
            extra_groups=self.extra_groups)
        self.stats.prefill_tokens += b * plen

        current = self._sample(last_logits)
        alive = np.ones(b, bool)
        decode = self._decode_fn((b, capacity))
        for step in range(max_new):
            for bi, r in enumerate(wave):
                if alive[bi]:
                    tok = int(current[bi])
                    r.output.append(tok)
                    self.stats.generated_tokens += 1
                    if (r.eos_id is not None and tok == r.eos_id) or \
                            len(r.output) >= r.max_new_tokens:
                        alive[bi] = False
            if not alive.any() or step == max_new - 1:
                break
            logits, state = decode(self.params, state, current[:, None])
            current = self._sample(logits)
        for r in wave:
            r.done = True
        self.stats.waves += 1
        self.stats.wall_s += time.time() - t0

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.rng, sub = jax.random.split(self.rng)
        return jax.random.categorical(sub, logits).astype(jnp.int32)


def generate(api, params, schedule, prompts: np.ndarray, max_new_tokens: int,
             eos_id: int | None = None, **kw) -> tuple[np.ndarray, EngineStats]:
    """Convenience batched generation: prompts [B, S] → outputs [B, T]."""
    eng = ServeEngine(api, params, schedule, max_batch=prompts.shape[0], **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=np.asarray(p), eos_id=eos_id,
                           max_new_tokens=max_new_tokens))
    done = sorted(eng.run(), key=lambda r: r.uid)
    width = max(len(r.output) for r in done)
    out = np.zeros((len(done), width), np.int32)
    for i, r in enumerate(done):
        out[i, :len(r.output)] = r.output
    return out, eng.stats
