"""Serving engines with KVTuner mixed-precision KV cache.

Two schedulers over the same model API:

* ``ContinuousEngine`` (primary) — slot-based **continuous batching** over the
  shared paged KV pool (``repro.cache.paged``). A fixed ``max_batch`` of slots
  decodes in lock-step through ONE jitted step; a request that finishes frees
  its blocks and its slot admits the next queued request mid-decode. No
  (batch, capacity)-shaped recompiles: the decode step compiles once for the
  whole run regardless of the request mix. Optional **prefix caching**
  (``prefix_cache=True``) shares quantized prompt blocks between requests
  through a radix tree (``repro.cache.prefix``) and prefills only the
  non-cached suffix, chunked straight into pool blocks.

* ``ServeEngine`` (wave baseline) — buckets requests by exact prompt length
  into lock-step waves; each (batch, capacity) pair jits its own decode step
  and short requests hold their slot until the wave drains. Kept as the
  reference/baseline the benchmark compares against.

Both preserve the KVTuner property: the schedule is loaded once and every
layer's cache ops lower with **static** per-layer precision ("no online
decision overhead", paper §5). Throughput accounting mirrors the paper's
Table 8 definition: generated tokens per second end-to-end, including
quantization/dequantization work.

The continuous engine additionally carries the **request-lifecycle /
fault-tolerance layer** (see ``docs/paged_pool.md``, "Failure modes &
request lifecycle"): per-request deadlines (``Request.deadline_step``),
client cancellation (:meth:`ContinuousEngine.cancel`), graceful drain
(:meth:`ContinuousEngine.drain`), bounded-queue overload shedding
(``max_waiting``), NaN/Inf logit quarantine (``guard_nan``), deterministic
fault injection (``faults`` — ``repro.serving.faults``) and an engine-wide
invariant auditor (``audit`` — ``repro.serving.audit``). Every request ends
in exactly one terminal status::

    QUEUED -> PREFILLING -> DECODING -> {DONE, CANCELLED, TIMED_OUT,
                                         SHED, FAILED}
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import KVTunerSchedule
from repro.serving.telemetry import MetricsRegistry, QuantProbe
from repro.serving.trace import Tracer


class RequestStatus:
    """Request lifecycle states. ``QUEUED -> PREFILLING -> DECODING`` while
    in flight (preemption loops a request back to ``QUEUED``); exactly one
    of the ``TERMINAL`` states ends it. ``DONE`` is the only terminal state
    that also sets ``Request.done`` — everything else is a failure mode the
    engine survived."""

    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    DONE = "done"
    CANCELLED = "cancelled"      # client cancel()
    TIMED_OUT = "timed_out"      # deadline_step passed before completion
    SHED = "shed"                # dropped by overload shedding or drain
    FAILED = "failed"            # quarantined (NaN/corruption) or stalled

    TERMINAL = frozenset({DONE, CANCELLED, TIMED_OUT, SHED, FAILED})


@dataclasses.dataclass(eq=False)  # identity semantics: prompts are ndarrays
class Request:
    uid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    arrival_step: int = 0        # decode-step index when the request arrives
    priority: int = 0            # higher wins under the 'priority' scheduler
    # absolute decode-step deadline (TTL): the request must COMPLETE before
    # the engine's step counter reaches this value, or it is timed out at
    # the next host sync and its blocks/host state released. None = no TTL.
    deadline_step: int | None = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = RequestStatus.QUEUED
    error: str | None = None     # human-readable cause for non-DONE endings

    @property
    def terminal(self) -> bool:
        return self.status in RequestStatus.TERMINAL


@dataclasses.dataclass(eq=False)
class _Parked:
    """Device-side remains of a preempted request. ``entries`` maps each of
    its page-table positions to ``("dev", block)`` — a shared block the
    parked request still holds a reference on — or ``("host", handle)`` —
    an exclusively-owned block swapped out to the host tier. ``None``
    entries mean the blocks were dropped entirely (host tier full):
    resume recomputes from the prompt instead of swapping in."""

    entries: list | None
    residuals: list | None       # per-layer (k_res, v_res) host rows


# EngineStats facade routing: every int counter lives in the metrics
# registry as "engine.<field>" (so `stats.completed += 1` and a registry
# export see the same number), float occupancy/wall fields are gauges, and
# the old ad-hoc sample lists are bounded-reservoir histograms that iterate
# exactly like the lists they replace (see repro.serving.telemetry).
_STAT_COUNTERS = frozenset({
    "generated_tokens", "decode_tokens", "prefill_tokens", "waves",
    "decode_steps", "decode_dispatches", "admitted",
    # request-lifecycle terminal accounting (see RequestStatus)
    "completed", "cancelled", "timed_out", "shed", "failed", "quarantined",
    # prefix-cache accounting (continuous engine with prefix_cache=True)
    "prefix_hits", "prefix_misses", "prefix_hit_tokens",
    "prefix_evicted_blocks",
    # tiered-store accounting (host_blocks > 0 and/or preemption enabled)
    "preemptions", "resumes", "recompute_resumes", "replay_steps",
    "swap_out_blocks", "swap_in_blocks", "host_prefix_hits",
    "host_prefix_hit_tokens", "prefix_spilled_blocks",
    "prefix_dropped_blocks", "host_evicted_blocks",
    # device round-trips spent admitting requests: dense prefill + adopt
    # count one each; serial paged prefill one per request; batched
    # admission one per chunk wave (the number the batched path shrinks)
    "prefill_dispatches",
    # speculative decode accounting (continuous engine, speculate_k > 0):
    # a verify dispatch commits a VARIABLE number of tokens, so throughput
    # math must count committed tokens, never dispatches × slots
    "spec_steps", "drafted_tokens", "accepted_tokens",
})
_STAT_GAUGES = frozenset({
    "wall_s",
    # pool occupancy (allocated fraction of usable device blocks) at the
    # last allocator event; host-tier fill likewise
    "pool_utilization", "pool_high_watermark", "host_utilization",
})
_STAT_HISTS = {
    # per-DISPATCH decode wall clock (seconds): one real sample per device
    # round-trip, whatever its step count — percentiles are over dispatches
    "step_wall_times": "engine.decode_dispatch_wall_s",
    # per-prefill-dispatch wall clock and per-request admission-start →
    # first-token latency (seconds)
    "prefill_wall_times": "engine.prefill_dispatch_wall_s",
    "admit_latency_times": "engine.admit_latency_s",
    # committed tokens per live slot per spec dispatch (>= 1 each: the
    # verify of position 0 is a normal decode step) — p50/p95 below
    "accepted_lengths": "engine.accepted_len",
}


class EngineStats:
    """Engine accounting, as a facade over one
    :class:`~repro.serving.telemetry.MetricsRegistry`.

    Every public field keeps its historical name and semantics —
    ``generated_tokens``, ``decode_tokens`` (subset emitted by decode
    steps; the first token per request samples prefill logits), terminal
    counts, prefix/tier/speculation accounting, per-shard occupancy lists
    — but counters and gauges are registry-backed (``engine.<field>``) and
    the wall-time/accepted-length sample lists are bounded-reservoir
    histograms, so ``stats.registry.snapshot()`` /
    ``stats.registry.to_prometheus()`` export the whole surface. Plain
    attribute reads/writes (``stats.completed += 1``) route through
    ``__getattr__``/``__setattr__``; ``n_shards`` and the per-shard
    occupancy lists stay ordinary attributes (the mesh allocator is
    global, so each shard's fill equals the global fill — the lists keep
    that invariant visible in reports)."""

    def __init__(self, registry: MetricsRegistry | None = None):
        d = self.__dict__
        d["registry"] = MetricsRegistry() if registry is None else registry
        for n in sorted(_STAT_COUNTERS):
            self.registry.counter("engine." + n)
        for n in sorted(_STAT_GAUGES):
            self.registry.gauge("engine." + n)
        for m in _STAT_HISTS.values():
            self.registry.histogram(m)
        self.registry.histogram("engine.decode_dispatch_steps")
        d["n_shards"] = 1
        d["shard_pool_utilization"] = []
        d["shard_pool_high_watermark"] = []

    def __getattr__(self, name):
        reg = self.__dict__.get("registry")
        if reg is not None:
            if name in _STAT_COUNTERS:
                return reg.counter("engine." + name).value
            if name in _STAT_GAUGES:
                return reg.gauge("engine." + name).value
            if name in _STAT_HISTS:
                return reg.histogram(_STAT_HISTS[name])
        raise AttributeError(
            f"{type(self).__name__} has no attribute {name!r}")

    def __setattr__(self, name, value):
        if name in _STAT_COUNTERS:
            self.registry.counter("engine." + name).set(value)
        elif name in _STAT_GAUGES:
            self.registry.gauge("engine." + name).set(value)
        elif name in _STAT_HISTS:
            raise AttributeError(
                f"{name} is a histogram — append/extend it instead")
        else:
            object.__setattr__(self, name, value)

    @property
    def throughput(self) -> float:
        return self.generated_tokens / max(self.wall_s, 1e-9)

    def record_step_wall(self, seconds: float, steps: int = 1) -> None:
        """Record ONE decode dispatch: its real wall time plus its step
        count (no per-step smearing — a ``decode_horizon=4`` dispatch is
        one 4-step sample, so tail percentiles see true dispatch walls)."""
        self.step_wall_times.append(seconds)
        self.registry.histogram("engine.decode_dispatch_steps") \
            .observe(steps)
        self.decode_dispatches += 1

    def record_prefill_wall(self, seconds: float) -> None:
        self.prefill_wall_times.append(seconds)

    def record_admit_latency(self, seconds: float) -> None:
        self.admit_latency_times.append(seconds)

    @property
    def terminal_counts(self) -> dict:
        """Terminal-status breakdown — the lifecycle scoreboard surfaced by
        the table8/table11/table12/table13 reports."""
        return {"done": self.completed, "cancelled": self.cancelled,
                "timed_out": self.timed_out, "shed": self.shed,
                "failed": self.failed, "quarantined": self.quarantined}

    @staticmethod
    def _percentile(values, q: float) -> float:
        """Percentile that is safe on empty samples (0.0, never a raise) so
        reports from drained or all-shed runs don't crash. Accepts the
        registry histograms (iterated as their sample reservoirs) and
        plain lists alike."""
        vals = list(values)
        if not vals:
            return 0.0
        return float(np.percentile(np.asarray(vals), q))

    @classmethod
    def _percentile_ms(cls, values, q: float) -> float:
        return cls._percentile(values, q) * 1e3

    @property
    def decode_p50_ms(self) -> float:
        """Median decode DISPATCH wall time (one sample per device
        round-trip — a multi-step horizon dispatch is one sample)."""
        return self._percentile_ms(self.step_wall_times, 50)

    @property
    def decode_p95_ms(self) -> float:
        return self._percentile_ms(self.step_wall_times, 95)

    @property
    def prefill_p50_ms(self) -> float:
        """Median wall time of one prefill device dispatch (a full request
        on the serial paths; one chunk wave under batched admission)."""
        return self._percentile_ms(self.prefill_wall_times, 50)

    @property
    def prefill_p95_ms(self) -> float:
        return self._percentile_ms(self.prefill_wall_times, 95)

    @property
    def admit_p50_ms(self) -> float:
        """Median admission-start → first-sampled-token latency per
        request (page-table update + prefill + first sample)."""
        return self._percentile_ms(self.admit_latency_times, 50)

    @property
    def admit_p95_ms(self) -> float:
        return self._percentile_ms(self.admit_latency_times, 95)

    @property
    def decode_tokens_per_s(self) -> float:
        """Aggregate decode-emitted tokens/s over decode-step wall time only
        (prefill-sampled admission tokens and host scheduling excluded — the
        kernel-facing throughput number). ``decode_tokens`` counts actual
        committed tokens, so multi-token speculative commits are credited
        at their true count, not one-per-step-per-slot. Uses the dispatch
        histogram's exact running total (not the bounded reservoir)."""
        wall = self.step_wall_times
        if wall.count == 0:
            return 0.0
        return self.decode_tokens / max(wall.total, 1e-9)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the verify pass committed."""
        return self.accepted_tokens / max(self.drafted_tokens, 1)

    @property
    def accepted_len_p50(self) -> float:
        """Median committed tokens per live slot per verify dispatch."""
        return self._percentile(self.accepted_lengths, 50)

    @property
    def accepted_len_p95(self) -> float:
        return self._percentile(self.accepted_lengths, 95)


# ==================================================================== wave
class ServeEngine:
    """Wave-based batching baseline (see module docstring)."""

    def __init__(self, api, params, schedule: KVTunerSchedule | None,
                 max_batch: int = 8, extra_groups: int = 8,
                 greedy: bool = True, use_pallas: bool = False, seed: int = 0):
        self.api = api
        self.params = params
        self.schedule = schedule
        self.max_batch = max_batch
        self.extra_groups = extra_groups
        self.greedy = greedy
        self.use_pallas = use_pallas
        self.rng = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.stats = EngineStats()
        self._decode_jit = {}

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------ serving
    def _decode_fn(self, key):
        if key not in self._decode_jit:
            self._decode_jit[key] = jax.jit(
                partial(self.api.decode_step, use_pallas=self.use_pallas))
        return self._decode_jit[key]

    @property
    def decode_compilations(self) -> int:
        """Distinct decode-step compilations so far: one per (batch,
        capacity) bucket — the cost the continuous engine eliminates."""
        return len(self._decode_jit)

    def run(self) -> list[Request]:
        """Drain the queue; returns completed requests."""
        done: list[Request] = []
        buckets: dict[int, list[Request]] = defaultdict(list)
        for r in self.queue:
            buckets[len(r.prompt)].append(r)
        self.queue.clear()
        for plen, reqs in sorted(buckets.items()):
            for i in range(0, len(reqs), self.max_batch):
                wave = reqs[i:i + self.max_batch]
                self._run_wave(wave, plen)
                done.extend(wave)
        return done

    def _run_wave(self, wave: list[Request], plen: int) -> None:
        t0 = time.time()
        b = len(wave)
        toks = jnp.asarray(np.stack([r.prompt for r in wave]), jnp.int32)
        max_new = max(r.max_new_tokens for r in wave)
        capacity = plen + max_new

        last_logits, state = self.api.prefill(
            self.params, {"tokens": toks}, self.schedule, capacity=capacity,
            extra_groups=self.extra_groups)
        self.stats.prefill_tokens += b * plen

        current = self._sample(last_logits)
        np.asarray(current)  # sync so prefill/admission wall times are real
        self.stats.record_prefill_wall(time.time() - t0)
        self.stats.prefill_dispatches += 1
        for _ in wave:
            self.stats.record_admit_latency(time.time() - t0)
        alive = np.ones(b, bool)
        decode = self._decode_fn((b, capacity))
        for step in range(max_new):
            for bi, r in enumerate(wave):
                if alive[bi]:
                    tok = int(current[bi])
                    r.output.append(tok)
                    self.stats.generated_tokens += 1
                    if step:  # the step-0 token sampled prefill logits
                        self.stats.decode_tokens += 1
                    if (r.eos_id is not None and tok == r.eos_id) or \
                            len(r.output) >= r.max_new_tokens:
                        alive[bi] = False
            if not alive.any() or step == max_new - 1:
                break
            ts = time.time()
            logits, state = decode(self.params, state, current[:, None])
            current = self._sample(logits)
            np.asarray(current)  # sync so the step wall time is real
            self.stats.record_step_wall(time.time() - ts)
            self.stats.decode_steps += 1
        for r in wave:
            r.done = True
            r.status = RequestStatus.DONE
            self.stats.completed += 1
        self.stats.waves += 1
        self.stats.wall_s += time.time() - t0

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.rng, sub = jax.random.split(self.rng)
        return jax.random.categorical(sub, logits).astype(jnp.int32)


WaveEngine = ServeEngine


# ============================================================== continuous
class ContinuousEngine:
    """Slot-based continuous batching over the shared paged KV pool.

    * ``max_batch`` serving slots decode together through a single jitted
      step of fixed shape; padded/dead slots are masked via ``alive``.
    * Each request's blocks (one block = one quant group of R tokens) are
      allocated from the global pool at admission — enough for
      ``prompt + max_new_tokens`` — and recycled the moment it finishes, so
      the next queued request is admitted mid-decode into the freed slot.
    * ``arrival_step`` on a request simulates an online arrival process
      deterministically: the request only becomes visible once that many
      decode steps have executed (benchmarks drive this with Poisson draws).
    * ``prefill_paged`` switches admission from dense prefill + block
      adoption to **chunked in-pool prefill**: the prompt runs through the
      model in ``prefill_chunk``-token chunks that attend to already-written
      (quantized) pool blocks and write their own quantized groups straight
      into allocated blocks — no transient full-precision cache.
      ``prefill_chunk`` trades admission compile cost against sharing
      granularity: each trace unrolls ``suffix/chunk`` chunk passes, while
      prefixes are shared only in chunk multiples. The default (one quant
      group, R tokens) maximizes sharing; raise it (any multiple of R) for
      long-prompt workloads where prefill trace time dominates.
    * ``prefix_cache`` (implies ``prefill_paged``) additionally indexes every
      prefilled prompt's block chain in a radix tree (``repro.cache.prefix``)
      and admits later requests by pinning the longest cached prefix and
      prefilling only the suffix. Cached blocks are shared copy-on-write
      (read-only; refcounted) and evicted LRU under pool pressure. Greedy
      outputs are token-identical with the cache on or off.
    * ``batched_admission`` (implies ``prefill_paged``) prefills every
      request admissible at a tick **together**, as lock-step chunk waves
      through one retrace-free jitted dispatch (``prefill_paged_wave`` with
      traced per-slot context/chunk lengths; the fused ``qprefill_paged``
      kernel keeps the work per lane proportional to its live context): a
      burst of arrivals costs one device round-trip per chunk wave instead
      of one per request. Greedy outputs are token-identical batched or
      serial, kernel on or off.
    * ``scheduler`` picks the admission/preemption policy (``"fcfs"`` /
      ``"priority"`` / ``"ssf"`` or a ``SchedulerPolicy`` instance — see
      ``repro.serving.scheduler``). ``host_blocks`` adds a host-RAM tier
      (``repro.cache.offload``) that parks preempted requests' packed
      blocks and receives evicted radix prefixes (spill-instead-of-drop:
      a later match on a spilled chain swaps it back in and still counts
      as a hit). With a host tier, preemption is on by default: under pool
      pressure the scheduler parks a policy-chosen victim — swap-out is
      bitwise, so the victim resumes token-identically — instead of
      stalling the queue; when the host tier is full the victim's blocks
      are dropped and resume recomputes from the prompt (deterministic
      prefill + recorded-token replay, still token-identical). ``preempt``
      overrides the default (e.g. recompute-only preemption with no host
      tier).
    * ``speculate_k`` turns on speculative multi-token decode: a host-side
      ``Drafter`` (default: model-free prompt lookup over each request's own
      prompt + generated tokens, ``repro.serving.draft``) proposes up to k
      candidates per live slot, ONE device dispatch verifies all k+1
      positions against the quantized pool, and the longest
      greedy-consistent prefix is accepted — 1..k+1 tokens per request per
      dispatch, token-identical with ``speculate_k=0``. The default backend
      scans k+1 serial-shaped decode sub-steps inside the dispatch (bitwise
      = plain decode by construction) and **rolls back** the rejected
      tail's KV bitwise (``PagedKVPool.rollback_tail`` against pre-step
      snapshots — rejected tokens vanish from windows and blocks alike);
      ``fused_verify=True`` instead scores all k+1 positions in one wide
      forward pass (Pallas ``qverify_paged`` or its XLA oracle) and commits
      only accepted KV — fewer pool passes, but wide-matmul rounding may
      diverge from serial decode at near-tie argmaxes. Either way the
      post-step pool state is exactly the accepted prefix's, so preemption,
      prefix sharing and the host tier compose unchanged. Requires greedy
      decoding and ``speculate_k + 1 <= R`` (a commit flushes at most one
      quant group).

    * **Request lifecycle / fault tolerance**: every request ends in exactly
      one terminal :class:`RequestStatus`. ``Request.deadline_step`` is an
      absolute decode-step TTL enforced at every host sync;
      :meth:`cancel` aborts a request wherever it lives (queued, decoding,
      swap-parked on the host tier, mid-speculation) releasing its blocks,
      prefix pins and host handles without disturbing co-scheduled slots;
      :meth:`drain` stops admission, sheds the waiting queue and finishes
      live (slot-resident + preemption-parked) work; ``max_waiting`` bounds
      the arrived-but-waiting queue, shedding the scheduler's worst-ranked
      waiters (``SHED``) instead of queueing unboundedly; an admission that
      can make no progress for ``stall_ticks`` consecutive no-live-slot
      ticks fails THAT request (``FAILED``) instead of raising engine-wide.
      ``guard_nan=True`` (greedy, ``decode_horizon=1``, no speculation)
      checks sampled logits for NaN/Inf and quarantines only the poisoned
      slot — survivors keep decoding token-identically. ``faults`` accepts
      a :class:`repro.serving.faults.FaultInjector` for deterministic chaos
      schedules; ``audit=True`` cross-checks allocator refcounts, page
      tables, prefix chains and host-store entries at every host sync
      (``repro.serving.audit``).

    Restrictions (v1): attention-only stacks with global (non-windowed)
    attention; see ``repro.cache.paged``.
    """

    def __init__(self, api, params, schedule: KVTunerSchedule | None,
                 max_batch: int = 4, max_seq: int = 512,
                 num_blocks: int | None = None, greedy: bool = True,
                 use_pallas: bool = False, seed: int = 0,
                 prefill_paged: bool = False, prefix_cache: bool = False,
                 prefill_chunk: int | None = None, decode_horizon: int = 1,
                 batched_admission: bool = False,
                 scheduler="fcfs", host_blocks: int = 0,
                 preempt: bool | None = None, speculate_k: int = 0,
                 drafter=None, fused_verify: bool = False,
                 max_waiting: int | None = None, stall_ticks: int = 200,
                 guard_nan: bool = False, faults=None, audit: bool = False,
                 mesh=None, sharding_rules=None, trace: bool = False,
                 probe_every: int = 0, probe_blocks: int = 4,
                 probe_bits: tuple = (2, 2)):
        cfg = api.cfg
        self.api = api
        self.params = params
        self.schedule = schedule
        self.max_batch = max_batch
        self.group_size = cfg.kv_group_size
        # +1: a request needs (prompt+max_new)//R + 1 blocks in the worst case
        self.max_pages = max_seq // self.group_size + 1
        self.num_blocks = num_blocks if num_blocks is not None \
            else 1 + max_batch * self.max_pages
        self.greedy = greedy
        self.use_pallas = use_pallas
        self.rng = jax.random.PRNGKey(seed)
        self.stats = EngineStats()
        # batched admission prefills a burst of arrivals as lock-step chunk
        # waves straight into pool blocks — it implies the in-pool path
        self.batched_admission = batched_admission
        self.prefill_paged = prefill_paged or prefix_cache or batched_admission
        # default chunk = one quant group: finest sharing granularity (any
        # cached prefix of >= R tokens is usable), more chunks per prefill
        self.prefill_chunk = prefill_chunk if prefill_chunk is not None \
            else self.group_size
        if self.prefill_chunk <= 0 or self.prefill_chunk % self.group_size:
            raise ValueError(
                f"prefill_chunk ({self.prefill_chunk}) must be a positive "
                f"multiple of the quant group size ({self.group_size})")

        from repro.cache.offload import HostBlockStore
        from repro.cache.paged import BlockAllocator
        from repro.cache.prefix import PrefixCache
        from repro.serving.scheduler import make_scheduler

        self.state = api.init_paged_state(
            schedule, max_batch, self.num_blocks, self.max_pages)

        # ------------------------------------------------- mesh sharding
        # mesh=None keeps the classic single-device engine byte-for-byte.
        # With a mesh, the pool's packed codes/scales and residual windows
        # split over the `kv_heads` rules axis (one slice of every block
        # per device); page table, lengths, weights and the allocator stay
        # logically global — replicated tables, ONE allocation decision.
        # Greedy outputs are token-identical to single-device: attention is
        # embarrassingly parallel over KV heads and all replicated compute
        # is bitwise the same on every device (see models/attention.py
        # ``_head_sharded_call``).
        self.mesh = mesh
        self._rules = None
        self._shard_axis = None
        self._n_shards = 1
        if mesh is not None:
            from repro.distributed.sharding import make_rules
            self._rules = sharding_rules if sharding_rules is not None \
                else make_rules(mesh)
            ax = self._rules.axes("kv_heads", cfg.num_kv_heads)
            if isinstance(ax, str):
                self._shard_axis = ax
                self._n_shards = mesh.shape[ax]
            self.state = dataclasses.replace(
                self.state, pools=self._place_pools(self.state.pools),
                page_table=self._to_dev(self.state.page_table),
                lengths=self._to_dev(self.state.lengths))
            self.params = jax.device_put(self.params, self._replicated())
        self.stats.n_shards = self._n_shards

        self.alloc = BlockAllocator(self.num_blocks)
        # host tier: one capacity knob shared by prefix spills and
        # preemption parking — the host-RAM mirror of num_blocks
        self.host = HostBlockStore(host_blocks) if host_blocks > 0 else None
        self.prefix = PrefixCache(self.alloc, self.group_size,
                                  host_store=self.host) \
            if prefix_cache else None
        self.sched = make_scheduler(scheduler)
        # preemption defaults on exactly when a host tier exists to park
        # victims in; recompute-only preemption is opt-in (preempt=True)
        self.preempt_enabled = bool(host_blocks > 0) if preempt is None \
            else preempt
        self._parked: dict[int, _Parked] = {}
        self._pt = np.zeros((max_batch, self.max_pages), np.int32)
        self._slots: list[Request | None] = [None] * max_batch
        self._slot_pages: list[list[int]] = [[] for _ in range(max_batch)]
        self._reserved: set[int] = set()    # slots mid-batched-admission
        self._current = np.zeros(max_batch, np.int32)
        self._pending: list[Request] = []   # submitted, not yet arrived
        self._ready: list[Request] = []     # arrived, waiting for slot/blocks
        self._step_count = 0
        # decode horizon: H decode steps per device dispatch (lax.scan with
        # in-device sampling + EOS/budget masking); the host syncs for
        # admissions/finishes only every H steps. H=1 keeps the classic
        # step-sync loop. Greedy outputs are identical for any H; sampled
        # decoding uses a device-side rng stream, so only H=1 reproduces the
        # host sampler's draws.
        if decode_horizon < 1:
            raise ValueError(f"decode_horizon ({decode_horizon}) must be >= 1")
        self.decode_horizon = decode_horizon
        # donate the state: the pool is sized to fill HBM, so the step must
        # update it in place rather than hold old+new copies (no-op on CPU)
        # (`_with_rules` makes the engine's sharding rules ambient while a
        # jitted callable traces/runs, so attention picks the shard_map
        # path; identity when mesh is None — `_step_jit` keeps the raw jit
        # for the `decode_compilations` cache-size probe)
        self._step_jit = jax.jit(
            partial(api.paged_decode_step, use_pallas=use_pallas),
            donate_argnums=(1,))
        self._step = self._with_rules(self._step_jit)
        self._loop = self._with_rules(jax.jit(
            partial(api.paged_decode_loop, horizon=decode_horizon,
                    use_pallas=use_pallas, greedy=greedy),
            donate_argnums=(1,)))
        # NOTE: adoption (like any prefill) traces per distinct prompt-group
        # count — that is admission cost, paid once per request; the decode
        # step above stays single-compile for the whole run.
        self._adopt = self._with_rules(
            jax.jit(api.paged_adopt, donate_argnums=(0,)))
        # chunked in-pool prefill: retraces once per distinct
        # (suffix length, shared-prefix length) pair — `start` is static so
        # each chunk attends only the live context blocks, not max_pages
        self._prefill = self._with_rules(jax.jit(
            partial(api.prefill_paged, chunk=self.prefill_chunk,
                    use_pallas=use_pallas),
            static_argnums=(4,), donate_argnums=(1,)))
        # batched admission wave: per-slot context/chunk lengths are traced
        # (the fused prefill kernel is length-aware), so this compiles ONCE
        # and serves every burst composition — one device round-trip per
        # chunk wave instead of per request
        self._wave = self._with_rules(jax.jit(
            partial(api.prefill_paged_wave, use_pallas=use_pallas),
            donate_argnums=(1,)))
        # speculative decode: acceptance is greedy-consistency, and the
        # single-flush rollback bound requires a whole speculative commit
        # (k accepted drafts + 1 bonus token) to fit in one quant group
        if speculate_k < 0:
            raise ValueError(f"speculate_k ({speculate_k}) must be >= 0")
        if speculate_k:
            if not greedy:
                raise ValueError(
                    "speculate_k requires greedy decoding (acceptance keeps "
                    "the longest greedy-consistent draft prefix)")
            if speculate_k + 1 > self.group_size:
                raise ValueError(
                    f"speculate_k + 1 ({speculate_k + 1}) must be <= the "
                    f"quant group size ({self.group_size})")
        self.speculate_k = speculate_k
        self.fused_verify = fused_verify
        from repro.serving.draft import PromptLookupDrafter
        self.drafter = drafter if drafter is not None else PromptLookupDrafter()
        self._spec = self._with_rules(jax.jit(
            partial(api.paged_spec_step, use_pallas=use_pallas,
                    fused=fused_verify),
            donate_argnums=(1,)))

        # ---------------------------------------- lifecycle / fault layer
        if max_waiting is not None and max_waiting < 1:
            raise ValueError(f"max_waiting ({max_waiting}) must be >= 1")
        if stall_ticks < 1:
            raise ValueError(f"stall_ticks ({stall_ticks}) must be >= 1")
        if guard_nan and (decode_horizon > 1 or speculate_k or not greedy):
            raise ValueError(
                "guard_nan requires greedy decoding with decode_horizon=1 "
                "and speculate_k=0 (the quarantine check reads each "
                "dispatch's logits on the host)")
        self.max_waiting = max_waiting
        self.stall_ticks = stall_ticks
        self.guard_nan = guard_nan
        self.audit_enabled = audit
        self._draining = False
        self._stall = 0                      # consecutive no-progress ticks
        self._uids: set = set()              # every uid ever submitted
        self._by_uid: dict[int, Request] = {}
        self._done: list[Request] = []       # terminal requests, any status
        self._poison_uids: set = set()       # pending NaN-poison injections

        # ------------------------------------------------ telemetry layer
        # tracer=None keeps every hook site a dead `is not None` check —
        # a tracing-off run executes no telemetry code at all. Tracing adds
        # no device syncs (it reuses the walls the engine already measures
        # around host-synced dispatches), so traced greedy outputs are
        # token-identical to untraced ones. The quant-quality probe fires
        # every `probe_every` host syncs (0 = off) and only READS pool
        # state — see repro.serving.telemetry.QuantProbe.
        self.tracer = Tracer() if trace else None
        self.probe = QuantProbe(probe_every, probe_blocks, probe_bits,
                                seed=seed) if probe_every else None
        self._stream_cache = None

        self.faults = faults
        if faults is not None:
            faults.attach(self)

    @property
    def metrics(self) -> MetricsRegistry:
        """The engine's metrics registry (owned by ``stats`` — resetting
        ``eng.stats`` starts a fresh registry, as the warm-round
        benchmarks rely on)."""
        return self.stats.registry

    # --------------------------------------------------- telemetry helpers
    def _ctx_lens(self) -> np.ndarray:
        """Per-slot cached KV lengths (tokens) for live, non-reserved
        slots — the input to the pool's analytic byte counters."""
        lens = np.zeros(self.max_batch, np.int64)
        for i, req in enumerate(self._slots):
            if req is None or i in self._reserved:
                continue
            lens[i] = len(req.prompt) + len(req.output) - 1
        return lens[lens > 0]

    def _stream_const(self) -> tuple:
        """Per-shard byte constants of the pools' analytic stream counters
        (``repro.cache.paged``), summed over layers and cached: pool shapes
        never change mid-run, and recomputing them per dispatch costs more
        than a tiny model's dispatch itself. ``(block, residual,
        per-token fp unit)`` bytes."""
        if self._stream_cache is None:
            ns = self._n_shards
            blk = res = unit = 0
            for p in self.state.pools:
                if p is None:
                    continue
                blk += p.block_bytes(ns)
                rb = int(np.prod(p.k_res.shape[1:])) * p.k_res.dtype.itemsize
                res += rb // ns
                unit += p.k_res.shape[1] * p.head_dim \
                    * p.k_res.dtype.itemsize // ns
            self._stream_cache = (blk, res, unit)
        return self._stream_cache

    def _fetched(self, lens) -> int:
        # lengths floor to full groups; a zero-context slot still fetches
        # one aliased block (same rule as the pool counters)
        if len(lens) == 0:
            return 0
        return int(np.sum(np.maximum(np.asarray(lens) // self.group_size,
                                     1)))

    def _decode_bytes(self, lens, steps: int = 1) -> int:
        """Analytic per-device HBM bytes of ``steps`` fused decode launches
        at pre-dispatch lengths ``lens`` (a horizon's later steps stream a
        group more at most — the counters floor to full groups anyway)."""
        blk, res, _ = self._stream_const()
        return steps * (self._fetched(lens) * blk + 2 * len(lens) * res)

    def _verify_bytes(self, lens, n_tokens: int) -> int:
        blk, res, unit = self._stream_const()
        return self._fetched(lens) * blk \
            + 2 * len(lens) * (res + unit * n_tokens)

    def _prefill_bytes(self, ctx_lens, chunk: int) -> int:
        blk, _, unit = self._stream_const()
        return self._fetched(ctx_lens) * blk \
            + 2 * len(ctx_lens) * unit * chunk

    def _note_dispatch(self, kind: str, t0: float, t1: float, n_bytes: int,
                       span: str | None = None, **args) -> None:
        """Record one device dispatch in the telemetry layer: cumulative
        analytic stream-bytes counter, achieved-bandwidth gauge (analytic
        bytes over the measured host-synced wall — the serve-time number
        roofline.py's peak-bandwidth model is compared against), and an
        engine-track trace span. Only called when tracing is on."""
        reg = self.stats.registry
        reg.counter(f"engine.{kind}_stream_bytes").inc(n_bytes)
        reg.gauge(f"engine.{kind}_achieved_gbps").set(
            n_bytes / max(t1 - t0, 1e-9) / 1e9)
        self.tracer.engine_span(span or f"{kind}_dispatch", t0, t1,
                                bytes=n_bytes, **args)

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        """Validate and enqueue one request. Malformed requests are rejected
        here with a precise message (never a mid-serve crash); requests
        submitted while the engine is draining are SHED instead of queued."""
        if req.uid in self._uids:
            raise ValueError(
                f"request {req.uid}: duplicate request id (a request with "
                "this uid was already submitted to this engine)")
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.max_new_tokens <= 0:
            raise ValueError(
                f"request {req.uid}: max_new_tokens "
                f"({req.max_new_tokens}) must be positive")
        if req.deadline_step is not None:
            if req.deadline_step <= self._step_count:
                raise ValueError(
                    f"request {req.uid}: deadline_step "
                    f"({req.deadline_step}) is already in the past — the "
                    f"engine is at step {self._step_count}")
            if req.deadline_step <= req.arrival_step:
                raise ValueError(
                    f"request {req.uid}: deadline_step "
                    f"({req.deadline_step}) is at or before its "
                    f"arrival_step ({req.arrival_step}); it can never "
                    "complete in time")
        need = self._pages_needed(req)
        if need > self.max_pages:
            raise ValueError(
                f"request {req.uid}: prompt+max_new "
                f"({len(req.prompt)}+{req.max_new_tokens}) exceeds engine "
                f"max_seq (needs {need} pages, table holds {self.max_pages})")
        if need > self.num_blocks - 1:
            raise ValueError(
                f"request {req.uid}: needs {need} blocks, pool has "
                f"{self.num_blocks - 1}")
        self._uids.add(req.uid)
        self._by_uid[req.uid] = req
        if self.tracer is not None:
            self.tracer.begin(req.uid)
        if self._draining:
            self._finish(req, RequestStatus.SHED,
                         "engine is draining: admission stopped")
            return
        req.status = RequestStatus.QUEUED
        self._pending.append(req)

    def _pages_needed(self, req: Request) -> int:
        return (len(req.prompt) + req.max_new_tokens) // self.group_size + 1

    @property
    def decode_compilations(self) -> int:
        """Distinct decode-step compilations (the acceptance metric): stays
        at 1 for any mix of prompt lengths and admission points."""
        try:
            return int(self._step_jit._cache_size())
        except AttributeError:  # older jax: one fixed-shape step → 1 compile
            return 1 if self.stats.decode_steps else 0

    # ------------------------------------------------------- mesh plumbing
    def _replicated(self):
        from jax.sharding import NamedSharding, PartitionSpec
        return NamedSharding(self.mesh, PartitionSpec())

    def _to_dev(self, x):
        """Host array → device. Single-device: plain (uncommitted) upload.
        Mesh: commit replicated, so page-table/length pushes never hand the
        jitted step an array whose placement disagrees with the sharded
        pools (mixing differently-committed inputs is a jit error)."""
        x = jnp.asarray(x)
        if self.mesh is None:
            return x
        return jax.device_put(x, self._replicated())

    def _place_pools(self, pools: list) -> list:
        """Commit every pool array to the mesh: arrays with the KV-head dim
        (always dim 1: packed codes, scales/zeros, residual windows) split
        over the kv_heads axis, dummy 1-D scale placeholders replicated.
        Also the re-placement point after host-tier swap-ins, whose eager
        scatters may lose the sharding layout."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        ax = self._shard_axis

        def place(a):
            spec = P(None, ax) if ax is not None and jnp.ndim(a) >= 2 \
                else P()
            return jax.device_put(a, NamedSharding(self.mesh, spec))

        return [jax.tree.map(place, p) if p is not None else None
                for p in pools]

    def _with_rules(self, fn):
        """Wrap a jitted callable so the engine's sharding rules are the
        ambient rules while it traces and runs (attention consults
        ``active_rules()`` to pick the KV-head shard_map path). Identity
        when the engine has no mesh."""
        if self._rules is None:
            return fn
        from repro.distributed.sharding import use_rules
        rules = self._rules

        def run(*args):
            with use_rules(rules):
                return fn(*args)
        return run

    # ----------------------------------------------------- lifecycle layer
    def _finish(self, req: Request, status: str,
                error: str | None = None) -> None:
        """Move ``req`` to terminal ``status`` and record it. The single
        bookkeeping choke point: every request passes through here exactly
        once, whatever ends it."""
        req.status = status
        req.error = error
        if status == RequestStatus.DONE:
            req.done = True
            self.stats.completed += 1
        elif status == RequestStatus.CANCELLED:
            self.stats.cancelled += 1
        elif status == RequestStatus.TIMED_OUT:
            self.stats.timed_out += 1
        elif status == RequestStatus.SHED:
            self.stats.shed += 1
        elif status == RequestStatus.FAILED:
            self.stats.failed += 1
        if self.tracer is not None:
            self.tracer.finish(req.uid, status, error)
        self._done.append(req)

    def _release_slot(self, slot: int) -> None:
        """Free a slot and every block reference it holds (own blocks AND
        pinned prefix-chain blocks — the pin is just a refcount). Dead slots
        are masked out of the next dispatch by ``alive``; the stale page-
        table row is rewritten at the next admission into the slot."""
        self.alloc.release(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self._slots[slot] = None
        self._note_pool()

    def _drop_parked(self, req: Request) -> None:
        """Release a parked request's tier state: host handles for swapped-
        out blocks, device refs for shared blocks it kept pinned. A spilled
        prefix chain whose only non-tree holder was this request becomes
        evictable again (and cascade-drops with its ancestors later)."""
        parked = self._parked.pop(req.uid, None)
        if parked is None or parked.entries is None:
            return
        host = [v for kind, v in parked.entries if kind == "host"]
        if host:
            self.host.release(host)
        self.alloc.release([v for kind, v in parked.entries
                            if kind == "dev"])
        self._note_pool()

    def cancel(self, uid: int, status: str = RequestStatus.CANCELLED,
               error: str | None = None) -> bool:
        """Abort request ``uid`` wherever it currently lives — pending,
        waiting, swap- or recompute-parked, or slot-resident mid-decode /
        mid-speculation. Releases its blocks, prefix pins and host-tier
        state; co-scheduled slots are untouched (their next dispatch just
        masks the freed slot dead). Returns False when the uid is unknown
        or already terminal. ``status``/``error`` let the lifecycle sweeps
        reuse this path for TIMED_OUT / SHED endings."""
        req = self._by_uid.get(uid)
        if req is None or req.terminal:
            return False
        if req in self._slots:
            slot = self._slots.index(req)
            if slot in self._reserved:
                # reserved mid-batched-admission: pages not yet attached to
                # the slot — unreachable from host-sync hooks, guard anyway
                return False
            self._release_slot(slot)
        else:
            if req in self._pending:
                self._pending.remove(req)
            if req in self._ready:
                self._ready.remove(req)
            self._drop_parked(req)
        self._poison_uids.discard(uid)
        self._finish(req, status, error)
        return True

    def drain(self) -> None:
        """Graceful drain: stop admission and finish live work. Waiting
        requests that never started (pending arrivals + arrived-but-queued)
        are SHED immediately; slot-resident requests and preemption-parked
        requests (work in flight) run to completion. Later ``submit()``
        calls are SHED on arrival. Idempotent."""
        self._draining = True
        for r in list(self._pending):
            self.cancel(r.uid, RequestStatus.SHED,
                        "engine drained before admission")
        for r in list(self._ready):
            if r.uid not in self._parked:
                self.cancel(r.uid, RequestStatus.SHED,
                            "engine drained before admission")

    @property
    def draining(self) -> bool:
        return self._draining

    def _shed_overflow(self) -> None:
        """Bounded admission queue: while more than ``max_waiting`` fresh
        (non-parked) requests wait, shed the scheduler's worst-ranked one.
        Parked requests are work in flight and never count against the
        bound (their state is already paid for)."""
        if self.max_waiting is None:
            return
        while True:
            fresh = [r for r in self._ready if r.uid not in self._parked]
            if len(fresh) <= self.max_waiting:
                return
            victim = max(fresh,
                         key=lambda r: self.sched.shed_key(r, self))
            self.cancel(victim.uid, RequestStatus.SHED,
                        f"admission queue over capacity "
                        f"(max_waiting={self.max_waiting})")

    def _lifecycle_tick(self) -> None:
        """Host-sync lifecycle sweep, run once per serve-loop iteration:
        fire the fault injector's scheduled actions, then time out every
        non-terminal request whose ``deadline_step`` has passed (waiting or
        running — blocks, pins and host state are released either way)."""
        if self.faults is not None:
            self.faults.on_tick(self)
        expired = [r for r in (self._pending + self._ready
                               + [s for s in self._slots if s is not None])
                   if r.deadline_step is not None
                   and self._step_count >= r.deadline_step]
        for r in expired:
            self.cancel(r.uid, RequestStatus.TIMED_OUT,
                        f"deadline_step {r.deadline_step} passed at "
                        f"engine step {self._step_count}")

    def _quarantine(self, slot: int, reason: str) -> None:
        """Isolate a poisoned slot: free its state and FAIL the request.
        Slots never mix in attention (per-slot page tables), so survivors
        of the same dispatch are token-identical to an unfaulted run."""
        req = self._slots[slot]
        self._release_slot(slot)
        self._poison_uids.discard(req.uid)
        self.stats.quarantined += 1
        if self.tracer is not None:
            self.tracer.event(req.uid, "quarantine", reason=reason)
        self._finish(req, RequestStatus.FAILED, reason)

    def audit(self) -> dict:
        """Run the engine-wide invariant auditor (leak/aliasing detector
        across allocator, page tables, prefix chains and host store);
        raises ``repro.serving.audit.AuditError`` on any violation — after
        recording it in the telemetry layer, so violations are observable
        even when the caller swallows the exception."""
        from repro.serving.audit import AuditError, audit_engine

        try:
            return audit_engine(self)
        except AuditError as e:
            self.stats.registry.counter("faults.audit_violations").inc()
            if self.tracer is not None:
                self.tracer.engine_event("audit_violation", error=str(e))
            raise

    # ---------------------------------------------------------- admission
    def _free_slot(self) -> int | None:
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def _try_admit(self) -> None:
        """Scheduler-ordered admission: fill free slots while the pool has
        blocks. With the prefix cache on, each admission first pins the
        longest cached prefix — swapping host-resident chain links back in —
        so only the suffix needs fresh blocks (and prefill). With
        ``batched_admission``, every request admissible at a tick is
        reserved first and then prefilled together as lock-step chunk
        waves (:meth:`_admit_batch`) — one device dispatch per wave for
        the whole burst instead of one (or more) per request. When the
        queue head cannot be admitted and preemption is enabled, the
        scheduler may park a running victim (:meth:`_preempt`) instead of
        stalling. A burst member that finishes instantly frees its slot;
        the outer loop re-collects so waiting requests can take it (as the
        serial path's rolling while-loop does)."""
        while True:
            self._ready.sort(key=lambda r: self.sched.admission_key(r, self))
            batch: list = []
            while self._ready:
                req = self._ready[0]
                parked = self._parked.get(req.uid)
                if parked is not None and parked.entries is not None:
                    # swap-in resume: no prefill, just blocks + residuals
                    if self._resume_swap(req, parked):
                        self._ready.pop(0)
                        continue
                    if self.preempt_enabled and self._preempt_for(req):
                        continue
                    break  # head-of-line waits for slot/blocks
                res = self._reserve(req, resuming=parked is not None)
                if res is None:
                    if self.preempt_enabled and self._preempt_for(req):
                        continue
                    break  # head-of-line waits for blocks to free up
                self._ready.pop(0)
                slot, pages, n_shared = res
                if parked is not None:
                    # recompute fallback: re-prefill + replay recorded
                    # tokens (never batched — replay is per-slot serial)
                    self._admit(req, slot, pages, n_shared=n_shared,
                                replay=True)
                elif self.batched_admission:
                    self._slots[slot] = req  # reserve the slot for the burst
                    self._reserved.add(slot)
                    batch.append((req, slot, pages, n_shared))
                else:
                    self._admit(req, slot, pages, n_shared=n_shared)
            if not batch:
                return
            self._admit_batch(batch)
            self._reserved.clear()
            if not self._ready:
                return

    def _match_chain(self, req: Request) -> list:
        """Longest usable cached prefix of this prompt, as radix nodes
        (device- or host-resident).

        The match is capped below the full prompt (at least one suffix token
        must run so admission has logits to sample from) and truncated to a
        multiple of the prefill chunk: chunk boundaries are quantization
        context boundaries, so only chunk-aligned sharing reproduces the
        cache-off computation bit-for-bit.
        """
        nodes = self.prefix.match_nodes(req.prompt)
        r = self.group_size
        per_chunk = self.prefill_chunk // r
        n = min(len(nodes), (len(req.prompt) - 1) // r)
        return nodes[:n // per_chunk * per_chunk]

    def suffix_tokens(self, req: Request) -> int:
        """Prompt tokens an admission of ``req`` would actually prefill
        (scheduler hook): zero while it runs or sits swap-parked, otherwise
        its prompt minus the longest usable cached prefix."""
        parked = self._parked.get(req.uid)
        if parked is not None and parked.entries is not None:
            return 0
        if req in self._slots:
            return 0
        if self.prefix is None:
            return len(req.prompt)
        return len(req.prompt) - len(self._match_chain(req)) * self.group_size

    def _reserve(self, req: Request, resuming: bool = False):
        """Slot + blocks for one admission: pin the longest usable cached
        prefix (swapping host-resident chain links back into fresh device
        blocks — a *host-tier hit*), then allocate fresh blocks for the
        rest. Returns ``(slot, pages, n_shared)``, or ``None`` (with
        nothing pinned or allocated) when a slot or blocks are missing.
        ``resuming`` (recompute resume of a preempted request) suppresses
        the hit/miss counters — the request was already counted at its
        original admission; physical swap traffic is still recorded."""
        slot = self._free_slot()
        if slot is None:
            return None
        chain = self._match_chain(req) if self.prefix is not None else []
        dev = [n.block for n in chain if n.on_device]
        hst = [n for n in chain if not n.on_device]
        if dev:
            self.alloc.ref(dev)       # pin before eviction reaps them
        if hst:
            # shield the host copies from host-tier LRU drops while the
            # allocation below spills other chains into the store
            self.host.ref([n.host for n in hst])
        pages = self._alloc_with_eviction(self._pages_needed(req) - len(dev))
        if pages is None:
            if dev:
                self.alloc.release(dev)   # unpin; retry next tick
            if hst:
                self.host.release([n.host for n in hst])
            return None
        if hst:
            from repro.cache.offload import HostStoreError

            handles = [n.host for n in hst]
            dst = pages[:len(hst)]
            try:
                pools = self.host.take_to_device(self.state.pools, handles,
                                                 dst)
            except HostStoreError:
                # host-tier read failure: unwind every pin taken above and
                # drop the unreachable host chain from the tree so the next
                # match stops at the device-resident prefix instead
                if dev:
                    self.alloc.release(dev)
                self.host.release(handles)      # our shield
                self.alloc.release(pages)
                self.prefix.drop_chain(hst[0])
                return None
            self.state = dataclasses.replace(self.state, pools=pools)
            self.alloc.ref(dst)            # the tree's reference moves tiers
            self.host.release(handles)     # ... so its host reference drops
            self.host.release(handles)     # ... and so does our shield
            for n, b in zip(hst, dst):
                n.block, n.host = b, None
            self.stats.swap_in_blocks += len(hst)
            if not resuming:
                self.stats.host_prefix_hits += 1
                self.stats.host_prefix_hit_tokens += \
                    len(hst) * self.group_size
        if self.prefix is not None and not resuming:
            if chain:
                self.stats.prefix_hits += 1
                self.stats.prefix_hit_tokens += len(chain) * self.group_size
                if self.tracer is not None:
                    self.tracer.event(
                        req.uid, "prefix_match",
                        tokens=len(chain) * self.group_size,
                        host_blocks=len(hst))
            else:
                self.stats.prefix_misses += 1
        self._note_pool()
        return slot, [n.block for n in chain] + pages[len(hst):], len(chain)

    def _alloc_with_eviction(self, n: int) -> list[int] | None:
        """Allocate n blocks, evicting LRU cached prefixes under pressure
        (spilled to the host tier when one is attached, dropped otherwise).
        Eviction is one tree pass for exactly the deficit, and refuses when
        it cannot reach it — a doomed attempt leaves the cache intact."""
        pages = self.alloc.alloc(n)
        if pages is None and self.prefix is not None:
            pc = self.prefix
            before = (pc.spilled_blocks, pc.dropped_blocks,
                      pc.host_dropped_blocks)
            freed = pc.evict(n - self.alloc.free_blocks,
                             pools=self.state.pools)
            if freed:
                self.stats.prefix_evicted_blocks += freed
                self.stats.prefix_spilled_blocks += \
                    pc.spilled_blocks - before[0]
                self.stats.prefix_dropped_blocks += \
                    pc.dropped_blocks - before[1]
                self.stats.host_evicted_blocks += \
                    pc.host_dropped_blocks - before[2]
                pages = self.alloc.alloc(n)
        self._note_pool()
        return pages

    def _note_pool(self) -> None:
        self.stats.pool_utilization = self.alloc.utilization
        self.stats.pool_high_watermark = \
            self.alloc.high_watermark / max(self.num_blocks - 1, 1)
        # per-shard occupancy: allocation is global (one decision covers
        # every shard's slice of a block), so each shard's fill equals the
        # global fill — recorded per shard to keep reports honest about it
        self.stats.shard_pool_utilization = \
            [self.stats.pool_utilization] * self._n_shards
        self.stats.shard_pool_high_watermark = \
            [self.stats.pool_high_watermark] * self._n_shards
        if self.host is not None and self.host.capacity:
            self.stats.host_utilization = len(self.host) / self.host.capacity

    # --------------------------------------------------- preemption / tiers
    def _preempt_for(self, waiting: Request) -> bool:
        """Preempt ONE scheduler-approved victim to make room for
        ``waiting``; False when no victim qualifies (the queue head then
        stalls exactly as without preemption). Victims are chosen by the
        policy's ``victim_key``; slots reserved for an in-flight admission
        burst are never preempted."""
        victims = [(s, r) for s, r in enumerate(self._slots)
                   if r is not None and s not in self._reserved
                   and self.sched.wants_preempt(waiting, r, self)]
        if not victims:
            return False
        slot, _ = min(victims,
                      key=lambda sr: self.sched.victim_key(sr[1], self))
        self._preempt(slot)
        return True

    def _preempt(self, slot: int) -> None:
        """Park the request running in ``slot``. Its exclusively-owned
        blocks (refcount 1) swap out to the host tier in one batched
        transfer — bitwise, so resume is token-identical — together with
        its per-layer residual windows; shared blocks keep their reference
        (other owners are serving from them anyway, and the kept pin stops
        the radix tree from spilling them underneath the parked request).
        When the host tier cannot hold the exclusive blocks even after
        dropping cold host entries, everything is released instead and the
        resume replays from the prompt (recompute fallback — deterministic
        chunked prefill + recorded-token replay, still token-identical)."""
        from repro.cache import offload

        req = self._slots[slot]
        pages = self._slot_pages[slot]
        excl = [b for b in pages if self.alloc.refcount(b) == 1]
        handles = None
        if self.host is not None:
            short = len(excl) - self.host.free_slots
            if short > 0 and self.prefix is not None:
                self.stats.host_evicted_blocks += \
                    self.prefix.drop_host_lru(short)
            handles = self.host.put_blocks(self.state.pools, excl)
        if handles is None:
            # recompute fallback: shared references drop too — resume is a
            # full re-admission (prefix re-match included) plus replay
            self.alloc.release(pages)
            self._parked[req.uid] = _Parked(entries=None, residuals=None)
        else:
            hmap = dict(zip(excl, handles))
            entries = [("host", hmap[b]) if b in hmap else ("dev", b)
                       for b in pages]
            residuals = offload.extract_residual(self.state.pools, slot)
            self.alloc.release(excl)
            self._parked[req.uid] = _Parked(entries=entries,
                                            residuals=residuals)
            self.stats.swap_out_blocks += len(excl)
        self.stats.preemptions += 1
        if self.tracer is not None:
            self.tracer.event(
                req.uid, "preempt",
                mode="recompute" if handles is None else "swap",
                blocks=len(excl))
            self.tracer.phase(req.uid, "queued")
        self._slots[slot] = None
        self._slot_pages[slot] = []
        req.status = RequestStatus.QUEUED
        self._ready.append(req)
        # keep the waiting queue policy-ordered mid-pass: the victim must
        # not sit behind lower-ranked requests for the rest of this tick
        self._ready.sort(key=lambda r: self.sched.admission_key(r, self))
        self._note_pool()

    def _resume_swap(self, req: Request, parked: _Parked) -> bool:
        """Un-park a swap-preempted request into a free slot: allocate fresh
        device blocks for its host-tier entries, swap the packed bytes back
        in (one batched transfer), restore its residual windows, page-table
        row, cached length, and current token. Bitwise — decode continues
        exactly where preemption stopped it."""
        from repro.cache import offload

        slot = self._free_slot()
        if slot is None:
            return False
        n_host = sum(1 for kind, _ in parked.entries if kind == "host")
        fresh = self._alloc_with_eviction(n_host)
        if fresh is None:
            return False
        handles = [v for kind, v in parked.entries if kind == "host"]
        it = iter(fresh)
        pages = [v if kind == "dev" else next(it)
                 for kind, v in parked.entries]
        pools = self.state.pools
        if handles:
            from repro.cache.offload import HostStoreError

            try:
                pools = self.host.take_to_device(pools, handles, fresh)
            except HostStoreError:
                # host-tier read failure: the parked bytes are unreachable —
                # demote this request to the recompute-from-prompt fallback
                # (deterministic replay keeps it token-identical) and free
                # everything the swap-in path had staged
                self.alloc.release(fresh)
                self.host.release(handles)
                self.alloc.release([v for kind, v in parked.entries
                                    if kind == "dev"])
                parked.entries = None
                parked.residuals = None
                self._note_pool()
                return False
            self.host.release(handles)
        pools = offload.scatter_residual(pools, parked.residuals, slot)
        if self.mesh is not None:
            # host-tier scatters run eagerly and may hand back arrays with
            # a propagated (not committed-by-rule) layout; re-commit so the
            # jitted step's input shardings never drift mid-run
            pools = self._place_pools(pools)
        self._pt[slot, :] = 0
        self._pt[slot, :len(pages)] = pages
        lengths = self.state.lengths.at[slot].set(
            len(req.prompt) + len(req.output) - 1)
        self.state = dataclasses.replace(
            self.state, pools=pools, lengths=lengths,
            page_table=self._to_dev(self._pt))
        self._slots[slot] = req
        self._slot_pages[slot] = pages
        self._current[slot] = req.output[-1]
        req.status = RequestStatus.DECODING
        del self._parked[req.uid]
        self.stats.swap_in_blocks += n_host
        self.stats.resumes += 1
        if self.tracer is not None:
            self.tracer.event(req.uid, "swap_in", blocks=n_host)
            self.tracer.phase(req.uid, "decode")
        self._note_pool()
        return True

    def _demote_parked_lru(self) -> bool:
        """Last-resort deadlock breaker: a swap-parked request pins its
        shared blocks and host handles; when admission stalls with no live
        slots, converting one parked request to recompute releases those
        pins so the queue head can proceed."""
        for parked in self._parked.values():
            if parked.entries is None:
                continue
            host = [v for kind, v in parked.entries if kind == "host"]
            if host:
                self.host.release(host)
            self.alloc.release([v for kind, v in parked.entries
                                if kind == "dev"])
            parked.entries = None
            parked.residuals = None
            self._note_pool()
            return True
        return False

    def _replay(self, req: Request, slot: int) -> None:
        """Rebuild a recompute-parked request's decode state bitwise by
        feeding its recorded tokens back through the normal decode step
        (every KV append lands exactly where the original decode put it;
        the logits are discarded — outputs were already emitted)."""
        out = req.output
        alive = np.zeros(self.max_batch, bool)
        alive[slot] = True
        alive_dev = jnp.asarray(alive)
        for t in range(len(out) - 1):
            tokens = np.zeros(self.max_batch, np.int32)
            tokens[slot] = out[t]
            _, self.state = self._step(
                self.params, self.state, jnp.asarray(tokens[:, None]),
                alive_dev)
            self.stats.replay_steps += 1
        self._current[slot] = out[-1]
        del self._parked[req.uid]
        self.stats.recompute_resumes += 1
        if self.tracer is not None:
            self.tracer.event(req.uid, "recompute_replay",
                              steps=max(len(out) - 1, 0))

    def _admit(self, req: Request, slot: int, pages: list[int],
               n_shared: int = 0, replay: bool = False) -> None:
        t0 = time.time()
        req.status = RequestStatus.PREFILLING
        if self.tracer is not None:
            self.tracer.phase(req.uid, "prefill")
        plen = len(req.prompt)
        self._pt[slot, :] = 0
        self._pt[slot, :len(pages)] = pages
        self.state = dataclasses.replace(
            self.state, page_table=self._to_dev(self._pt))

        if self.prefill_paged:
            # chunked in-pool prefill of the non-cached suffix only
            start = n_shared * self.group_size
            toks = jnp.asarray(np.asarray(req.prompt)[None, start:],
                               jnp.int32)
            ts = time.time()
            last_logits, self.state = self._prefill(
                self.params, self.state, toks, jnp.int32(slot), start)
            np.asarray(last_logits)  # sync so the wall time is real
            now = time.time()
            self.stats.record_prefill_wall(now - ts)
            self.stats.prefill_dispatches += 1
            self.stats.prefill_tokens += plen - start
            if self.tracer is not None:
                c = self.prefill_chunk
                nb = sum(self._prefill_bytes([start + j * c], c)
                         for j in range(-(-(plen - start) // c)))
                self._note_dispatch("prefill", ts, now, nb,
                                    uid=req.uid, tokens=plen - start)
        else:
            toks = jnp.asarray(np.asarray(req.prompt)[None], jnp.int32)
            ts = time.time()
            last_logits, dense = self.api.prefill(
                self.params, {"tokens": toks}, self.schedule, capacity=plen,
                extra_groups=0)
            self.stats.prefill_tokens += plen
            n_groups = plen // self.group_size
            self.state = self._adopt(
                self.state, dense.caches, jnp.int32(slot),
                jnp.asarray(pages[:n_groups], jnp.int32), jnp.int32(plen))
            np.asarray(last_logits)  # sync so the wall time is real
            now = time.time()
            self.stats.record_prefill_wall(now - ts)
            self.stats.prefill_dispatches += 2  # dense prefill + adopt
            if self.tracer is not None:
                # dense prefill + adopt: the paged byte counters do not
                # model this path, so the span carries no bandwidth gauge
                self.tracer.engine_span("prefill_dispatch", ts, now,
                                        uid=req.uid, tokens=plen,
                                        dense=True)

        self._slots[slot] = req
        self._slot_pages[slot] = pages
        if self.guard_nan and not np.isfinite(np.asarray(last_logits)).all():
            # poisoned admission: quarantine BEFORE the prefix tree adopts
            # any of this prompt's blocks, so corruption never enters the
            # shared cache
            self._quarantine(slot, "non-finite prefill logits")
            return
        if self.prefill_paged and self.prefix is not None:
            # index the full-group chain (shared nodes just touch LRU)
            self.prefix.insert(req.prompt, pages)
        if replay:
            # recompute resume: the request already emitted tokens — rebuild
            # its decode-produced blocks/residual instead of sampling afresh
            self._replay(req, slot)
            req.status = RequestStatus.DECODING
            if self.tracer is not None:
                self.tracer.phase(req.uid, "decode")
            return
        self.stats.admitted += 1
        req.status = RequestStatus.DECODING
        if self.tracer is not None:
            self.tracer.phase(req.uid, "decode")

        tok = int(self._sample(last_logits)[0])
        self.stats.record_admit_latency(time.time() - t0)
        self._emit(slot, req, tok)

    def _admit_batch(self, batch: list) -> None:
        """Admit a burst of reserved requests with chunk-wave batched
        prefill: one page-table update for the whole burst, then each wave
        runs every member's next ``prefill_chunk``-token chunk in ONE
        device dispatch (``prefill_paged_wave`` — traced ragged lengths,
        dead lanes masked). Device round-trips scale with the longest
        suffix, not the burst size. ``batch`` holds ``(req, slot, pages,
        n_shared)`` tuples from :meth:`_try_admit`."""
        t0 = time.time()
        r = self.group_size
        c = self.prefill_chunk
        for req, slot, pages, _ in batch:
            req.status = RequestStatus.PREFILLING
            if self.tracer is not None:
                self.tracer.phase(req.uid, "prefill")
            self._pt[slot, :] = 0
            self._pt[slot, :len(pages)] = pages
        self.state = dataclasses.replace(
            self.state, page_table=self._to_dev(self._pt))

        suffixes = [np.asarray(req.prompt)[n_shared * r:]
                    for req, _, _, n_shared in batch]
        n_waves = max(-(-len(sfx) // c) for sfx in suffixes)
        last_logits: dict[int, np.ndarray] = {}
        for w in range(n_waves):
            tokens = np.zeros((self.max_batch, c), np.int32)
            ctx = np.zeros(self.max_batch, np.int32)
            clen = np.zeros(self.max_batch, np.int32)
            for (req, slot, _, n_shared), sfx in zip(batch, suffixes):
                off = w * c
                if off >= len(sfx):
                    continue  # out of chunks: dead lane this wave
                ln = min(c, len(sfx) - off)
                tokens[slot, :ln] = sfx[off:off + ln]
                ctx[slot] = n_shared * r + off
                clen[slot] = ln
            ts = time.time()
            logits, self.state = self._wave(
                self.params, self.state, jnp.asarray(tokens),
                jnp.asarray(ctx), jnp.asarray(clen))
            logits = np.asarray(logits)  # host sync: wall time is real
            now = time.time()
            self.stats.record_prefill_wall(now - ts)
            self.stats.prefill_dispatches += 1
            if self.tracer is not None:
                self._note_dispatch(
                    "prefill", ts, now, self._prefill_bytes(ctx[clen > 0], c),
                    span="prefill_wave", wave=w,
                    lanes=int((clen > 0).sum()))
            for (req, slot, _, _), sfx in zip(batch, suffixes):
                if w == (len(sfx) - 1) // c:  # this member's final wave
                    last_logits[slot] = logits[slot]

        for (req, slot, pages, n_shared), sfx in zip(batch, suffixes):
            self.stats.prefill_tokens += len(sfx)
            self._slot_pages[slot] = pages
            self._reserved.discard(slot)
            if self.guard_nan and \
                    not np.isfinite(last_logits[slot]).all():
                # quarantine before the prefix tree adopts this prompt's
                # blocks; burst mates are untouched (per-slot page tables)
                self._quarantine(slot, "non-finite prefill logits")
                continue
            if self.prefix is not None:
                self.prefix.insert(req.prompt, pages)
            self.stats.admitted += 1
            req.status = RequestStatus.DECODING
            if self.tracer is not None:
                self.tracer.phase(req.uid, "decode")
            # sample in admission order so the non-greedy rng stream matches
            # the serial path's draw order
            tok = int(self._sample(jnp.asarray(last_logits[slot][None]))[0])
            self.stats.record_admit_latency(time.time() - t0)
            self._emit(slot, req, tok)

    def _emit(self, slot: int, req: Request, tok: int) -> None:
        """Record one generated token; finish + free the slot on EOS/limit."""
        req.output.append(tok)
        self.stats.generated_tokens += 1
        if (req.eos_id is not None and tok == req.eos_id) or \
                len(req.output) >= req.max_new_tokens:
            self._release_slot(slot)
            self._finish(req, RequestStatus.DONE)
        else:
            self._current[slot] = tok

    # ------------------------------------------------------------ serving
    def run(self) -> list[Request]:
        """Serve until no admissible work remains. Returns every request
        that reached a terminal status since the engine was built — DONE
        and failure endings alike (check ``req.status``); under faults,
        survivors' greedy outputs are token-identical to a fault-free run."""
        t0 = time.time()
        while True:
            # lifecycle sweep first: fault-injector actions fire, expired
            # deadlines cancel, so this tick's admissions see the truth
            self._lifecycle_tick()
            if self.probe is not None:
                self.probe.on_sync(self)
            # deliver simulated arrivals, then admit into free slots
            arrived = [r for r in self._pending
                       if r.arrival_step <= self._step_count]
            if arrived:
                self._pending = [r for r in self._pending if r not in arrived]
                self._ready.extend(sorted(arrived, key=lambda r: r.uid))
            self._try_admit()
            # bound the waiting queue AFTER admission: only requests that
            # actually failed to get a slot this tick count against it
            self._shed_overflow()
            if self.audit_enabled:
                self.audit()

            live = [i for i, s in enumerate(self._slots) if s is not None]
            if not live:
                if not self._pending and not self._ready:
                    break
                if self._ready:
                    # swap-parked requests pin their shared blocks and host
                    # handles; with no live slots that is the only thing
                    # that can still block the queue head — demote one to
                    # recompute and retry. Fault-free, nothing-to-demote
                    # cannot happen (every slot and post-eviction block is
                    # free, submit() rejects pool-oversized requests); with
                    # an injected allocator fault it can, so instead of
                    # crashing the engine, tick time forward (deadlines and
                    # fault windows keep moving) and, if the stall outlives
                    # ``stall_ticks``, fail the queue head and move on.
                    if self._demote_parked_lru():
                        continue
                    self._stall += 1
                    self._step_count += 1
                    if self._stall >= self.stall_ticks:
                        head = min(self._ready, key=lambda r:
                                   self.sched.admission_key(r, self))
                        self.cancel(head.uid, RequestStatus.FAILED,
                                    f"admission stalled for {self._stall} "
                                    "ticks with no live slots")
                        self._stall = 0
                    continue
                # nothing decodable yet: fast-forward straight to the next
                # simulated arrival instead of ticking one step at a time
                self._step_count = max(
                    self._step_count,
                    min(r.arrival_step for r in self._pending))
                continue
            self._stall = 0

            tokens = np.zeros(self.max_batch, np.int32)
            alive = np.zeros(self.max_batch, bool)
            for i in live:
                tokens[i] = self._current[i]
                alive[i] = True
            if self.speculate_k:
                self._run_spec(live, tokens, alive)
            elif self.decode_horizon == 1:
                lens = self._ctx_lens() if self.tracer is not None else None
                ts = time.time()
                logits, self.state = self._step(
                    self.params, self.state, jnp.asarray(tokens[:, None]),
                    jnp.asarray(alive))
                if self.guard_nan:
                    self._step_guarded(live, logits, ts, lens)
                else:
                    nxt = np.asarray(self._sample(logits))
                    now = time.time()
                    self.stats.record_step_wall(now - ts)
                    if self.tracer is not None:
                        self._note_dispatch("decode", ts, now,
                                            self._decode_bytes(lens),
                                            steps=1, slots=len(live))
                    self._step_count += 1
                    self.stats.decode_steps += 1
                    self.stats.decode_tokens += len(live)
                    for i in live:
                        self._emit(i, self._slots[i], int(nxt[i]))
            else:
                self._run_horizon(live, tokens, alive)
        if self.audit_enabled:
            self.audit()
        self.stats.wall_s += time.time() - t0
        return self._done

    def _step_guarded(self, live, logits, ts: float, lens=None) -> None:
        """Host-side finish of one H=1 decode dispatch under ``guard_nan``:
        apply any pending logit-poison injections, quarantine slots whose
        logits went non-finite (corrupted block, poisoned activation), and
        emit for the finite survivors. Greedy host ``np.argmax`` picks the
        same token as the device ``jnp.argmax`` (first max wins in both),
        so guarded and unguarded runs are token-identical."""
        lg = np.array(logits)   # writable copy: poison injection edits rows
        for i in live:
            if self._slots[i].uid in self._poison_uids:
                lg[i] = np.nan      # injected fault: poison this slot only
        now = time.time()
        self.stats.record_step_wall(now - ts)
        if self.tracer is not None:
            self._note_dispatch("decode", ts, now, self._decode_bytes(lens),
                                steps=1, slots=len(live))
        self._step_count += 1
        self.stats.decode_steps += 1
        nxt = np.argmax(np.nan_to_num(lg, nan=0.0, posinf=0.0, neginf=0.0),
                        axis=-1).astype(np.int32)
        for i in live:
            if not np.isfinite(lg[i]).all():
                self._quarantine(i, "non-finite decode logits")
            else:
                self.stats.decode_tokens += 1
                self._emit(i, self._slots[i], int(nxt[i]))

    def _run_horizon(self, live, tokens, alive) -> None:
        """One device dispatch of ``decode_horizon`` steps; the host then
        replays the emitted-token log (finishing slots exactly where the
        device's liveness mask stopped them)."""
        h = self.decode_horizon
        remaining = np.zeros(self.max_batch, np.int32)
        eos = np.full(self.max_batch, -1, np.int32)
        for i in live:
            req = self._slots[i]
            remaining[i] = req.max_new_tokens - len(req.output)
            if req.eos_id is not None:
                eos[i] = req.eos_id
        lens = self._ctx_lens() if self.tracer is not None else None
        ts = time.time()
        self.state, toks, emitted, self.rng = self._loop(
            self.params, self.state, jnp.asarray(tokens), jnp.asarray(alive),
            jnp.asarray(remaining), jnp.asarray(eos), self.rng)
        toks = np.asarray(toks)          # [H, max_batch]
        emitted = np.asarray(emitted)
        now = time.time()
        self.stats.record_step_wall(now - ts, h)
        if self.tracer is not None:
            self._note_dispatch("decode", ts, now,
                                self._decode_bytes(lens, steps=h),
                                steps=h, slots=len(live))
        self._step_count += h
        self.stats.decode_steps += h
        self.stats.decode_tokens += int(emitted.sum())
        for t in range(h):
            for i in live:
                if emitted[t, i]:
                    self._emit(i, self._slots[i], int(toks[t, i]))

    def _run_spec(self, live, tokens, alive) -> None:
        """Up to ``decode_horizon`` speculative dispatches: draft k
        candidates per live slot on host, verify all k+1 positions in one
        fused pass, then commit + emit each slot's accepted
        greedy-consistent prefix (1..k+1 tokens per dispatch). The host
        must sync every dispatch anyway — accepted tokens feed the next
        round of drafting — so the horizon composes as H sequential
        dispatches between admission checks, not one fused device loop."""
        k = self.speculate_k
        for _ in range(self.decode_horizon):
            drafts = np.zeros((self.max_batch, k), np.int32)
            n_draft = np.zeros(self.max_batch, np.int32)
            remaining = np.zeros(self.max_batch, np.int32)
            eos = np.full(self.max_batch, -1, np.int32)
            for i in live:
                req = self._slots[i]
                remaining[i] = req.max_new_tokens - len(req.output)
                if req.eos_id is not None:
                    eos[i] = req.eos_id
                hist = np.concatenate(
                    [np.asarray(req.prompt, np.int32),
                     np.asarray(req.output, np.int32)])
                d = np.asarray(self.drafter.draft(hist, k),
                               np.int32).ravel()[:k]
                drafts[i, :len(d)] = d
                n_draft[i] = len(d)
            lens = self._ctx_lens() if self.tracer is not None else None
            ts = time.time()
            self.state, toks, emitted = self._spec(
                self.params, self.state, jnp.asarray(tokens),
                jnp.asarray(drafts), jnp.asarray(n_draft),
                jnp.asarray(alive), jnp.asarray(remaining), jnp.asarray(eos))
            toks = np.asarray(toks)          # [max_batch, k+1]
            emitted = np.asarray(emitted)    # [max_batch, k+1] bool
            now = time.time()
            self.stats.record_step_wall(now - ts)
            counts = emitted.sum(axis=1)
            if self.tracer is not None:
                self._note_dispatch("spec", ts, now,
                                    self._verify_bytes(lens, k + 1),
                                    span="spec_dispatch", slots=len(live))
            self._step_count += 1
            self.stats.decode_steps += 1
            self.stats.spec_steps += 1
            self.stats.decode_tokens += int(counts.sum())
            for i in live:
                self.stats.drafted_tokens += int(n_draft[i])
                self.stats.accepted_tokens += int(counts[i]) - 1
                self.stats.accepted_lengths.append(int(counts[i]))
                if self.tracer is not None:
                    self.tracer.event(self._slots[i].uid, "spec_commit",
                                      drafted=int(n_draft[i]),
                                      accepted=int(counts[i]) - 1)
                for t in range(int(counts[i])):
                    self._emit(i, self._slots[i], int(toks[i, t]))
                    if self._slots[i] is None:
                        break       # EOS/limit is always the last accepted
            live = [i for i in live if self._slots[i] is not None]
            if not live:
                return
            tokens = np.zeros(self.max_batch, np.int32)
            alive = np.zeros(self.max_batch, bool)
            for i in live:
                tokens[i] = self._current[i]
                alive[i] = True

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.rng, sub = jax.random.split(self.rng)
        return jax.random.categorical(sub, logits).astype(jnp.int32)


# ================================================================ frontends
def generate(api, params, schedule, prompts: np.ndarray, max_new_tokens: int,
             eos_id: int | None = None, **kw) -> tuple[np.ndarray, EngineStats]:
    """Convenience batched generation via the wave engine:
    prompts [B, S] → outputs [B, T]."""
    eng = ServeEngine(api, params, schedule, max_batch=prompts.shape[0], **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=np.asarray(p), eos_id=eos_id,
                           max_new_tokens=max_new_tokens))
    done = sorted(eng.run(), key=lambda r: r.uid)
    return _pack_outputs(done), eng.stats


def generate_continuous(api, params, schedule, prompts, max_new_tokens: int,
                        eos_id: int | None = None, max_batch: int = 4,
                        **kw) -> tuple[np.ndarray, EngineStats]:
    """Batched generation via the continuous engine. ``prompts`` may be a
    ragged list of 1-D arrays (mixed lengths are the point)."""
    plens = [len(p) for p in prompts]
    eng = ContinuousEngine(api, params, schedule, max_batch=max_batch,
                           max_seq=max(plens) + max_new_tokens, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=np.asarray(p), eos_id=eos_id,
                           max_new_tokens=max_new_tokens))
    done = sorted(eng.run(), key=lambda r: r.uid)
    return _pack_outputs(done), eng.stats


def _pack_outputs(done: list[Request]) -> np.ndarray:
    width = max(len(r.output) for r in done)
    out = np.zeros((len(done), width), np.int32)
    for i, r in enumerate(done):
        out[i, :len(r.output)] = r.output
    return out
