"""Serving engines with KVTuner mixed-precision KV cache.

Two schedulers over the same model API:

* ``ContinuousEngine`` (primary) — slot-based **continuous batching** over the
  shared paged KV pool (``repro.cache.paged``). A fixed ``max_batch`` of slots
  decodes in lock-step through ONE jitted step; a request that finishes frees
  its blocks and its slot admits the next queued request mid-decode. No
  (batch, capacity)-shaped recompiles: the decode step compiles once for the
  whole run regardless of the request mix. Optional **prefix caching**
  (``prefix_cache=True``) shares quantized prompt blocks between requests
  through a radix tree (``repro.cache.prefix``) and prefills only the
  non-cached suffix, chunked straight into pool blocks.

* ``ServeEngine`` (wave baseline) — buckets requests by exact prompt length
  into lock-step waves; each (batch, capacity) pair jits its own decode step
  and short requests hold their slot until the wave drains. Kept as the
  reference/baseline the benchmark compares against.

Both preserve the KVTuner property: the schedule is loaded once and every
layer's cache ops lower with **static** per-layer precision ("no online
decision overhead", paper §5). Throughput accounting mirrors the paper's
Table 8 definition: generated tokens per second end-to-end, including
quantization/dequantization work.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import KVTunerSchedule


@dataclasses.dataclass(eq=False)  # identity semantics: prompts are ndarrays
class Request:
    uid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    arrival_step: int = 0        # decode-step index when the request arrives
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    generated_tokens: int = 0
    decode_tokens: int = 0       # subset emitted by decode steps (the first
    prefill_tokens: int = 0      # token per request samples prefill logits)
    wall_s: float = 0.0
    waves: int = 0
    decode_steps: int = 0
    admitted: int = 0
    # prefix-cache accounting (continuous engine with prefix_cache=True)
    prefix_hits: int = 0
    prefix_misses: int = 0
    prefix_hit_tokens: int = 0      # prompt tokens served from cached blocks
    prefix_evicted_blocks: int = 0
    # device round-trips spent admitting requests: dense prefill + adopt
    # count one each; serial paged prefill one per request; batched
    # admission one per chunk wave (the number the batched path shrinks)
    prefill_dispatches: int = 0
    # per-decode-step wall clock (seconds); multi-step horizons contribute
    # their per-step average so percentiles stay per-token-step
    step_wall_times: list = dataclasses.field(default_factory=list,
                                              repr=False)
    # per-prefill-dispatch wall clock and per-request admission-start →
    # first-token latency (seconds)
    prefill_wall_times: list = dataclasses.field(default_factory=list,
                                                 repr=False)
    admit_latency_times: list = dataclasses.field(default_factory=list,
                                                  repr=False)

    @property
    def throughput(self) -> float:
        return self.generated_tokens / max(self.wall_s, 1e-9)

    def record_step_wall(self, seconds: float, steps: int = 1) -> None:
        self.step_wall_times.extend([seconds / steps] * steps)

    def record_prefill_wall(self, seconds: float) -> None:
        self.prefill_wall_times.append(seconds)

    def record_admit_latency(self, seconds: float) -> None:
        self.admit_latency_times.append(seconds)

    @staticmethod
    def _percentile_ms(values: list, q: float) -> float:
        if not values:
            return 0.0
        return float(np.percentile(np.asarray(values), q) * 1e3)

    @property
    def decode_p50_ms(self) -> float:
        return self._percentile_ms(self.step_wall_times, 50)

    @property
    def decode_p95_ms(self) -> float:
        return self._percentile_ms(self.step_wall_times, 95)

    @property
    def prefill_p50_ms(self) -> float:
        """Median wall time of one prefill device dispatch (a full request
        on the serial paths; one chunk wave under batched admission)."""
        return self._percentile_ms(self.prefill_wall_times, 50)

    @property
    def prefill_p95_ms(self) -> float:
        return self._percentile_ms(self.prefill_wall_times, 95)

    @property
    def admit_p50_ms(self) -> float:
        """Median admission-start → first-sampled-token latency per
        request (page-table update + prefill + first sample)."""
        return self._percentile_ms(self.admit_latency_times, 50)

    @property
    def admit_p95_ms(self) -> float:
        return self._percentile_ms(self.admit_latency_times, 95)

    @property
    def decode_tokens_per_s(self) -> float:
        """Aggregate decode-emitted tokens/s over decode-step wall time only
        (prefill-sampled admission tokens and host scheduling excluded — the
        kernel-facing throughput number)."""
        if not self.step_wall_times:
            return 0.0
        return self.decode_tokens / max(sum(self.step_wall_times), 1e-9)


# ==================================================================== wave
class ServeEngine:
    """Wave-based batching baseline (see module docstring)."""

    def __init__(self, api, params, schedule: KVTunerSchedule | None,
                 max_batch: int = 8, extra_groups: int = 8,
                 greedy: bool = True, use_pallas: bool = False, seed: int = 0):
        self.api = api
        self.params = params
        self.schedule = schedule
        self.max_batch = max_batch
        self.extra_groups = extra_groups
        self.greedy = greedy
        self.use_pallas = use_pallas
        self.rng = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.stats = EngineStats()
        self._decode_jit = {}

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------ serving
    def _decode_fn(self, key):
        if key not in self._decode_jit:
            self._decode_jit[key] = jax.jit(
                partial(self.api.decode_step, use_pallas=self.use_pallas))
        return self._decode_jit[key]

    @property
    def decode_compilations(self) -> int:
        """Distinct decode-step compilations so far: one per (batch,
        capacity) bucket — the cost the continuous engine eliminates."""
        return len(self._decode_jit)

    def run(self) -> list[Request]:
        """Drain the queue; returns completed requests."""
        done: list[Request] = []
        buckets: dict[int, list[Request]] = defaultdict(list)
        for r in self.queue:
            buckets[len(r.prompt)].append(r)
        self.queue.clear()
        for plen, reqs in sorted(buckets.items()):
            for i in range(0, len(reqs), self.max_batch):
                wave = reqs[i:i + self.max_batch]
                self._run_wave(wave, plen)
                done.extend(wave)
        return done

    def _run_wave(self, wave: list[Request], plen: int) -> None:
        t0 = time.time()
        b = len(wave)
        toks = jnp.asarray(np.stack([r.prompt for r in wave]), jnp.int32)
        max_new = max(r.max_new_tokens for r in wave)
        capacity = plen + max_new

        last_logits, state = self.api.prefill(
            self.params, {"tokens": toks}, self.schedule, capacity=capacity,
            extra_groups=self.extra_groups)
        self.stats.prefill_tokens += b * plen

        current = self._sample(last_logits)
        np.asarray(current)  # sync so prefill/admission wall times are real
        self.stats.record_prefill_wall(time.time() - t0)
        self.stats.prefill_dispatches += 1
        for _ in wave:
            self.stats.record_admit_latency(time.time() - t0)
        alive = np.ones(b, bool)
        decode = self._decode_fn((b, capacity))
        for step in range(max_new):
            for bi, r in enumerate(wave):
                if alive[bi]:
                    tok = int(current[bi])
                    r.output.append(tok)
                    self.stats.generated_tokens += 1
                    if step:  # the step-0 token sampled prefill logits
                        self.stats.decode_tokens += 1
                    if (r.eos_id is not None and tok == r.eos_id) or \
                            len(r.output) >= r.max_new_tokens:
                        alive[bi] = False
            if not alive.any() or step == max_new - 1:
                break
            ts = time.time()
            logits, state = decode(self.params, state, current[:, None])
            current = self._sample(logits)
            np.asarray(current)  # sync so the step wall time is real
            self.stats.record_step_wall(time.time() - ts)
            self.stats.decode_steps += 1
        for r in wave:
            r.done = True
        self.stats.waves += 1
        self.stats.wall_s += time.time() - t0

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.rng, sub = jax.random.split(self.rng)
        return jax.random.categorical(sub, logits).astype(jnp.int32)


WaveEngine = ServeEngine


# ============================================================== continuous
class ContinuousEngine:
    """Slot-based continuous batching over the shared paged KV pool.

    * ``max_batch`` serving slots decode together through a single jitted
      step of fixed shape; padded/dead slots are masked via ``alive``.
    * Each request's blocks (one block = one quant group of R tokens) are
      allocated from the global pool at admission — enough for
      ``prompt + max_new_tokens`` — and recycled the moment it finishes, so
      the next queued request is admitted mid-decode into the freed slot.
    * ``arrival_step`` on a request simulates an online arrival process
      deterministically: the request only becomes visible once that many
      decode steps have executed (benchmarks drive this with Poisson draws).
    * ``prefill_paged`` switches admission from dense prefill + block
      adoption to **chunked in-pool prefill**: the prompt runs through the
      model in ``prefill_chunk``-token chunks that attend to already-written
      (quantized) pool blocks and write their own quantized groups straight
      into allocated blocks — no transient full-precision cache.
      ``prefill_chunk`` trades admission compile cost against sharing
      granularity: each trace unrolls ``suffix/chunk`` chunk passes, while
      prefixes are shared only in chunk multiples. The default (one quant
      group, R tokens) maximizes sharing; raise it (any multiple of R) for
      long-prompt workloads where prefill trace time dominates.
    * ``prefix_cache`` (implies ``prefill_paged``) additionally indexes every
      prefilled prompt's block chain in a radix tree (``repro.cache.prefix``)
      and admits later requests by pinning the longest cached prefix and
      prefilling only the suffix. Cached blocks are shared copy-on-write
      (read-only; refcounted) and evicted LRU under pool pressure. Greedy
      outputs are token-identical with the cache on or off.
    * ``batched_admission`` (implies ``prefill_paged``) prefills every
      request admissible at a tick **together**, as lock-step chunk waves
      through one retrace-free jitted dispatch (``prefill_paged_wave`` with
      traced per-slot context/chunk lengths; the fused ``qprefill_paged``
      kernel keeps the work per lane proportional to its live context): a
      burst of arrivals costs one device round-trip per chunk wave instead
      of one per request. Greedy outputs are token-identical batched or
      serial, kernel on or off.

    Restrictions (v1): attention-only stacks with global (non-windowed)
    attention; see ``repro.cache.paged``.
    """

    def __init__(self, api, params, schedule: KVTunerSchedule | None,
                 max_batch: int = 4, max_seq: int = 512,
                 num_blocks: int | None = None, greedy: bool = True,
                 use_pallas: bool = False, seed: int = 0,
                 prefill_paged: bool = False, prefix_cache: bool = False,
                 prefill_chunk: int | None = None, decode_horizon: int = 1,
                 batched_admission: bool = False):
        cfg = api.cfg
        self.api = api
        self.params = params
        self.schedule = schedule
        self.max_batch = max_batch
        self.group_size = cfg.kv_group_size
        # +1: a request needs (prompt+max_new)//R + 1 blocks in the worst case
        self.max_pages = max_seq // self.group_size + 1
        self.num_blocks = num_blocks if num_blocks is not None \
            else 1 + max_batch * self.max_pages
        self.greedy = greedy
        self.use_pallas = use_pallas
        self.rng = jax.random.PRNGKey(seed)
        self.stats = EngineStats()
        # batched admission prefills a burst of arrivals as lock-step chunk
        # waves straight into pool blocks — it implies the in-pool path
        self.batched_admission = batched_admission
        self.prefill_paged = prefill_paged or prefix_cache or batched_admission
        # default chunk = one quant group: finest sharing granularity (any
        # cached prefix of >= R tokens is usable), more chunks per prefill
        self.prefill_chunk = prefill_chunk if prefill_chunk is not None \
            else self.group_size
        if self.prefill_chunk <= 0 or self.prefill_chunk % self.group_size:
            raise ValueError(
                f"prefill_chunk ({self.prefill_chunk}) must be a positive "
                f"multiple of the quant group size ({self.group_size})")

        from repro.cache.paged import BlockAllocator
        from repro.cache.prefix import PrefixCache

        self.state = api.init_paged_state(
            schedule, max_batch, self.num_blocks, self.max_pages)
        self.alloc = BlockAllocator(self.num_blocks)
        self.prefix = PrefixCache(self.alloc, self.group_size) \
            if prefix_cache else None
        self._pt = np.zeros((max_batch, self.max_pages), np.int32)
        self._slots: list[Request | None] = [None] * max_batch
        self._slot_pages: list[list[int]] = [[] for _ in range(max_batch)]
        self._current = np.zeros(max_batch, np.int32)
        self._pending: list[Request] = []   # submitted, not yet arrived
        self._ready: list[Request] = []     # arrived, waiting for slot/blocks
        self._step_count = 0
        # decode horizon: H decode steps per device dispatch (lax.scan with
        # in-device sampling + EOS/budget masking); the host syncs for
        # admissions/finishes only every H steps. H=1 keeps the classic
        # step-sync loop. Greedy outputs are identical for any H; sampled
        # decoding uses a device-side rng stream, so only H=1 reproduces the
        # host sampler's draws.
        if decode_horizon < 1:
            raise ValueError(f"decode_horizon ({decode_horizon}) must be >= 1")
        self.decode_horizon = decode_horizon
        # donate the state: the pool is sized to fill HBM, so the step must
        # update it in place rather than hold old+new copies (no-op on CPU)
        self._step = jax.jit(
            partial(api.paged_decode_step, use_pallas=use_pallas),
            donate_argnums=(1,))
        self._loop = jax.jit(
            partial(api.paged_decode_loop, horizon=decode_horizon,
                    use_pallas=use_pallas, greedy=greedy),
            donate_argnums=(1,))
        # NOTE: adoption (like any prefill) traces per distinct prompt-group
        # count — that is admission cost, paid once per request; the decode
        # step above stays single-compile for the whole run.
        self._adopt = jax.jit(api.paged_adopt, donate_argnums=(0,))
        # chunked in-pool prefill: retraces once per distinct
        # (suffix length, shared-prefix length) pair — `start` is static so
        # each chunk attends only the live context blocks, not max_pages
        self._prefill = jax.jit(
            partial(api.prefill_paged, chunk=self.prefill_chunk,
                    use_pallas=use_pallas),
            static_argnums=(4,), donate_argnums=(1,))
        # batched admission wave: per-slot context/chunk lengths are traced
        # (the fused prefill kernel is length-aware), so this compiles ONCE
        # and serves every burst composition — one device round-trip per
        # chunk wave instead of per request
        self._wave = jax.jit(
            partial(api.prefill_paged_wave, use_pallas=use_pallas),
            donate_argnums=(1,))

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        need = self._pages_needed(req)
        if need > self.max_pages:
            raise ValueError(
                f"request {req.uid}: prompt+max_new "
                f"({len(req.prompt)}+{req.max_new_tokens}) exceeds engine "
                f"max_seq (needs {need} pages, table holds {self.max_pages})")
        if need > self.num_blocks - 1:
            raise ValueError(
                f"request {req.uid}: needs {need} blocks, pool has "
                f"{self.num_blocks - 1}")
        self._pending.append(req)

    def _pages_needed(self, req: Request) -> int:
        return (len(req.prompt) + req.max_new_tokens) // self.group_size + 1

    @property
    def decode_compilations(self) -> int:
        """Distinct decode-step compilations (the acceptance metric): stays
        at 1 for any mix of prompt lengths and admission points."""
        try:
            return int(self._step._cache_size())
        except AttributeError:  # older jax: one fixed-shape step → 1 compile
            return 1 if self.stats.decode_steps else 0

    # ---------------------------------------------------------- admission
    def _free_slot(self) -> int | None:
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def _try_admit(self) -> None:
        """FIFO admission: fill free slots while the pool has blocks. With
        the prefix cache on, each admission first pins the longest cached
        prefix so only the suffix needs fresh blocks (and prefill). With
        ``batched_admission``, every request admissible this tick is
        reserved first and then prefilled together as lock-step chunk
        waves (:meth:`_admit_batch`) — one device dispatch per wave for
        the whole burst instead of one (or more) per request. A burst
        member that finishes instantly frees its slot; the outer loop
        re-collects so waiting requests can take it (as the serial path's
        rolling while-loop does)."""
        while True:
            batch: list = []
            while self._ready:
                slot = self._free_slot()
                if slot is None:
                    break
                req = self._ready[0]
                shared = self._match_prefix(req) if self.prefix is not None \
                    else []
                if shared:
                    self.alloc.ref(shared)  # pin before eviction reaps them
                pages = self._alloc_with_eviction(
                    self._pages_needed(req) - len(shared))
                if pages is None:
                    if shared:
                        self.alloc.release(shared)  # unpin; retry next tick
                    break  # head-of-line waits for blocks to free up
                if self.prefix is not None:
                    if shared:
                        self.stats.prefix_hits += 1
                        self.stats.prefix_hit_tokens += \
                            len(shared) * self.group_size
                    else:
                        self.stats.prefix_misses += 1
                self._ready.pop(0)
                if self.batched_admission:
                    self._slots[slot] = req  # reserve the slot for the burst
                    batch.append((req, slot, shared + pages, len(shared)))
                else:
                    self._admit(req, slot, shared + pages,
                                n_shared=len(shared))
            if not batch:
                return
            self._admit_batch(batch)
            if not self._ready:
                return

    def _match_prefix(self, req: Request) -> list[int]:
        """Longest usable cached prefix of this prompt, as block ids.

        The match is capped below the full prompt (at least one suffix token
        must run so admission has logits to sample from) and truncated to a
        multiple of the prefill chunk: chunk boundaries are quantization
        context boundaries, so only chunk-aligned sharing reproduces the
        cache-off computation bit-for-bit.
        """
        blocks = self.prefix.match(req.prompt)
        r = self.group_size
        per_chunk = self.prefill_chunk // r
        n = min(len(blocks), (len(req.prompt) - 1) // r)
        return blocks[:n // per_chunk * per_chunk]

    def _alloc_with_eviction(self, n: int) -> list[int] | None:
        """Allocate n blocks, evicting LRU cached prefixes under pressure.
        Eviction is one tree pass for exactly the deficit, and refuses when
        it cannot reach it — a doomed attempt leaves the cache intact."""
        pages = self.alloc.alloc(n)
        if pages is None and self.prefix is not None:
            freed = self.prefix.evict(n - self.alloc.free_blocks)
            if freed:
                self.stats.prefix_evicted_blocks += freed
                pages = self.alloc.alloc(n)
        return pages

    def _admit(self, req: Request, slot: int, pages: list[int],
               n_shared: int = 0) -> None:
        t0 = time.time()
        plen = len(req.prompt)
        self._pt[slot, :] = 0
        self._pt[slot, :len(pages)] = pages
        self.state = dataclasses.replace(
            self.state, page_table=jnp.asarray(self._pt))

        if self.prefill_paged:
            # chunked in-pool prefill of the non-cached suffix only
            start = n_shared * self.group_size
            toks = jnp.asarray(np.asarray(req.prompt)[None, start:],
                               jnp.int32)
            ts = time.time()
            last_logits, self.state = self._prefill(
                self.params, self.state, toks, jnp.int32(slot), start)
            np.asarray(last_logits)  # sync so the wall time is real
            self.stats.record_prefill_wall(time.time() - ts)
            self.stats.prefill_dispatches += 1
            self.stats.prefill_tokens += plen - start
            if self.prefix is not None:
                # index the full-group chain (shared nodes just touch LRU)
                self.prefix.insert(req.prompt, pages)
        else:
            toks = jnp.asarray(np.asarray(req.prompt)[None], jnp.int32)
            ts = time.time()
            last_logits, dense = self.api.prefill(
                self.params, {"tokens": toks}, self.schedule, capacity=plen,
                extra_groups=0)
            self.stats.prefill_tokens += plen
            n_groups = plen // self.group_size
            self.state = self._adopt(
                self.state, dense.caches, jnp.int32(slot),
                jnp.asarray(pages[:n_groups], jnp.int32), jnp.int32(plen))
            np.asarray(last_logits)  # sync so the wall time is real
            self.stats.record_prefill_wall(time.time() - ts)
            self.stats.prefill_dispatches += 2  # dense prefill + adopt

        self.stats.admitted += 1
        self._slots[slot] = req
        self._slot_pages[slot] = pages

        tok = int(self._sample(last_logits)[0])
        self.stats.record_admit_latency(time.time() - t0)
        self._emit(slot, req, tok)

    def _admit_batch(self, batch: list) -> None:
        """Admit a burst of reserved requests with chunk-wave batched
        prefill: one page-table update for the whole burst, then each wave
        runs every member's next ``prefill_chunk``-token chunk in ONE
        device dispatch (``prefill_paged_wave`` — traced ragged lengths,
        dead lanes masked). Device round-trips scale with the longest
        suffix, not the burst size. ``batch`` holds ``(req, slot, pages,
        n_shared)`` tuples from :meth:`_try_admit`."""
        t0 = time.time()
        r = self.group_size
        c = self.prefill_chunk
        for req, slot, pages, _ in batch:
            self._pt[slot, :] = 0
            self._pt[slot, :len(pages)] = pages
        self.state = dataclasses.replace(
            self.state, page_table=jnp.asarray(self._pt))

        suffixes = [np.asarray(req.prompt)[n_shared * r:]
                    for req, _, _, n_shared in batch]
        n_waves = max(-(-len(sfx) // c) for sfx in suffixes)
        last_logits: dict[int, np.ndarray] = {}
        for w in range(n_waves):
            tokens = np.zeros((self.max_batch, c), np.int32)
            ctx = np.zeros(self.max_batch, np.int32)
            clen = np.zeros(self.max_batch, np.int32)
            for (req, slot, _, n_shared), sfx in zip(batch, suffixes):
                off = w * c
                if off >= len(sfx):
                    continue  # out of chunks: dead lane this wave
                ln = min(c, len(sfx) - off)
                tokens[slot, :ln] = sfx[off:off + ln]
                ctx[slot] = n_shared * r + off
                clen[slot] = ln
            ts = time.time()
            logits, self.state = self._wave(
                self.params, self.state, jnp.asarray(tokens),
                jnp.asarray(ctx), jnp.asarray(clen))
            logits = np.asarray(logits)  # host sync: wall time is real
            self.stats.record_prefill_wall(time.time() - ts)
            self.stats.prefill_dispatches += 1
            for (req, slot, _, _), sfx in zip(batch, suffixes):
                if w == (len(sfx) - 1) // c:  # this member's final wave
                    last_logits[slot] = logits[slot]

        for (req, slot, pages, n_shared), sfx in zip(batch, suffixes):
            self.stats.prefill_tokens += len(sfx)
            if self.prefix is not None:
                self.prefix.insert(req.prompt, pages)
            self.stats.admitted += 1
            self._slot_pages[slot] = pages
            # sample in admission order so the non-greedy rng stream matches
            # the serial path's draw order
            tok = int(self._sample(jnp.asarray(last_logits[slot][None]))[0])
            self.stats.record_admit_latency(time.time() - t0)
            self._emit(slot, req, tok)

    def _emit(self, slot: int, req: Request, tok: int) -> None:
        """Record one generated token; finish + free the slot on EOS/limit."""
        req.output.append(tok)
        self.stats.generated_tokens += 1
        if (req.eos_id is not None and tok == req.eos_id) or \
                len(req.output) >= req.max_new_tokens:
            req.done = True
            self.alloc.release(self._slot_pages[slot])
            self._slot_pages[slot] = []
            self._slots[slot] = None
            self._done.append(req)
        else:
            self._current[slot] = tok

    # ------------------------------------------------------------ serving
    def run(self) -> list[Request]:
        """Drain pending+ready requests; returns completed requests."""
        t0 = time.time()
        self._done: list[Request] = []
        while True:
            # deliver simulated arrivals, then admit into free slots
            arrived = [r for r in self._pending
                       if r.arrival_step <= self._step_count]
            if arrived:
                self._pending = [r for r in self._pending if r not in arrived]
                self._ready.extend(sorted(arrived, key=lambda r: r.uid))
            self._try_admit()

            live = [i for i, s in enumerate(self._slots) if s is not None]
            if not live:
                if not self._pending and not self._ready:
                    break
                if self._ready:
                    # cannot happen: with no live slots every slot is free
                    # and (post-eviction) every pool block too, and submit()
                    # rejects requests larger than the pool
                    raise RuntimeError(
                        "admission stalled with no live slots")
                # nothing decodable yet: fast-forward straight to the next
                # simulated arrival instead of ticking one step at a time
                self._step_count = max(
                    self._step_count,
                    min(r.arrival_step for r in self._pending))
                continue

            tokens = np.zeros(self.max_batch, np.int32)
            alive = np.zeros(self.max_batch, bool)
            for i in live:
                tokens[i] = self._current[i]
                alive[i] = True
            if self.decode_horizon == 1:
                ts = time.time()
                logits, self.state = self._step(
                    self.params, self.state, jnp.asarray(tokens[:, None]),
                    jnp.asarray(alive))
                nxt = np.asarray(self._sample(logits))
                self.stats.record_step_wall(time.time() - ts)
                self._step_count += 1
                self.stats.decode_steps += 1
                self.stats.decode_tokens += len(live)
                for i in live:
                    self._emit(i, self._slots[i], int(nxt[i]))
            else:
                self._run_horizon(live, tokens, alive)
        self.stats.wall_s += time.time() - t0
        return self._done

    def _run_horizon(self, live, tokens, alive) -> None:
        """One device dispatch of ``decode_horizon`` steps; the host then
        replays the emitted-token log (finishing slots exactly where the
        device's liveness mask stopped them)."""
        h = self.decode_horizon
        remaining = np.zeros(self.max_batch, np.int32)
        eos = np.full(self.max_batch, -1, np.int32)
        for i in live:
            req = self._slots[i]
            remaining[i] = req.max_new_tokens - len(req.output)
            if req.eos_id is not None:
                eos[i] = req.eos_id
        ts = time.time()
        self.state, toks, emitted, self.rng = self._loop(
            self.params, self.state, jnp.asarray(tokens), jnp.asarray(alive),
            jnp.asarray(remaining), jnp.asarray(eos), self.rng)
        toks = np.asarray(toks)          # [H, max_batch]
        emitted = np.asarray(emitted)
        self.stats.record_step_wall(time.time() - ts, h)
        self._step_count += h
        self.stats.decode_steps += h
        self.stats.decode_tokens += int(emitted.sum())
        for t in range(h):
            for i in live:
                if emitted[t, i]:
                    self._emit(i, self._slots[i], int(toks[t, i]))

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.rng, sub = jax.random.split(self.rng)
        return jax.random.categorical(sub, logits).astype(jnp.int32)


# ================================================================ frontends
def generate(api, params, schedule, prompts: np.ndarray, max_new_tokens: int,
             eos_id: int | None = None, **kw) -> tuple[np.ndarray, EngineStats]:
    """Convenience batched generation via the wave engine:
    prompts [B, S] → outputs [B, T]."""
    eng = ServeEngine(api, params, schedule, max_batch=prompts.shape[0], **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=np.asarray(p), eos_id=eos_id,
                           max_new_tokens=max_new_tokens))
    done = sorted(eng.run(), key=lambda r: r.uid)
    return _pack_outputs(done), eng.stats


def generate_continuous(api, params, schedule, prompts, max_new_tokens: int,
                        eos_id: int | None = None, max_batch: int = 4,
                        **kw) -> tuple[np.ndarray, EngineStats]:
    """Batched generation via the continuous engine. ``prompts`` may be a
    ragged list of 1-D arrays (mixed lengths are the point)."""
    plens = [len(p) for p in prompts]
    eng = ContinuousEngine(api, params, schedule, max_batch=max_batch,
                           max_seq=max(plens) + max_new_tokens, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=np.asarray(p), eos_id=eos_id,
                           max_new_tokens=max_new_tokens))
    done = sorted(eng.run(), key=lambda r: r.uid)
    return _pack_outputs(done), eng.stats


def _pack_outputs(done: list[Request]) -> np.ndarray:
    width = max(len(r.output) for r in done)
    out = np.zeros((len(done), width), np.int32)
    for i, r in enumerate(done):
        out[i, :len(r.output)] = r.output
    return out
