"""Engine-wide invariant auditor: leak & aliasing detection across tiers.

``BlockAllocator.assert_consistent`` checks the allocator against itself
(free list vs refcounts). This module extends that to the whole engine:
it *reconstructs* the reference count every device block and host handle
ought to have from the structures that are supposed to hold references —

* live slots' page lists (own blocks + pinned prefix chains),
* parked (preempted) requests' swap entries (device pins + host handles),
* the prefix tree's device- and host-resident nodes,

and cross-checks them against what the allocator and host store actually
record, plus the host-side page-table mirror and the device cached-length
row of every live slot. Any divergence is a leak (references the engine
forgot to drop), an alias (two owners claiming the same exclusive
reference, or a handle pointing at freed bytes), or a stale mapping — the
failure classes that silently corrupt outputs long before they crash.

Run it directly (``engine.audit()``), or at every host sync with
``ContinuousEngine(audit=True)`` — cheap enough for tests: pure python over
host-side bookkeeping plus one ``device_get`` of the lengths vector.
"""
from __future__ import annotations

import numpy as np


class AuditError(AssertionError):
    """An engine invariant does not hold (leaked / aliased / stale state)."""


def _fail(msg: str) -> None:
    raise AuditError(msg)


def audit_engine(engine) -> dict:
    """Cross-check allocator refcounts vs page tables vs prefix chains vs
    host-store entries. Returns a summary dict on success; raises
    :class:`AuditError` naming the first violated invariant otherwise."""
    alloc = engine.alloc
    alloc.assert_consistent()

    # -------- reconstruct expected device-block / host-handle refcounts
    dev_expect = np.zeros(alloc.num_blocks, np.int64)
    host_expect: dict[int, int] = {}
    live_slots = 0
    for slot, req in enumerate(engine._slots):
        if req is None:
            continue
        live_slots += 1
        for b in engine._slot_pages[slot]:
            dev_expect[b] += 1
    n_parked = 0
    for uid, parked in engine._parked.items():
        if parked.entries is None:
            continue          # recompute-parked: holds no tier state
        n_parked += 1
        for kind, v in parked.entries:
            if kind == "dev":
                dev_expect[v] += 1
            else:
                host_expect[v] = host_expect.get(v, 0) + 1
    tree_dev = tree_host = 0
    if engine.prefix is not None:
        for node in engine.prefix.iter_nodes():
            if node.on_device:
                tree_dev += 1
                dev_expect[node.block] += 1
            else:
                tree_host += 1
                if node.host is None:
                    _fail(f"prefix node {node.key!r} is host-resident but "
                          "has no host handle")
                host_expect[node.host] = host_expect.get(node.host, 0) + 1

    # ------------------------------------------------- device-block check
    if dev_expect[0]:
        _fail(f"scratch block 0 is referenced {dev_expect[0]}x (slots / "
              "parked entries / prefix nodes must never hold it)")
    for b in range(1, alloc.num_blocks):
        actual = alloc.refcount(b)
        if actual != dev_expect[b]:
            kind = "leaked" if actual > dev_expect[b] else "aliased/dangling"
            _fail(f"device block {b} {kind}: allocator refcount {actual}, "
                  f"but slots+parked+prefix account for {dev_expect[b]} "
                  "references")

    # ------------------------------------------------- host-handle check
    if engine.host is not None:
        actual_refs = engine.host.handle_refcounts()
        for h, n in host_expect.items():
            if h not in actual_refs:
                _fail(f"host handle {h} dangling: referenced {n}x by "
                      "parked/prefix state but absent from the store")
            if actual_refs[h] != n:
                kind = "leaked" if actual_refs[h] > n else "aliased"
                _fail(f"host handle {h} {kind}: store refcount "
                      f"{actual_refs[h]}, engine accounts for {n}")
        for h, n in actual_refs.items():
            if h not in host_expect:
                _fail(f"host handle {h} leaked: store refcount {n}, but no "
                      "parked entry or prefix node references it")
    elif host_expect:
        _fail("engine has no host store, yet parked/prefix state holds "
              f"host handles {sorted(host_expect)}")

    # ------------------------------ page-table mirror + cached lengths
    lengths = np.asarray(engine.state.lengths)
    for slot, req in enumerate(engine._slots):
        if req is None or slot in engine._reserved:
            continue
        pages = engine._slot_pages[slot]
        row = engine._pt[slot, :len(pages)]
        if list(row) != list(pages):
            _fail(f"slot {slot} page-table row {list(row)} diverges from "
                  f"its page list {list(pages)}")
        if req.output:           # admitted and decoding: length invariant
            want = len(req.prompt) + len(req.output) - 1
            if int(lengths[slot]) != want:
                _fail(f"slot {slot} cached length {int(lengths[slot])} != "
                      f"prompt+output-1 ({want}) for request {req.uid}")
            if want // engine.group_size + 1 > len(pages):
                _fail(f"slot {slot} holds {len(pages)} blocks but needs "
                      f"{want // engine.group_size + 1} for its cached "
                      f"length {want}")

    return {
        "device_blocks_live": int(alloc.allocated_blocks),
        "host_handles_live": 0 if engine.host is None else len(engine.host),
        "live_slots": live_slots,
        "swap_parked": n_parked,
        "prefix_device_nodes": tree_dev,
        "prefix_host_nodes": tree_host,
    }
