"""Per-request span tracing for the serving engines + Perfetto export.

A :class:`Tracer` records, for every submitted request, a **root span**
(submit → terminal status) subdivided into a contiguous sequence of
**phase spans** — ``queued`` / ``prefill`` / ``decode`` — plus instant
**events** (``prefix_match``, ``preempt``, ``swap_in``,
``recompute_replay``, ``spec_commit``, ``quarantine``, ``fault.*``,
``audit_violation``). Phases are gap-free and properly nested *by
construction*: a phase transition closes the previous phase and opens the
next at the same timestamp, and :meth:`Tracer.finish` closes the last
phase at the root span's end. A second track carries **engine-level
dispatch spans** (``decode_dispatch`` / ``spec_dispatch`` /
``prefill_dispatch`` / ``prefill_wave``) timed around the host-synced
device dispatches the engine already measures, plus engine-scope instant
events — tracing adds **no** device syncs, so traced greedy outputs are
token-identical to untraced ones.

The engine holds ``tracer = None`` unless built with ``trace=True``; every
hook site is guarded by that None check, so a tracing-off run executes no
telemetry code at all.

Exporters / validators:

* :func:`to_perfetto` — Chrome trace-event JSON (``traceEvents`` with
  ``"X"`` complete spans, ``"i"`` instants, ``"M"`` thread-name metadata;
  microsecond timestamps relative to the trace epoch). Load in
  https://ui.perfetto.dev or ``chrome://tracing``.
* :func:`validate_trace` — structural gate over a live tracer: every
  terminal request has a closed, gap-free, taxonomy-conforming span tree
  with all events inside the root span. Raises :class:`TraceError`.
* :func:`validate_perfetto` — schema check over exported (or re-loaded)
  trace-event JSON. The module doubles as a CLI:
  ``python -m repro.serving.trace <trace.json>``.
"""
from __future__ import annotations

import dataclasses
import json
import time

PHASES = ("queued", "prefill", "decode")
EVENTS = ("prefix_match", "preempt", "swap_in", "recompute_replay",
          "spec_commit", "quarantine")
ENGINE_SPANS = ("decode_dispatch", "spec_dispatch", "prefill_dispatch",
                "prefill_wave")


class TraceError(AssertionError):
    """A trace or exported trace file violates the span invariants."""


@dataclasses.dataclass
class Span:
    name: str
    t0: float
    t1: float | None = None
    args: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class RequestTrace:
    uid: int
    t_begin: float
    t_end: float | None = None
    status: str | None = None            # terminal RequestStatus value
    error: str | None = None
    phases: list = dataclasses.field(default_factory=list)
    events: list = dataclasses.field(default_factory=list)  # (t, name, args)

    @property
    def terminal(self) -> bool:
        return self.status is not None


class Tracer:
    """Collects request traces + engine-track spans/events (see module
    docstring). All timestamps are host ``time.time()`` seconds — the same
    clock the engine's wall-time stats use."""

    def __init__(self):
        self.epoch = time.time()
        self.requests: dict[int, RequestTrace] = {}
        self.engine_spans: list[Span] = []
        self.engine_events: list = []    # (t, name, args)

    # ------------------------------------------------------ request track
    def begin(self, uid: int) -> None:
        """Open a request's root span at submit; the ``queued`` phase
        starts immediately."""
        now = time.time()
        rt = RequestTrace(uid=uid, t_begin=now)
        rt.phases.append(Span("queued", now))
        self.requests[uid] = rt

    def phase(self, uid: int, name: str) -> None:
        """Transition to phase ``name``: closes the current phase and opens
        the next at one shared timestamp (gap-free by construction).
        Re-entering the current phase is a no-op."""
        rt = self.requests[uid]
        cur = rt.phases[-1]
        if cur.name == name and cur.t1 is None:
            return
        now = time.time()
        cur.t1 = now
        rt.phases.append(Span(name, now))

    def event(self, uid: int, name: str, **args) -> None:
        self.requests[uid].events.append((time.time(), name, args))

    def finish(self, uid: int, status: str, error: str | None = None) -> None:
        """Close the request's open phase and root span at its terminal
        status (called from the engine's single ``_finish`` choke point)."""
        rt = self.requests[uid]
        now = time.time()
        rt.phases[-1].t1 = now
        rt.t_end = now
        rt.status = status
        rt.error = error

    # ------------------------------------------------------- engine track
    def engine_span(self, name: str, t0: float, t1: float, **args) -> None:
        self.engine_spans.append(Span(name, t0, t1, args))

    def engine_event(self, name: str, **args) -> None:
        self.engine_events.append((time.time(), name, args))

    # ---------------------------------------------------------- reporting
    def summary(self) -> dict:
        term = [r for r in self.requests.values() if r.terminal]
        return {
            "requests": len(self.requests),
            "terminal": len(term),
            "statuses": sorted({r.status for r in term}),
            "phase_spans": sum(len(r.phases) for r in self.requests.values()),
            "events": sum(len(r.events) for r in self.requests.values())
                      + len(self.engine_events),
            "engine_spans": len(self.engine_spans),
        }


# ================================================================ validation
def validate_trace(tracer: Tracer, require_terminal: bool = True) -> dict:
    """Gate the span invariants over a live tracer; returns
    :meth:`Tracer.summary` or raises :class:`TraceError` listing every
    violation. ``require_terminal`` additionally fails any request that
    never reached a terminal status (the completeness gate after a full
    ``run()``)."""
    issues: list[str] = []
    for uid, rt in sorted(tracer.requests.items()):
        tag = f"request {uid}"
        if not rt.terminal:
            if require_terminal:
                issues.append(f"{tag}: never reached a terminal status")
            continue
        if rt.t_end is None:
            issues.append(f"{tag}: terminal but root span never closed")
            continue
        if not rt.phases:
            issues.append(f"{tag}: no phase spans")
            continue
        for s in rt.phases:
            if s.name not in PHASES:
                issues.append(f"{tag}: unknown phase {s.name!r}")
            if s.t1 is None:
                issues.append(f"{tag}: phase {s.name!r} never closed")
            elif s.t1 < s.t0:
                issues.append(f"{tag}: phase {s.name!r} ends before start")
        if rt.phases[0].name != "queued":
            issues.append(f"{tag}: first phase is {rt.phases[0].name!r}, "
                          "not 'queued'")
        if rt.phases[0].t0 != rt.t_begin:
            issues.append(f"{tag}: first phase starts after submit (gap)")
        if rt.phases[-1].t1 is not None and rt.phases[-1].t1 != rt.t_end:
            issues.append(f"{tag}: last phase does not close the root span")
        for a, b in zip(rt.phases, rt.phases[1:]):
            if a.t1 is not None and a.t1 != b.t0:
                issues.append(f"{tag}: gap between phases "
                              f"{a.name!r} and {b.name!r}")
        for t, name, _ in rt.events:
            if not rt.t_begin <= t <= rt.t_end:
                issues.append(f"{tag}: event {name!r} outside root span")
    for s in tracer.engine_spans:
        if s.t1 is None or s.t1 < s.t0:
            issues.append(f"engine span {s.name!r}: bad interval")
    if issues:
        raise TraceError("trace invariants violated:\n  "
                         + "\n  ".join(issues))
    return tracer.summary()


# ==================================================================== export
def to_perfetto(tracer: Tracer) -> dict:
    """Chrome trace-event JSON: engine track on tid 0, one tid per request
    (root span + phases + instant events), µs timestamps relative to the
    trace epoch."""
    epoch = tracer.epoch
    ts = [r.t_begin for r in tracer.requests.values()]
    ts += [s.t0 for s in tracer.engine_spans]
    ts += [t for t, _, _ in tracer.engine_events]
    if ts:
        epoch = min(epoch, min(ts))

    def us(t: float) -> float:
        return (t - epoch) * 1e6

    ev: list[dict] = [
        {"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
         "args": {"name": "ContinuousEngine"}},
        {"ph": "M", "pid": 0, "tid": 0, "name": "thread_name",
         "args": {"name": "engine"}},
    ]
    for s in tracer.engine_spans:
        ev.append({"ph": "X", "pid": 0, "tid": 0, "name": s.name,
                   "ts": us(s.t0), "dur": max(s.t1 - s.t0, 0.0) * 1e6,
                   "args": s.args})
    for t, name, args in tracer.engine_events:
        ev.append({"ph": "i", "pid": 0, "tid": 0, "name": name,
                   "ts": us(t), "s": "t", "args": args})
    for uid, rt in sorted(tracer.requests.items()):
        tid = uid + 1
        ev.append({"ph": "M", "pid": 0, "tid": tid, "name": "thread_name",
                   "args": {"name": f"req {uid}"}})
        if rt.terminal and rt.t_end is not None:
            ev.append({"ph": "X", "pid": 0, "tid": tid,
                       "name": f"request:{rt.status}", "ts": us(rt.t_begin),
                       "dur": max(rt.t_end - rt.t_begin, 0.0) * 1e6,
                       "args": {"status": rt.status, "error": rt.error}})
        for s in rt.phases:
            if s.t1 is None:
                continue
            ev.append({"ph": "X", "pid": 0, "tid": tid, "name": s.name,
                       "ts": us(s.t0), "dur": max(s.t1 - s.t0, 0.0) * 1e6,
                       "args": s.args})
        for t, name, args in rt.events:
            ev.append({"ph": "i", "pid": 0, "tid": tid, "name": name,
                       "ts": us(t), "s": "t", "args": args})
    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def write_perfetto(tracer: Tracer, path: str) -> dict:
    doc = to_perfetto(tracer)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def validate_perfetto(doc: dict) -> dict:
    """Schema-check exported (or re-loaded) trace-event JSON; returns
    per-phase-type counts or raises :class:`TraceError`."""
    issues: list[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise TraceError("not a trace-event document "
                         "(missing 'traceEvents')")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise TraceError("'traceEvents' is not a list")
    counts = {"X": 0, "i": 0, "M": 0}
    for i, e in enumerate(events):
        tag = f"event {i}"
        if not isinstance(e, dict):
            issues.append(f"{tag}: not an object")
            continue
        ph = e.get("ph")
        if ph not in counts:
            issues.append(f"{tag}: unknown ph {ph!r}")
            continue
        counts[ph] += 1
        if not isinstance(e.get("name"), str):
            issues.append(f"{tag}: missing/non-string name")
        for key in ("pid", "tid"):
            if not isinstance(e.get(key), int):
                issues.append(f"{tag}: missing/non-int {key}")
        if ph in ("X", "i"):
            t = e.get("ts")
            if not isinstance(t, (int, float)) or t < 0:
                issues.append(f"{tag}: bad ts {t!r}")
        if ph == "X":
            d = e.get("dur")
            if not isinstance(d, (int, float)) or d < 0:
                issues.append(f"{tag}: bad dur {d!r}")
        if "args" in e and not isinstance(e["args"], dict):
            issues.append(f"{tag}: args is not an object")
    if issues:
        raise TraceError("perfetto schema violated:\n  "
                         + "\n  ".join(issues))
    return counts


def main(argv=None) -> None:
    """CLI schema validation: ``python -m repro.serving.trace <file.json>``
    exits nonzero (with the violation list) on a malformed trace."""
    import argparse

    ap = argparse.ArgumentParser(
        description="validate a Perfetto/chrome trace-event JSON file")
    ap.add_argument("path", help="trace file to validate")
    args = ap.parse_args(argv)
    with open(args.path) as f:
        doc = json.load(f)
    counts = validate_perfetto(doc)
    print(f"{args.path}: OK — {counts['X']} spans, {counts['i']} instants, "
          f"{counts['M']} metadata events")


if __name__ == "__main__":
    main()
