"""Deterministic fault injection for ``ContinuousEngine``.

Chaos testing for the serving stack: a :class:`FaultInjector` is handed to
the engine (``ContinuousEngine(faults=...)``) and wires itself into the
seams where real deployments fail —

* **allocator exhaustion** — ``BlockAllocator.fault_hook`` makes ``alloc``
  report a dry pool, exercising eviction / preemption / stall-shed paths;
* **host-tier failures** — ``HostBlockStore.fault_hook`` fails swap-outs
  (``put`` → the capacity-full ``None`` every caller already handles) and
  swap-ins (``get`` → :class:`~repro.cache.offload.HostStoreError`, which
  the engine converts to chain-drop or recompute fallbacks);
* **client churn** — scheduled ``cancel``/``drain`` calls at given engine
  steps, mid-prefill / mid-decode / mid-preemption;
* **data corruption** — NaN written into a live, exclusively-owned packed
  block's scales, or a slot's logits poisoned directly; with
  ``guard_nan=True`` the engine quarantines exactly the poisoned slot.

Everything is seeded and replayable: the same injector config against the
same workload fires the same faults at the same points. Probabilistic hooks
draw from one ``numpy`` generator in engine-call order (which is itself
deterministic); scheduled actions key on ``engine._step_count``.

The acceptance property this enables (see ``tests/test_chaos.py`` and
``benchmarks/table13_chaos.py``): under any fault schedule, every request
ends in a terminal status (nothing hangs, the engine never crashes), every
survivor's greedy output is token-identical to an unfaulted run, and the
invariant auditor finds zero leaked or aliased blocks afterwards.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class FaultInjector:
    """Seeded fault schedule for one engine run.

    Probabilistic knobs (fire independently on every call, optionally
    budget-capped):

    * ``p_alloc_fail`` — probability one ``BlockAllocator.alloc`` call
      reports exhaustion (``max_alloc_faults`` caps the total).
    * ``p_host_put_fail`` / ``p_host_get_fail`` — probability one host-tier
      swap-out / swap-in fails (``max_host_faults`` caps the total).

    Scheduled actions (fire at the first lifecycle tick whose engine step is
    ``>= step``):

    * ``cancel_at`` — iterable of ``(step, uid)``: client cancellation.
    * ``poison_at`` — iterable of ``(step, uid)``: force that request's next
      decode logits to NaN (requires ``guard_nan``; models a poisoned
      activation).
    * ``corrupt_at`` — iterable of steps: write NaN into one randomly chosen
      live, exclusively-owned packed pool block (retries each tick until a
      victim exists); the owner's uid lands in :attr:`corrupted_uids`.
    * ``call_at`` — iterable of ``(step, fn)``: arbitrary host-sync action,
      ``fn(engine)`` — e.g. ``lambda e: e.drain()``.
    """

    def __init__(self, seed: int = 0, p_alloc_fail: float = 0.0,
                 p_host_put_fail: float = 0.0, p_host_get_fail: float = 0.0,
                 max_alloc_faults: int | None = None,
                 max_host_faults: int | None = None,
                 cancel_at=(), poison_at=(), corrupt_at=(), call_at=()):
        for name, p in (("p_alloc_fail", p_alloc_fail),
                        ("p_host_put_fail", p_host_put_fail),
                        ("p_host_get_fail", p_host_get_fail)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} ({p}) must be in [0, 1]")
        self.rng = np.random.default_rng(seed)
        self.p_alloc_fail = p_alloc_fail
        self.p_host_put_fail = p_host_put_fail
        self.p_host_get_fail = p_host_get_fail
        self.max_alloc_faults = max_alloc_faults
        self.max_host_faults = max_host_faults
        self._cancel = sorted(cancel_at)
        self._poison = sorted(poison_at)
        self._corrupt = sorted(corrupt_at)
        self._call = sorted(call_at, key=lambda sf: sf[0])
        # fired-fault counters (chaos tests assert each class actually fired)
        self.alloc_faults = 0
        self.host_put_faults = 0
        self.host_get_faults = 0
        self.cancels_fired = 0
        self.poisons_fired = 0
        self.corruptions_fired = 0
        self.calls_fired = 0
        self.corrupted_uids: set = set()
        self._engine = None

    # ------------------------------------------------------------- wiring
    def attach(self, engine) -> None:
        """Wire the probabilistic hooks into ``engine``'s allocator and
        host store (called by ``ContinuousEngine.__init__``)."""
        self._engine = engine
        engine.alloc.fault_hook = self._alloc_hook
        if engine.host is not None:
            engine.host.fault_hook = self._host_hook

    def _observe(self, kind: str, **args) -> None:
        """Mirror one fired fault into the attached engine's telemetry:
        a ``faults.<kind>`` counter always, plus an engine-track trace
        event when tracing is on — chaos runs assert faults are
        *observable* from the telemetry alone, not just survived."""
        eng = self._engine
        if eng is None:
            return
        eng.stats.registry.counter(f"faults.{kind}").inc()
        if eng.tracer is not None:
            eng.tracer.engine_event(f"fault.{kind}", **args)

    def _alloc_hook(self, n: int) -> bool:
        if self.max_alloc_faults is not None \
                and self.alloc_faults >= self.max_alloc_faults:
            return False
        if self.p_alloc_fail and self.rng.random() < self.p_alloc_fail:
            self.alloc_faults += 1
            self._observe("alloc", blocks=n)
            return True
        return False

    def _host_hook(self, op: str, n: int) -> bool:
        if self.max_host_faults is not None and \
                self.host_put_faults + self.host_get_faults \
                >= self.max_host_faults:
            return False
        p = self.p_host_put_fail if op == "put" else self.p_host_get_fail
        if p and self.rng.random() < p:
            if op == "put":
                self.host_put_faults += 1
            else:
                self.host_get_faults += 1
            self._observe(f"host_{op}", blocks=n)
            return True
        return False

    # ----------------------------------------------------- scheduled fire
    def on_tick(self, engine) -> None:
        """Fire every scheduled action whose step has arrived (called by
        the engine's lifecycle sweep, once per serve-loop iteration)."""
        step = engine._step_count
        while self._call and self._call[0][0] <= step:
            _, fn = self._call.pop(0)
            fn(engine)
            self.calls_fired += 1
            self._observe("call", step=step)
        while self._cancel and self._cancel[0][0] <= step:
            _, uid = self._cancel.pop(0)
            if engine.cancel(uid):
                self.cancels_fired += 1
                self._observe("cancel", uid=uid, step=step)
        while self._poison and self._poison[0][0] <= step:
            _, uid = self._poison.pop(0)
            req = engine._by_uid.get(uid)
            if req is not None and not req.terminal:
                engine._poison_uids.add(uid)
                self.poisons_fired += 1
                self._observe("poison", uid=uid, step=step)
        # corruption retries until a live exclusively-owned block exists
        remaining = []
        for s in self._corrupt:
            if s <= step and self._corrupt_one(engine):
                self.corruptions_fired += 1
            elif s <= step:
                remaining.append(s)      # no victim yet: retry next tick
            else:
                remaining.append(s)
        self._corrupt = remaining

    def _corrupt_one(self, engine) -> bool:
        """Write NaN into one live slot's exclusively-owned packed block
        (within its already-written groups, so decode actually reads it).
        Exclusive ownership (refcount 1) keeps the blast radius to exactly
        one request — shared prefix blocks are never corrupted."""
        import jax.numpy as jnp

        cands = []
        for slot, req in enumerate(engine._slots):
            if req is None or slot in engine._reserved:
                continue
            n_full = (len(req.prompt) + len(req.output) - 1) \
                // engine.group_size
            for b in engine._slot_pages[slot][:n_full]:
                if engine.alloc.refcount(b) == 1:
                    cands.append(b)
        if not cands:
            return False
        b = cands[int(self.rng.integers(len(cands)))]
        owner = next(req for slot, req in enumerate(engine._slots)
                     if req is not None
                     and b in engine._slot_pages[slot])
        pools = list(engine.state.pools)
        li, p = next((i, p) for i, p in enumerate(pools) if p is not None)
        if p.codec.k.quantized:
            # flip the block's key scales to NaN: dequantized keys go NaN,
            # attention scores go NaN, the owner's logits go NaN
            pools[li] = dataclasses.replace(
                p, k_scale=p.k_scale.at[b].set(jnp.nan))
        else:
            # unquantized key segment: the codes array holds raw values
            pools[li] = dataclasses.replace(
                p, k_codes=p.k_codes.at[b].set(jnp.nan))
        engine.state = dataclasses.replace(engine.state, pools=pools)
        self.corrupted_uids.add(owner.uid)
        self._observe("corrupt", uid=owner.uid, block=int(b))
        return True

    # ------------------------------------------------------------ reporting
    def summary(self) -> dict:
        """Fired-fault counts by class (chaos tests assert coverage)."""
        return {
            "alloc_faults": self.alloc_faults,
            "host_put_faults": self.host_put_faults,
            "host_get_faults": self.host_get_faults,
            "cancels_fired": self.cancels_fired,
            "poisons_fired": self.poisons_fired,
            "corruptions_fired": self.corruptions_fired,
            "calls_fired": self.calls_fired,
        }
